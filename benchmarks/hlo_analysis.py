"""Loop-aware HLO cost extraction for the roofline analysis.

``compiled.cost_analysis()`` visits a ``while`` body ONCE, so scanned-layer
models would be undercounted by ~n_layers x (verified in
tests/test_hlo_analysis.py).  This module parses the post-optimization HLO
text (per-device shapes, SPMD-partitioned) and accumulates, per
computation and weighted by loop trip counts:

* ``dot_flops``   -- 2 * prod(result dims) * prod(contracting dims) per
  ``dot``; elementwise flops are excluded (transformer cost is >=95% dots;
  the MODEL_FLOPS/HLO_FLOPs ratio is cleaner on dots only).
* ``bytes``       -- sum over top-level instructions of result + operand
  bytes (post-opt top-level ops are fusions/dots/copies/collectives, so
  this is precisely the HBM traffic the fusion boundary implies).
* ``collective_bytes`` -- per collective family, *wire bytes per chip*
  using ring estimates on the (per-device) result shape:
      all-gather       r * (n-1)/n ~ r
      all-reduce       2r * (n-1)/n ~ 2r
      reduce-scatter   r * (n-1)   ~ input bytes
      all-to-all       r
      collective-permute r

Trip counts come from the largest integer constant in the while's
condition computation (jax scans compare the induction variable against
the literal trip count).
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

def xla_cost_analysis(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across JAX versions.

    Older releases return a *list* of per-device dicts (so ``ca["flops"]``
    raises ``TypeError: list indices must be integers``); newer ones
    return the dict directly.  Returns the first device's dict (SPMD
    lowering makes all devices identical), ``{}`` when unavailable.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"((?:[a-z0-9\-])+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "while", "conditional",
    "call", "iota",
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


@dataclass
class Instr:
    name: str
    rest: str                     # full text after "= "
    opcode: str = ""
    operands: list = field(default_factory=list)


@dataclass
class Computation:
    name: str
    instrs: dict = field(default_factory=dict)


def parse_hlo(text: str) -> dict:
    comps: dict[str, Computation] = {}
    cur = None
    for line in text.splitlines():
        if line and not line.startswith(" "):
            m = re.match(r"(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\{", line)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if line.lstrip().startswith("ENTRY") or "ENTRY" in line:
                    comps["__entry__"] = cur
            elif line.startswith("}"):
                cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rest = m.groups()
        # opcode = first word followed by '(' after the result type
        after_type = rest
        om = _OPCODE_RE.search(after_type)
        opcode = om.group(1) if om else ""
        # operands: %names inside the first balanced paren group
        pstart = after_type.find("(")
        pend = after_type.find(")", pstart)
        operands = (_OPERAND_RE.findall(after_type[pstart:pend + 1])
                    if pstart >= 0 else [])
        ins = Instr(name=name, rest=rest, opcode=opcode, operands=operands)
        cur.instrs[name] = ins
    return comps


def _trip_count(comps, cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for ins in cond.instrs.values():
        if ins.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", ins.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _dot_flops(comp: Computation, ins: Instr) -> float:
    _, out_dims = _shape_dims(ins.rest)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    if not m or not ins.operands:
        return 0.0
    cdims = [int(d) for d in m.group(1).split(",") if d]
    lhs = comp.instrs.get(ins.operands[0])
    if lhs is None:
        return 0.0
    _, lhs_dims = _shape_dims(lhs.rest)
    k = 1
    for d in cdims:
        if d < len(lhs_dims):
            k *= lhs_dims[d]
    out = 1
    for d in out_dims:
        out *= d
    return 2.0 * out * k


def _result_bytes(ins: Instr) -> int:
    head = ins.rest.split(" ")[0]
    return _shape_bytes(head if "[" in head else ins.rest)


def _operand_bytes(comp: Computation, ins: Instr) -> int:
    total = 0
    for op in ins.operands:
        src = comp.instrs.get(op)
        if src is not None:
            total += _result_bytes(src)
    return total


def _traffic_bytes(comp: Computation, ins: Instr, comps: dict) -> int:
    """HBM traffic estimate for one top-level instruction.

    Slice-aware: ``dynamic-slice``/``gather`` read only the slice (a scan
    body slicing stacked (n_layers, ...) weights must NOT be charged the
    whole stack per iteration); ``dynamic-update-slice``/``scatter`` are
    read-modify-writes of the update region only.  Fusions whose
    parameters feed *only* slicing ops inside are charged those params at
    the sliced size.
    """
    op = ins.opcode
    if op in ("dynamic-slice", "gather"):
        return 2 * _result_bytes(ins)             # read slice + write out
    if op in ("dynamic-update-slice", "scatter"):
        upd = (comp.instrs.get(ins.operands[1])
               if len(ins.operands) > 1 else None)
        return 3 * _result_bytes(upd) if upd is not None \
            else _result_bytes(ins)
    if op == "fusion":
        m = re.search(r"calls=%([\w.\-]+)", ins.rest)
        body = comps.get(m.group(1)) if m else None
        total = _result_bytes(ins)
        if body is None:
            return total + _operand_bytes(comp, ins)
        # param index -> set of opcodes consuming it inside the fusion
        params = {}
        for bins in body.instrs.values():
            if bins.opcode == "parameter":
                pm = re.search(r"parameter\((\d+)\)", bins.rest)
                if pm:
                    params[bins.name] = int(pm.group(1))
        # value origin: walk unary chains (convert/bitcast/copy/reshape/
        # transpose) back to a parameter so in-place DUS targets and slice
        # reads are detected through layout/dtype hops
        _UNARY = {"convert", "bitcast", "copy", "reshape", "transpose",
                  "broadcast"}

        def origin(name, depth=0):
            ins2 = body.instrs.get(name)
            if ins2 is None or depth > 8:
                return None
            if ins2.opcode == "parameter":
                return params.get(name)
            if ins2.opcode in _UNARY and ins2.operands:
                return origin(ins2.operands[0], depth + 1)
            return None

        consumers: dict[int, set] = {}
        slice_out: dict[int, int] = {}
        dus_target: set = set()
        for bins in body.instrs.values():
            for pos, opd in enumerate(bins.operands):
                idx = params.get(opd)
                if idx is None and bins.opcode in (
                        "dynamic-slice", "gather", "dynamic-update-slice"):
                    idx = origin(opd)
                if idx is None:
                    continue
                consumers.setdefault(idx, set()).add(bins.opcode)
                if bins.opcode in ("dynamic-slice", "gather"):
                    slice_out[idx] = slice_out.get(idx, 0) + \
                        _result_bytes(bins)
                if bins.opcode == "dynamic-update-slice" and pos == 0:
                    dus_target.add(idx)
        if dus_target:
            # in-place scatter fusion: the (aliased) full-buffer result is
            # NOT traffic -- charge read+write of the update slices instead
            upd_bytes = sum(
                _result_bytes(body.instrs[bins.operands[1]])
                for bins in body.instrs.values()
                if bins.opcode == "dynamic-update-slice"
                and len(bins.operands) > 1
                and bins.operands[1] in body.instrs)
            total = 2 * upd_bytes
        for i, opd in enumerate(ins.operands):
            src = comp.instrs.get(opd)
            if src is None:
                continue
            full = _result_bytes(src)
            used = consumers.get(i, set())
            if i in dus_target:
                continue       # in-place updated buffer: aliased, ~free read
            if used and used <= {"dynamic-slice", "gather"}:
                total += min(slice_out.get(i, full), full)
            else:
                total += full
        return total
    return _result_bytes(ins) + _operand_bytes(comp, ins)


def analyze(text: str) -> dict:
    """-> dict(dot_flops, bytes, collective_bytes, collectives={op: bytes},
    n_collective_ops, while_trips={name: trip}).  All values are
    PER-DEVICE (post-SPMD shapes), loop-trip weighted."""
    comps = parse_hlo(text)
    memo: dict[str, dict] = {}
    ops_memo: dict[str, list] = {}
    trips_seen = {}

    def comp_cost(cname: str, stack=()) -> dict:
        if cname in memo:
            return memo[cname]
        if cname in stack:           # recursion guard
            return defaultdict(float)
        comp = comps.get(cname)
        if comp is None:
            return defaultdict(float)
        acc = defaultdict(float)
        coll = defaultdict(float)
        ops: list = []
        for ins in comp.instrs.values():
            rtype = ins.rest[:ins.rest.find(" ")] if " " in ins.rest else ins.rest
            rbytes = _shape_bytes(ins.rest[:ins.rest.find(")")]
                                  if ins.opcode == "" else rtype)
            if ins.opcode == "dot":
                fl = _dot_flops(comp, ins)
                acc["dot_flops"] += fl
                # classify by operand dtype (MXU pipe): int8 runs at 2x bf16
                lhs = comp.instrs.get(ins.operands[0]) if ins.operands else None
                ldt = _shape_dims(lhs.rest)[0] if lhs else None
                if ldt in ("s8", "u8", "s4", "u4", "s16", "s32", "u32"):
                    acc["dot_flops_int"] += fl
                elif ldt == "f32":
                    acc["dot_flops_f32"] += fl
                else:
                    acc["dot_flops_bf16"] += fl
            if ins.opcode == "while":
                m = re.search(r"condition=%([\w.\-]+)", ins.rest)
                b = re.search(r"body=%([\w.\-]+)", ins.rest)
                trip = _trip_count(comps, m.group(1)) if m else 1
                trips_seen[ins.name] = trip
                if b:
                    sub = comp_cost(b.group(1), stack + (cname,))
                    for k, v in sub.items():
                        if k.startswith("coll:"):
                            coll[k[5:]] += v * trip
                        acc[k] += v * trip
                    ops.extend(
                        dict(o, bytes=o["bytes"] * trip,
                             flops=o["flops"] * trip,
                             name=f"{ins.name}[x{trip}]/{o['name']}")
                        for o in ops_memo.get(b.group(1), []))
                continue
            if ins.opcode in ("call", "conditional"):
                for cm in re.findall(r"(?:to_apply|calls)=%([\w.\-]+)",
                                     ins.rest):
                    sub = comp_cost(cm, stack + (cname,))
                    for k, v in sub.items():
                        if k.startswith("coll:"):
                            coll[k[5:]] += v
                        acc[k] += v
                continue
            if ins.opcode in _FREE_OPS or not ins.opcode:
                continue
            tb = _traffic_bytes(comp, ins, comps)
            acc["bytes"] += tb
            ops.append(dict(name=ins.name, opcode=ins.opcode, bytes=tb,
                            flops=_dot_flops(comp, ins)
                            if ins.opcode == "dot" else 0.0))
            for c in _COLLECTIVES:
                if ins.opcode == c:
                    factor = {"all-gather": 1.0, "all-reduce": 2.0,
                              "reduce-scatter": 1.0, "all-to-all": 1.0,
                              "collective-permute": 1.0}[c]
                    if c == "reduce-scatter":
                        wire = _operand_bytes(comp, ins)
                    else:
                        wire = rbytes * factor
                    acc["coll:" + c] += wire
                    acc["collective_bytes"] += wire
                    acc["n_collective_ops"] += 1
        memo[cname] = acc
        ops.sort(key=lambda o: -o["bytes"])
        ops_memo[cname] = ops[:24]
        return acc

    entry = comps.get("__entry__")
    if entry is None:
        return {"dot_flops": 0, "bytes": 0, "collective_bytes": 0}
    total = comp_cost(entry.name)
    out = dict(total)
    out["collectives"] = {k[5:]: v for k, v in total.items()
                          if k.startswith("coll:")}
    for k in list(out):
        if k.startswith("coll:"):
            del out[k]
    out["while_trips"] = trips_seen
    out["top_ops"] = ops_memo.get(entry.name, [])[:16]
    return out
