"""Benchmark harness -- one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

* ``us_per_call`` is a REAL measured wall time on this CPU host (jnp
  reference dataflow -- the same packed buffers/math the TPU kernel uses,
  numerically identical; interpret-mode Pallas is excluded from timing as
  it measures the Python interpreter, not the kernel).
* ``derived`` is the v5e roofline-model projection (benchmarks/tpu_model)
  -- the honest stand-in for the paper's RTX-3090 wall clocks on this
  CPU-only container (clearly labeled; see EXPERIMENTS.md).

Sections:
  T1  square MatMuls 1k/2k/4k      (paper Table 1)
  T2  Llama2-7B-shaped MatMuls     (paper Table 2)
  F5  TOPS-vs-size curves          (paper Fig. 5/6)
  F7  end-to-end LLM inference     (paper Fig. 7)
  M   packed-memory reduction      (paper §4.1 claim)
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import tpu_model as T


def _time_call(fn, *args, reps: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def _emit(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


# ---------------------------------------------------------------------------
# T1 / T2: GEMM benchmarks
# ---------------------------------------------------------------------------

SQUARE = [(1024, 1024, 1024), (2048, 2048, 2048), (4096, 4096, 4096)]
LLAMA = [(1024, 4096, 4096), (1024, 10752, 4096), (1024, 4096, 10752)]
# decode-phase GEMMs (M = batch): memory-bound on TPU -> bit-width-
# proportional speedups, the regime the paper's packing actually targets
DECODE = [(16, 4096, 4096), (16, 10752, 4096), (128, 14336, 4096)]
SCHEMES = ["FP32", "BF16", "INT8", "INT4", "W3A4", "W2A2", "W1A2"]


def _measured_gemm_us(m, n, k, name: str) -> float:
    """CPU wall time of the reference dataflow (small rep counts)."""
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    if name in ("FP32", "BF16"):
        dt = jnp.float32 if name == "FP32" else jnp.bfloat16
        a = jnp.asarray(rng.standard_normal((m, k)), dt)
        b = jnp.asarray(rng.standard_normal((n, k)), dt)
        f = jax.jit(lambda a, b: jax.lax.dot_general(
            a, b, (((1,), (1,)), ((), ()))))
        return _time_call(f, a, b, reps=3, warmup=1)
    if name.startswith("W"):
        wb, ab = (int(x) for x in name[1:].split("A"))
        a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        bt = ops.pack_weight(
            jnp.asarray(rng.standard_normal((n, k)), jnp.float32), wb,
            impl="reference")
        f = jax.jit(lambda a: ops.ap_linear(a, bt, a_bits=ab,
                                            impl="reference"))
        return _time_call(f, a, reps=3, warmup=1)
    return float("nan")  # INT8/INT4: no native CPU int-MXU analogue


def bench_gemm(shapes, tag):
    base = {s: T.gemm_time(*s, T.scheme("FP32"))["t"] for s in shapes}
    for name in SCHEMES:
        sch = T.scheme(name)
        for s in shapes:
            r = T.gemm_time(*s, sch)
            spd = base[s] / r["t"]
            us = _measured_gemm_us(*s, name)
            _emit(f"{tag}.{name}.{'x'.join(map(str, s))}", us,
                  f"v5e={r['t']*1e6:.1f}us speedup_vs_fp32={spd:.1f}x "
                  f"bound={r['bound']}")
    # paper-faithful bit-serial variant (the reproduction baseline)
    for name in ("W3A4", "W2A2", "W1A2"):
        sch = T.scheme(name, variant="bitserial")
        for s in shapes:
            r = T.gemm_time(*s, sch)
            spd = base[s] / r["t"]
            _emit(f"{tag}.{sch.name}.{'x'.join(map(str, s))}", float("nan"),
                  f"v5e={r['t']*1e6:.1f}us speedup_vs_fp32={spd:.1f}x "
                  f"bound={r['bound']}")


# ---------------------------------------------------------------------------
# F5/F6: TOPS curves
# ---------------------------------------------------------------------------

def bench_tops():
    for size in (128, 256, 512, 1024, 2048, 4096):
        row = []
        for name in ("BF16", "W2A2", "W1A2", "W3A4"):
            row.append(f"{name}={T.tops(size, size, size, T.scheme(name)):.0f}")
        _emit(f"F5.tops.{size}", float("nan"), " ".join(row) + " TOPS")


# ---------------------------------------------------------------------------
# F7: end-to-end LLM inference (measured small model + derived 7B)
# ---------------------------------------------------------------------------

def bench_llm_inference():
    import dataclasses

    from repro.configs import get_config
    from repro.models import model as M
    from repro.models.config import QuantConfig
    from repro.serving import engine as E

    cfg = get_config("llama3-8b").reduced(n_layers=4, d_model=256,
                                          n_heads=8, n_kv_heads=2,
                                          d_head=32, d_ff=512)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, (8,), dtype=np.int32)

    def tokens_per_s(p, quant):
        eng = E.Engine(p, cfg, n_slots=4, max_len=64, quant=quant)
        for _ in range(4):
            eng.submit(E.Request(prompt=prompt, max_new_tokens=8))
        t0 = time.perf_counter()
        eng.run()
        dt = time.perf_counter() - t0
        return 4 * 8 / dt

    tps_bf16 = tokens_per_s(params, None)
    for wb in (4, 2, 1):
        q = QuantConfig(w_bits=wb, a_bits=8)
        qp = M.quantize_params(params, q)
        tps = tokens_per_s(qp, q)
        # derived: decode step of the FULL llama3-8b on one v5e chip slice
        full = get_config("llama3-8b")
        nbytes_q = full.param_count() * wb / 8
        nbytes_bf = full.param_count() * 2
        t_q = nbytes_q / T.HBM_BW
        t_bf = nbytes_bf / T.HBM_BW
        _emit(f"F7.llama3-8b.W{wb}A8",
              1e6 / tps,
              f"cpu_tok_s={tps:.2f} (bf16 {tps_bf16:.2f}) "
              f"v5e_decode_speedup_vs_bf16={t_bf/t_q:.1f}x "
              f"(weight-HBM-bound decode)")


# ---------------------------------------------------------------------------
# M: §4.1 memory reduction (real bytes)
# ---------------------------------------------------------------------------

def bench_memory():
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((4096, 4096)), jnp.float32)
    for bits in (1, 2, 3, 4, 8):
        t = ops.pack_weight(w, bits, impl="reference")
        _emit(f"M.pack.{bits}bit", float("nan"),
              f"packed={t.nbytes_packed} bf16={t.nbytes_dense_bf16} "
              f"ratio={t.nbytes_dense_bf16/t.nbytes_packed:.2f}x")


def main() -> None:
    print("name,us_per_call,derived")
    bench_gemm(SQUARE, "T1")
    bench_gemm(LLAMA, "T2")
    bench_gemm(DECODE, "T2d")
    bench_tops()
    bench_memory()
    bench_llm_inference()


if __name__ == "__main__":
    main()
