"""Observability overhead: enabled vs disabled step time + agreement.

ISSUE 7's acceptance gate: with metrics *disabled* (the default) the
engine must be token-identical to the uninstrumented engine and pay at
most ~2% step-time overhead -- the hot path's only cost is one
attribute access + one constant no-op call per event (``NULL_OBS``).
With metrics *enabled* the registry counters must agree with
independent accounting.

Measured here, on the real reduced-model engine (CPU interpret):

* ``step_time_disabled_s`` / ``step_time_enabled_s``: min-of-repeats
  mean step wall time for an identical chunked workload with
  ``metrics=None`` vs ``metrics=True`` (one warmup run first, so JIT
  compilation is excluded from both).
* ``null_hook_ns``: nanoseconds per ``NULL_OBS`` hook call, measured
  directly, and ``computed_disabled_overhead_frac``: hook calls per
  step (counted from an instrumented run) x ns per call / measured
  step time.  This is the disabled-mode overhead bound the CI gates at
  <= 2% -- it does not depend on timer noise between two short runs.
* ``token_identity``: outputs byte-identical with metrics on vs off.
* ``ttft_agreement``: under a deterministic tick clock, the
  ``repro_request_ttft_seconds`` histogram's sum/count equal the
  per-request trace TTFTs -- registry and tracer cannot drift.
* ``stall_agreement``: benchmarks/chunked_prefill.py's simulate()
  asserts the ``repro_sched_stall_*`` counters equal its hand tally in
  both modes (re-run here; an AssertionError fails the benchmark).

Results go to ``BENCH_obs_overhead.json``; CI's bench-smoke job gates
the computed disabled overhead, the agreement booleans, and a loose
ceiling on the enabled ratio.

Usage:  PYTHONPATH=src:. python -m benchmarks.obs_overhead \
            [--out BENCH_obs_overhead.json]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

PROMPTS = (5, 9, 14)
MAX_NEW = 8
REPEATS = 5
NULL_CALLS = 200_000


class _Tick:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 0.001
        return self.t


def _build(metrics, clock=None):
    import jax
    from repro.configs import get_config
    from repro.models import model as M
    from repro.serving import engine as E

    cfg = get_config("mamba2-130m").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    kw = dict(clock=clock) if clock is not None else {}
    eng = E.Engine(params, cfg, n_slots=2, max_len=32, paged=True,
                   block_size=4, chunk_tokens=3, metrics=metrics, **kw)
    rng = np.random.default_rng(3)
    reqs = [E.Request(prompt=rng.integers(0, cfg.vocab, (n,),
                                          dtype=np.int32),
                      max_new_tokens=MAX_NEW) for n in PROMPTS]
    return eng, reqs


def _timed_run(metrics) -> tuple[float, list, object]:
    """One full workload; returns (mean step seconds, outputs, engine)."""
    eng, reqs = _build(metrics)
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0
    assert all(r.done and r.error is None for r in reqs)
    return dt / max(eng.steps, 1), [r.out for r in reqs], eng


def bench_step_time() -> dict:
    _timed_run(None)                      # warmup: JIT compilation
    off = min(_timed_run(None)[0] for _ in range(REPEATS))
    on = min(_timed_run(True)[0] for _ in range(REPEATS))
    _, out_off, _ = _timed_run(None)
    _, out_on, _ = _timed_run(True)
    return dict(step_time_disabled_s=off, step_time_enabled_s=on,
                enabled_overhead_ratio=on / off,
                token_identity=out_off == out_on)


def bench_null_hooks() -> float:
    """ns per NULL_OBS hook call (the entire disabled-mode cost)."""
    from repro.obs import NULL_OBS
    req = object()
    t0 = time.perf_counter()
    for _ in range(NULL_CALLS):
        NULL_OBS.on_token(req, 0)
    return (time.perf_counter() - t0) / NULL_CALLS * 1e9


def hooks_per_step() -> float:
    """Hook calls per engine step, counted on an instrumented run (the
    per-step NULL_OBS call count a disabled engine pays)."""
    eng, reqs = _build(True, clock=_Tick())
    for r in reqs:
        eng.submit(r)
    eng.run()
    reg = eng.obs.registry
    steps = reg.value("repro_engine_steps")
    traces = eng.obs.tracer.traces.values()
    chunks = sum(tr.n_chunks for tr in traces)
    # per request: submit + admit + decode_begin + finish; per token:
    # on_token; per chunk: on_chunk; per step: on_step + one dispatch
    calls = (4 * len(reqs) + reg.value("repro_engine_tokens")
             + chunks + 2 * steps)
    return calls / max(steps, 1)


def bench_ttft_agreement() -> dict:
    """Registry TTFT histogram vs per-trace TTFTs under a tick clock."""
    eng, reqs = _build(True, clock=_Tick())
    for r in reqs:
        eng.submit(r)
    eng.run()
    h = eng.obs.registry.get("repro_request_ttft_seconds")
    ttfts = [tr.ttft for tr in eng.obs.tracer.traces.values()
             if tr.ttft is not None]
    return dict(
        ttft_count=h.count,
        ttft_sum=h.sum,
        ttft_agreement=(h.count == len(ttfts) == len(reqs)
                        and abs(h.sum - sum(ttfts)) < 1e-9))


def bench_stall_agreement() -> dict:
    """Re-run the chunked-prefill simulation (its internal asserts are
    the agreement check) and surface the registry counters."""
    from benchmarks.chunked_prefill import CHUNK_TOKENS, simulate
    whole = simulate(None)
    chunked = simulate(CHUNK_TOKENS)
    return dict(stall_agreement=True,     # simulate() asserted it
                stall_tokens_whole=whole["stall_tokens_total"],
                stall_tokens_chunked=chunked["stall_tokens_total"],
                stall_steps_whole=whole["stall_steps"],
                stall_steps_chunked=chunked["stall_steps"])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_obs_overhead.json")
    args = ap.parse_args()
    result = bench_step_time()
    ns = bench_null_hooks()
    hps = hooks_per_step()
    result.update(
        null_hook_ns=ns,
        hooks_per_step=hps,
        computed_disabled_overhead_frac=(
            hps * ns * 1e-9 / result["step_time_disabled_s"]))
    result.update(bench_ttft_agreement())
    result.update(bench_stall_agreement())
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"step time  off {result['step_time_disabled_s']*1e3:.2f} ms"
          f"  on {result['step_time_enabled_s']*1e3:.2f} ms"
          f"  (ratio {result['enabled_overhead_ratio']:.3f})")
    print(f"NULL_OBS   {ns:.0f} ns/call x {hps:.1f} calls/step -> "
          f"{result['computed_disabled_overhead_frac']*100:.4f}% of a "
          f"disabled step")
    print(f"agreement  token_identity={result['token_identity']} "
          f"ttft={result['ttft_agreement']} "
          f"stall={result['stall_agreement']}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
