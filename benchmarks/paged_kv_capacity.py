"""Decode-capacity benchmark: contiguous slots vs the paged block pool.

Extends the roofline model (benchmarks/roofline.py) to serving *memory*
capacity: decode is KV-HBM-bound, so at a fixed cache-byte budget the
number of concurrent requests an engine can hold -- and with it decode
batch size and throughput -- is set by bytes per *resident* token.  The
contiguous engine reserves ``max_len`` tokens per slot regardless of
request length; the paged pool (src/repro/serving/paged_cache.py) holds
``ceil(len / block_size)`` blocks per request, so capacity scales with
the actual length mix and with ``kv_bits``.

Per (kv_bits x request-length mix) this script reports:

* bytes per cached token (packed bipolar planes + scales vs bf16),
* max concurrent requests at a fixed pool-byte budget, contiguous vs
  paged (analytic, from the mix), and the capacity ratio,
* tokens resident at that point and the paged pool's internal
  fragmentation,
* decode HBM time per step for the resident KV bytes at the roofline
  HBM bandwidth (the roofline.py memory term restricted to KV traffic),

and cross-checks the analytic pool model against the real
``PagedKVPool`` block accounting on a reduced config (same alloc code
the engine runs).  Results go to ``BENCH_paged_kv.json``.

Sliding-window reclaim (PR 5): ``run_swa_reclaim`` drives the *real*
pool + scheduler through a long-generation mix at ``window <
max_len`` and reports steady-state blocks/request vs window size --
out-of-window blocks roll off the table and return to the pool, so
steady state is ``~window/block_size + 1`` blocks however long the
generation runs (an un-reclaimed pool would hold ``length/block_size``).
Results go to ``BENCH_swa_reclaim.json`` and the CI ``bench-smoke`` job
gates the bound per PR.

Usage:  PYTHONPATH=src:. python -m benchmarks.paged_kv_capacity \
            [--out BENCH_paged_kv.json] [--swa-out BENCH_swa_reclaim.json]
"""

from __future__ import annotations

import argparse
import dataclasses
import json

import numpy as np

HBM_BW = 819e9          # bytes/s/chip, matches benchmarks/roofline.py

# a serving-shape reference arch for the analytic model (llama3-8b-like)
N_LAYERS = 32
N_KV_HEADS = 8
HEAD_DIM = 128
MAX_LEN = 2048
BLOCK_SIZE = 16
POOL_BYTES = 8 << 30    # 8 GiB KV budget per chip

MIXES = {
    # name -> (low, high) request lengths (tokens, uniform)
    "short": (16, 64),
    "mixed": (16, 512),
    "long": (512, 2048),
}


def bytes_per_token(kv_bits: int, n_kv_heads: int = N_KV_HEADS,
                    head_dim: int = HEAD_DIM,
                    n_layers: int = N_LAYERS) -> int:
    """Cache bytes per resident token across all layers (K + V).

    kv_bits=16 = the bf16 cache; otherwise packed bipolar planes
    (kv_bits uint32 words per 32 elements) + one f32 scale per
    (token, head) for each of K and V."""
    if kv_bits == 16:
        per_head = 2 * head_dim * 2                   # K+V bf16
    else:
        words = -(-head_dim // 32)
        per_head = 2 * (kv_bits * words * 4 + 4)      # planes + scale
    return per_head * n_kv_heads * n_layers


def capacity(pool_bytes: int, kv_bits: int, lens: np.ndarray,
             block_size: int = BLOCK_SIZE, max_len: int = MAX_LEN) -> dict:
    """Concurrent requests held at ``pool_bytes``: contiguous reserves
    ``max_len`` tokens per slot; paged reserves whole blocks."""
    bpt = bytes_per_token(kv_bits)
    slots = int(pool_bytes // (max_len * bpt))
    block_bytes = block_size * bpt
    n_blocks = int(pool_bytes // block_bytes)
    free = n_blocks
    admitted = tokens = blocks_used = 0
    for ln in lens:
        need = -(-int(ln) // block_size)
        if need > free:
            break
        free -= need
        blocks_used += need
        admitted += 1
        tokens += int(ln)
    resident_bytes = blocks_used * block_bytes
    return dict(
        kv_bits=kv_bits,
        bytes_per_token=bpt,
        contiguous_requests=slots,
        paged_requests=admitted,
        capacity_ratio=admitted / max(slots, 1),
        tokens_resident=tokens,
        fragmentation=(1.0 - tokens / (blocks_used * block_size))
        if blocks_used else 0.0,
        # roofline memory term for one decode step (read all resident KV)
        decode_hbm_ms=resident_bytes / HBM_BW * 1e3,
    )


def run_analytic(seed: int = 0) -> list:
    rng = np.random.default_rng(seed)
    rows = []
    for mix, (lo, hi) in MIXES.items():
        lens = rng.integers(lo, hi + 1, size=100_000)
        for kv_bits in (2, 4, 8, 16):
            rows.append(dict(mix=mix, len_range=[lo, hi],
                             **capacity(POOL_BYTES, kv_bits, lens)))
    return rows


def run_empirical() -> dict:
    """Cross-check the analytic block model against the real pool: same
    byte budget, same mix, counted by PagedKVPool's own alloc/report."""
    import jax  # noqa: F401  (pulls in the repro stack)
    from repro.configs import get_config
    from repro.models import model as M
    from repro.serving.engine import kv_cache_bytes
    from repro.serving.paged_cache import PagedKVPool

    cfg = get_config("llama3-8b").reduced(n_layers=2, d_head=32)
    kv8 = dataclasses.replace(cfg.quant, w_bits=None, kv_bits=8)
    n_slots, max_len, block_size = 2, 256, 16
    budget = kv_cache_bytes(M.init_caches(cfg, n_slots, max_len, quant=kv8))
    probe = PagedKVPool(cfg, 2, block_size, quant=kv8)
    per_block = kv_cache_bytes(probe.caches) // 2
    pool = PagedKVPool(cfg, int(budget // per_block), block_size, quant=kv8)

    rng = np.random.default_rng(0)
    admitted = tokens = 0
    while True:
        ln = int(rng.integers(16, 129))
        need = pool.blocks_for(ln)
        if need > pool.free_blocks:
            break
        pool.alloc(need)
        admitted += 1
        tokens += ln
    rep = pool.report(tokens_resident=tokens)
    return dict(arch="llama3-8b reduced", kv_bits=8,
                budget_bytes=int(budget),
                pool_bytes=rep["pool_bytes"],
                contiguous_requests=n_slots,
                paged_requests=admitted,
                capacity_ratio=admitted / n_slots,
                fragmentation=rep["fragmentation"],
                occupancy=rep["occupancy"])


def run_swa_reclaim(windows=(8, 16, 32), *, block_size=4, max_len=128,
                    gen_tokens=96, n_requests=3) -> list:
    """Long-generation sliding-window mix through the real pool +
    scheduler (stub prefill: block accounting only, no model forward).

    Per window size: peak and steady-state blocks/request over a
    ``gen_tokens``-token generation, blocks reclaimed by the window,
    and what an un-reclaimed pool would have held at the end."""
    import dataclasses as dc

    import jax  # noqa: F401  (pulls in the repro stack)
    from repro.configs import get_config
    from repro.serving.paged_cache import PagedKVPool
    from repro.serving.scheduler import Scheduler

    rows = []
    for window in windows:
        cfg = get_config("mixtral-8x7b").reduced(
            n_layers=2, window=window, max_seq_len=max_len)
        kv8 = dc.replace(cfg.quant, w_bits=None, kv_bits=8)
        pool = PagedKVPool(cfg, n_blocks=2 * n_requests * max_len
                           // block_size + 1,
                           block_size=block_size, quant=kv8)
        sch = Scheduler(pool, max_len=max_len, max_batch=n_requests)

        def stub(seq, tokens):
            seq.length = len(tokens)
            seq.last_tok = 1
            if not seq.req.out:
                seq.req.out.append(1)

        class Req:
            def __init__(self, prompt, n):
                self.prompt, self.max_new_tokens = prompt, n
                self.out, self.done, self.error = [], False, None
                self.temperature = 0.0

        prompt_len = window // 2 + 3
        for r in range(n_requests):
            sch.submit(Req(np.arange(prompt_len, dtype=np.int32) + r,
                           gen_tokens))
        sch.admit(stub)
        peak = steady = length = 0
        steps = 0
        while sch.running and steps < gen_tokens:
            sch.ensure_append_capacity()    # reclaim + per-step allocs
            for s in list(sch.running):
                s.req.out.append(1)
                s.length += 1
                length = max(length, s.length)   # actual tokens reached
                if len(s.req.out) >= s.req.max_new_tokens:
                    sch.finish(s)
            if sch.running:
                live = max(len(s.blocks) for s in sch.running)
                peak = max(peak, live)
                steady = live    # last observed = steady state
            steps += 1
        rows.append(dict(
            window=window, block_size=block_size,
            gen_tokens=gen_tokens, final_length=length,
            peak_blocks_per_request=peak,
            steady_blocks_per_request=steady,
            # sub-block tail compaction pre-seeds the next append block
            # while releasing the straddler, shaving the +1 write-target
            # block off the rolling-table steady state
            bound_blocks_per_request=window // block_size + 1,
            compacted_bound_blocks_per_request=window // block_size,
            unreclaimed_blocks_per_request=-(-length // block_size),
            window_reclaimed=pool.report()["window_reclaimed"],
            tail_compactions=int(sch._c_compactions.value),
            preemptions=sch.n_preemptions,
        ))
    return rows


def table(rows: list) -> str:
    hdr = ("| mix | kv_bits | B/token | contiguous | paged | ratio "
           "| frag | decode HBM/step |\n|---|---|---|---|---|---|---|---|\n")
    out = []
    for r in rows:
        out.append(
            f"| {r['mix']} | {r['kv_bits']} | {r['bytes_per_token']} | "
            f"{r['contiguous_requests']} | {r['paged_requests']} | "
            f"{r['capacity_ratio']:.1f}x | {r['fragmentation']*100:.1f}% | "
            f"{r['decode_hbm_ms']:.2f}ms |")
    return hdr + "\n".join(out) + "\n"


def swa_table(rows: list) -> str:
    hdr = ("| window | steady blk/req | bound | peak | unreclaimed "
           "| reclaims | compactions |\n|---|---|---|---|---|---|---|\n")
    out = []
    for r in rows:
        out.append(
            f"| {r['window']} | {r['steady_blocks_per_request']} | "
            f"{r['bound_blocks_per_request']} | "
            f"{r['peak_blocks_per_request']} | "
            f"{r['unreclaimed_blocks_per_request']} | "
            f"{r['window_reclaimed']} | {r['tail_compactions']} |")
    return hdr + "\n".join(out) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_paged_kv.json")
    ap.add_argument("--swa-out", default="BENCH_swa_reclaim.json")
    ap.add_argument("--skip-empirical", action="store_true")
    ap.add_argument("--skip-swa", action="store_true")
    args = ap.parse_args()
    rows = run_analytic()
    result = dict(
        model=dict(n_layers=N_LAYERS, n_kv_heads=N_KV_HEADS,
                   head_dim=HEAD_DIM, max_len=MAX_LEN,
                   block_size=BLOCK_SIZE, pool_bytes=POOL_BYTES,
                   hbm_bw=HBM_BW),
        analytic=rows,
    )
    if not args.skip_empirical:
        result["empirical"] = run_empirical()
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(table(rows))
    if "empirical" in result:
        e = result["empirical"]
        print(f"empirical ({e['arch']}, kv_bits=8, equal bytes): "
              f"{e['paged_requests']} paged vs {e['contiguous_requests']} "
              f"contiguous requests = {e['capacity_ratio']:.1f}x, "
              f"fragmentation {e['fragmentation']*100:.1f}%")
    print(f"wrote {args.out}")
    if not args.skip_swa:
        swa = run_swa_reclaim()
        with open(args.swa_out, "w") as f:
            json.dump(dict(swa_reclaim=swa), f, indent=1)
        print(swa_table(swa))
        print(f"wrote {args.swa_out}")


if __name__ == "__main__":
    main()
