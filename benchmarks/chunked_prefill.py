"""Chunked-prefill benchmark: decode stall + admission gate vs whole-prompt.

Companion to benchmarks/paged_kv_capacity.py (capacity) for the serving
loop's *latency* story: decode is HBM-bound, so the time a running
decode waits on a step is set by how much prompt work the step
co-schedules.  Whole-prompt admission processes an entire arriving
prompt alongside the decode batch -- every running request stalls
O(prompt tokens) in that step, and the admission gate transiently
demands O(prompt) blocks.  Chunked prefill (ISSUE 6) streams the prompt
through the step loop ``chunk_tokens`` at a time fused with the decode
bucket, and reclaims out-of-window blocks between chunks, so the stall
is bounded by the chunk budget and the gate by
``blocks_for(window + chunk) + 2``.

The simulation drives the *real* ``PagedKVPool`` + ``Scheduler`` (same
code the engine runs; stub execution, no model forward) through an
identical workload in both modes -- a resident decode batch plus a
stream of long prompts arriving mid-generation -- and reports:

* ``stall_tokens`` per step while a prompt is in flight and at least
  one decode is running (p50/p95/max): prompt tokens co-scheduled with
  the decodes, the per-step decode-latency tax,
* ``stall_free_frac``: fraction of decode steps with zero prompt work,
* per-arrival admission-gate blocks (``Scheduler.lifetime_need``),
* ``max_servable_prompt``: the longest prompt the gate admits at all,
* ``stall_tokens_total`` / ``stall_steps``: the scheduler's OWN stall
  counters (``repro_sched_stall_*`` in the shared metrics registry,
  ISSUE 7), asserted against an independent hand tally of the same
  canonical rule -- the telemetry cannot drift from the simulation.

Results go to ``BENCH_chunked_prefill.json``; the CI ``bench-smoke``
job gates chunked p95 <= chunk budget < whole-prompt p95 and the gate/
servable-length wins.

Usage:  PYTHONPATH=src:. python -m benchmarks.chunked_prefill \
            [--out BENCH_chunked_prefill.json]
"""

from __future__ import annotations

import argparse
import dataclasses
import json

import numpy as np

WINDOW = 8
BLOCK_SIZE = 4
MAX_LEN = 256
N_BLOCKS = 41            # 40 usable
MAX_BATCH = 8
CHUNK_TOKENS = 4

DECODE_REQS = 4          # resident decode batch (short prompts)
DECODE_PROMPT = 8
DECODE_NEW = 96
# long prompts arriving while the decodes run: (arrival step, length)
ARRIVALS = [(8, 48), (24, 96), (40, 120)]
ARRIVAL_NEW = 4


class _Req:
    def __init__(self, prompt, n):
        self.prompt, self.max_new_tokens = prompt, n
        self.out, self.done, self.error = [], False, None
        self.temperature = 0.0
        self.finish_reason = None


def _build(chunk):
    import jax  # noqa: F401  (pulls in the repro stack)
    from repro.configs import get_config
    from repro.serving.paged_cache import PagedKVPool
    from repro.serving.scheduler import Scheduler

    cfg = get_config("mixtral-8x7b").reduced(
        n_layers=2, window=WINDOW, max_seq_len=MAX_LEN)
    kv8 = dataclasses.replace(cfg.quant, w_bits=None, kv_bits=8)
    pool = PagedKVPool(cfg, n_blocks=N_BLOCKS, block_size=BLOCK_SIZE,
                       quant=kv8)
    return pool, Scheduler(pool, max_len=MAX_LEN, max_batch=MAX_BATCH,
                           chunk_tokens=chunk)


def simulate(chunk) -> dict:
    """One serving run: ``chunk=None`` = whole-prompt baseline."""
    pool, sch = _build(chunk)
    rng = np.random.default_rng(0)
    decodes = [_Req(rng.integers(0, 99, DECODE_PROMPT).astype(np.int32),
                    DECODE_NEW) for _ in range(DECODE_REQS)]
    arrivals = [_Req(rng.integers(0, 99, ln).astype(np.int32), ARRIVAL_NEW)
                for _, ln in ARRIVALS]
    for r in decodes:
        sch.submit(r)

    stall_this_step = [0]
    # independent tally of the scheduler's canonical stall rule, to
    # assert the repro_sched_stall_* registry counters agree exactly
    hand = dict(tokens=0, steps=0, call=0)

    def whole_prefill(seq, tokens):
        stall_this_step[0] += len(tokens) - seq.cached_len
        # suffix tokens prefilled while >= 1 admitted decode is live
        # (seq itself is not in sch.running yet at this point)
        if any(not s.prefilling for s in sch.running):
            hand["call"] += len(tokens) - seq.cached_len
        seq.length = len(tokens)
        seq.last_tok = 1
        if not seq.req.out:
            seq.req.out.append(1)

    def advance(seq):
        seq.req.out.append(1)
        seq.length += 1
        if len(seq.req.out) >= seq.req.max_new_tokens \
                or seq.length >= sch.max_len - 1:
            sch.finish(seq)

    stalls, decode_steps, gate_blocks = [], 0, []
    step = 0
    while sch.has_work or any(not r.done for r in arrivals):
        for (at, _), req in zip(ARRIVALS, arrivals):
            if at == step:
                gate_blocks.append(sch.lifetime_need(
                    len(req.prompt) + req.max_new_tokens))
                sch.submit(req)
        stall_this_step[0] = 0
        if chunk is None:
            hand["call"] = 0
            sch.admit(whole_prefill)     # the whole prompt lands here
            if hand["call"]:             # one stall step per admit() call
                hand["tokens"] += hand["call"]
                hand["steps"] += 1
            if sch.running:
                sch.ensure_append_capacity()
                for s in list(sch.running):
                    advance(s)
        else:
            sch.admit_chunked()
            plan = sch.ensure_step_capacity(sch.plan_step())
            # canonical rule: prompt tokens in the FINAL plan, counted
            # when the plan also carries at least one decode
            pre = sum(n for s, n in plan if s.prefilling)
            if pre and any(not s.prefilling for s, _ in plan):
                hand["tokens"] += pre
                hand["steps"] += 1
            for s, n in plan:
                if s.prefilling:
                    stall_this_step[0] += n
                    s.length += n
                    sch.register_progress(s)
                    if s.length >= len(s.pending):
                        s.pending = None
                        s.last_tok = 1
                        s.req.out.append(1)
                        if len(s.req.out) >= s.req.max_new_tokens:
                            sch.finish(s)
                else:
                    advance(s)
        if any(not s.prefilling for s in sch.running):
            decode_steps += 1
            if stall_this_step[0]:
                stalls.append(stall_this_step[0])
        step += 1
        assert step < 5000, "simulation did not drain"

    assert all(r.done and r.error is None for r in decodes + arrivals), \
        "workload must complete in both modes"
    assert pool.free_blocks == pool.n_usable
    # the longest prompt the submit gate admits at all (+new budget)
    servable = max((ln for ln in range(1, MAX_LEN - 1)
                    if sch.lifetime_need(ln + ARRIVAL_NEW)
                    <= pool.n_usable), default=0)
    # the registry's stall counters must equal the hand tally of the
    # same rule -- ISSUE 7's telemetry-agreement gate
    stall_tokens_total = int(pool.metrics.value("repro_sched_stall_tokens"))
    stall_steps = int(pool.metrics.value("repro_sched_stall_steps"))
    assert stall_tokens_total == hand["tokens"], \
        (stall_tokens_total, hand["tokens"])
    assert stall_steps == hand["steps"], (stall_steps, hand["steps"])
    stalls = stalls or [0]
    return dict(
        chunk_tokens=chunk,
        steps=step,
        p50_stall_tokens=float(np.percentile(stalls, 50)),
        p95_stall_tokens=float(np.percentile(stalls, 95)),
        max_stall_tokens=int(max(stalls)),
        stall_free_frac=1.0 - len(stalls) / max(decode_steps, 1),
        admission_gate_blocks=gate_blocks,
        max_servable_prompt=servable,
        preemptions=sch.n_preemptions,
        window_reclaimed=pool.report()["window_reclaimed"],
        stall_tokens_total=stall_tokens_total,
        stall_steps=stall_steps,
    )


def table(whole: dict, chunked: dict) -> str:
    hdr = ("| mode | p50 stall | p95 stall | max | stall-free | "
           "gate blocks | servable |\n|---|---|---|---|---|---|---|\n")
    out = []
    for r in (whole, chunked):
        mode = ("whole-prompt" if r["chunk_tokens"] is None
                else f"chunked({r['chunk_tokens']})")
        out.append(
            f"| {mode} | {r['p50_stall_tokens']:.0f} | "
            f"{r['p95_stall_tokens']:.0f} | {r['max_stall_tokens']} | "
            f"{r['stall_free_frac']*100:.0f}% | "
            f"{max(r['admission_gate_blocks'])} | "
            f"{r['max_servable_prompt']} |")
    return hdr + "\n".join(out) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_chunked_prefill.json")
    ap.add_argument("--chunk-tokens", type=int, default=CHUNK_TOKENS)
    args = ap.parse_args()
    whole = simulate(None)
    chunked = simulate(args.chunk_tokens)
    result = dict(
        workload=dict(window=WINDOW, block_size=BLOCK_SIZE,
                      max_len=MAX_LEN, n_blocks=N_BLOCKS,
                      decode_requests=DECODE_REQS,
                      decode_new_tokens=DECODE_NEW,
                      arrivals=[dict(step=at, prompt_len=ln)
                                for at, ln in ARRIVALS]),
        whole_prompt=whole, chunked=chunked)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(table(whole, chunked))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
