"""Perf harness for the grouped bipolar-INT MoE expert kernel.

Quantifies what :func:`repro.kernels.ops.ap_moe_expert_linear` (ISSUE 8
tentpole) buys over the batched-over-E baseline -- one fused-APMM
launch per (expert, projection), the pre-rewire kernel plan -- for one
MoE layer's expert FFN (gate/up dual GEMM + down projection):

* **kernel launches** -- ``pallas_call`` census of the traced
  ``interpret``-impl jaxpr (the same kernel graph the TPU path lowers).
  Grouped = 2 per layer (one dual gate/up launch + one down launch for
  ALL experts); batched-over-E = 2E (every expert re-launches, and
  every launch re-reads its activation rows even when the expert
  received no tokens).
* **HBM bytes** -- loop-aware HLO traffic (:mod:`benchmarks.
  hlo_analysis`) of the compiled ``reference``-impl dataflows: the
  grouped op quantizes the activation block once per projection pair
  and streams it against every expert's weights; the per-expert loop
  re-materializes per-expert intermediates E times.
* **skipped capacity tiles** -- on a decode-shaped dispatch (few live
  tokens, top-k routing) most (expert, group) capacity segments are
  empty; the kernel's scalar-prefetched counts let ``pl.when`` skip
  the quantize prologue and every MXU pass of those tiles.  Reported
  as the live-tile map's skipped fraction (kernel-reported, interpret
  impl -- the parity suite proves it equals the analytic map).
* **decode tokens/s** (full mode only, ungated) -- mixtral-8x7b smoke
  greedy decode through the real engine with ``layers.GROUPED_MOE``
  on vs off; CPU wall clock of the jnp reference dataflow, a proxy
  with no launch overhead to save -- not a kernel wall clock.

Results go to ``BENCH_moe.json``; the CI ``bench-smoke`` job gates the
launch-count and HBM-byte ratios and the skipped-tile fraction per PR.

Usage:  PYTHONPATH=src:. python -m benchmarks.moe_bench \
            [--out BENCH_moe.json] [--smoke]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import hlo_analysis
from benchmarks.apmm_bench import kernel_launches
from repro.kernels import ops
from repro.models.config import QuantConfig
from repro.models.model import _quantize_leaf

W_BITS, A_BITS = 2, 8            # the mixtral serving point (W2A8)

# (E, seg, d_model, d_ff) for one MoE layer's expert FFN
FULL_SHAPE = dict(e=8, seg=64, k=512, f=1024)
SMOKE_SHAPE = dict(e=4, seg=16, k=64, f=128)


def _pack3d(w: np.ndarray):
    return _quantize_leaf(jnp.asarray(w, jnp.float32),
                          QuantConfig(w_bits=W_BITS), stacked=False)


def _operands(e, seg, k, f, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((e, seg, k)), jnp.bfloat16)
    wg = rng.standard_normal((e, f, k)) / np.sqrt(k)
    wu = rng.standard_normal((e, f, k)) / np.sqrt(k)
    wd = rng.standard_normal((e, k, f)) / np.sqrt(f)
    counts = jnp.asarray(rng.integers(0, seg + 1, (e, 1)), jnp.int32)
    return x, wg, wu, wd, counts


def bench_expert_ffn(e, seg, k, f, *, smoke: bool) -> dict:
    x, wg, wu, wd, counts = _operands(e, seg, k, f)
    g3, u3, d3 = _pack3d(wg), _pack3d(wu), _pack3d(wd)
    # per-expert 2D tensors for the batched-over-E baseline (same
    # quantizer, so both plans multiply identical packed weights)
    g2 = [ops.pack_weight(jnp.asarray(wg[i], jnp.float32), W_BITS,
                          impl="reference") for i in range(e)]
    u2 = [ops.pack_weight(jnp.asarray(wu[i], jnp.float32), W_BITS,
                          impl="reference") for i in range(e)]
    d2 = [ops.pack_weight(jnp.asarray(wd[i], jnp.float32), W_BITS,
                          impl="reference") for i in range(e)]

    def grouped(impl):
        def fn(x):
            h = ops.ap_moe_expert_linear(
                x, g3, w2=u3, counts=counts, a_bits=A_BITS, act="silu",
                impl=impl)
            return ops.ap_moe_expert_linear(
                h, d3, counts=counts, a_bits=A_BITS, impl=impl)
        return fn

    def batched(impl):
        def fn(x):
            outs = []
            for i in range(e):
                h = ops.ap_linear_fused(
                    x[i], g2[i], w2=u2[i], a_bits=A_BITS, act="silu",
                    impl=impl)
                outs.append(ops.ap_linear_fused(
                    h, d2[i], a_bits=A_BITS, impl=impl))
            return jnp.stack(outs)
        return fn

    def hlo_bytes(fn):
        comp = jax.jit(fn).lower(x).compile()
        return float(hlo_analysis.analyze(comp.as_text())["bytes"])

    rec = dict(
        e=e, seg=seg, k=k, f=f, w_bits=W_BITS, a_bits=A_BITS,
        launches=dict(grouped=kernel_launches(grouped("interpret"), x),
                      batched=kernel_launches(batched("interpret"), x)),
        hlo_bytes=dict(grouped=hlo_bytes(grouped("reference")),
                       batched=hlo_bytes(batched("reference"))),
    )
    if not smoke:
        rec["us"] = dict(
            grouped=_time_call(jax.jit(grouped("reference")), x),
            batched=_time_call(jax.jit(batched("reference")), x))
    for key in [k_ for k_ in ("launches", "hlo_bytes", "us") if k_ in rec]:
        b, g = rec[key]["batched"], rec[key]["grouped"]
        rec[key]["grouped_over_batched"] = (g / b) if b else None
    return rec


def bench_skipped_tiles(e=8, tokens=2, top_k=2, k=64, f=128,
                        seed=1) -> dict:
    """Decode-shaped dispatch: ``tokens`` live tokens, top-k routing,
    capacity clamped to tokens*top_k rows (the satellite-1 clamp) --
    the kernel must skip every capacity tile of an expert that drew
    no token this step."""
    rng = np.random.default_rng(seed)
    cap = tokens * top_k
    # simulated router draw: top_k distinct experts per token
    load = np.zeros(e, np.int64)
    for _ in range(tokens):
        for ei in rng.choice(e, top_k, replace=False):
            load[ei] += 1
    counts = jnp.asarray(load.reshape(e, 1), jnp.int32)
    x = jnp.asarray(rng.standard_normal((e, cap, k)), jnp.bfloat16)
    w = _pack3d(rng.standard_normal((e, f, k)) / np.sqrt(k))
    _, live = ops.ap_moe_expert_linear(
        x, w, counts=counts, a_bits=A_BITS, impl="interpret",
        with_stats=True)
    live = np.asarray(live)
    return dict(e=e, tokens=tokens, top_k=top_k, capacity_rows=cap,
                live_tiles=int(live.sum()), total_tiles=int(live.size),
                skipped_fraction=float(1.0 - live.sum() / live.size))


def _time_call(fn, *args, reps: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def bench_decode_tokens_s() -> dict:
    """mixtral-8x7b smoke greedy decode, GROUPED_MOE on vs off (the
    jit cache must be dropped across the flip: the flag is read at
    trace time).  Ungated -- a CPU dataflow proxy, not kernel time."""
    import dataclasses

    from repro.configs import get_config
    from repro.models import layers as L
    from repro.models import model as M
    from repro.serving import engine as E

    cfg = get_config("mixtral-8x7b").reduced(n_layers=2)
    qcfg = dataclasses.replace(cfg.quant, kv_bits=8)
    params = M.quantize_params(M.init_params(cfg, jax.random.PRNGKey(1)),
                               qcfg)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, (6,), dtype=np.int32)
               for _ in range(3)]

    def run():
        eng = E.Engine(params, cfg, n_slots=2, max_len=32, quant=qcfg,
                       paged=True, block_size=8)
        reqs = [E.Request(prompt=p.copy(), max_new_tokens=8)
                for p in prompts]
        for r in reqs:
            eng.submit(r)
        t0 = time.perf_counter()
        eng.run()
        dt = time.perf_counter() - t0
        toks = sum(len(r.out) for r in reqs)
        assert all(r.done and r.error is None for r in reqs)
        return toks, dt

    out, old = {}, L.GROUPED_MOE
    try:
        for label, flag in (("grouped", True), ("legacy", False)):
            L.GROUPED_MOE = flag
            jax.clear_caches()
            run()                      # warm the jit caches
            toks, dt = run()
            out[f"{label}_tok_s"] = toks / dt
    finally:
        L.GROUPED_MOE = old
        jax.clear_caches()
    out["grouped_over_legacy"] = out["grouped_tok_s"] / out["legacy_tok_s"]
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_moe.json")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)
    shape = SMOKE_SHAPE if args.smoke else FULL_SHAPE
    ffn = bench_expert_ffn(**shape, smoke=args.smoke)
    print(f"expert ffn (E={ffn['e']}): launches "
          f"{ffn['launches']['batched']}->{ffn['launches']['grouped']}, "
          f"hlo bytes {ffn['hlo_bytes']['batched']:.3g}->"
          f"{ffn['hlo_bytes']['grouped']:.3g} "
          f"({ffn['hlo_bytes']['grouped_over_batched']:.3f}x)")
    tiles = bench_skipped_tiles()
    print(f"decode dispatch: {tiles['live_tiles']}/{tiles['total_tiles']} "
          f"tiles live, {tiles['skipped_fraction']:.2f} skipped")
    out = dict(
        meta=dict(smoke=bool(args.smoke), w_bits=W_BITS, a_bits=A_BITS,
                  note="launches: pallas_call census of the traced "
                       "interpret-impl kernel graph (grouped = one "
                       "dual gate/up launch + one down launch for all "
                       "experts; batched = 2 per expert); hlo_bytes: "
                       "loop-aware traffic of the compiled reference "
                       "dataflow on this host; skipped_fraction: "
                       "kernel-reported live-tile map on a decode-"
                       "shaped top-k dispatch; decode tok/s: CPU "
                       "reference-dataflow PROXY with no launch "
                       "overhead to save -- not a kernel wall clock"),
        expert_ffn=ffn,
        skipped_tiles=tiles,
    )
    if not args.smoke:
        out["decode"] = bench_decode_tokens_s()
        print("decode tok/s:", out["decode"])
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")
    return out


if __name__ == "__main__":
    main()
