"""GEMM perf harness for the one-kernel fused quantized linear.

Quantifies what :func:`repro.kernels.ops.ap_linear_fused` (ISSUE 4
tentpole) buys over the unfused quantize-pack-launch -> ap_matmul-launch
baseline, per decode-step linear:

* **kernel launches** -- counted by walking the traced jaxpr for
  ``pallas_call`` equations (``impl="interpret"`` traces the same kernel
  graph the TPU path lowers).  Unfused = 2 per linear (pack + GEMM);
  fused = 1; SwiGLU's gate+up collapse 4 -> 1 via the dual-GEMM mode.
* **HBM bytes** -- two views:
  - ``hlo_bytes``: the loop-aware HLO traffic estimate
    (:mod:`benchmarks.hlo_analysis`) of the compiled ``reference``-impl
    graph, fused vs unfused -- a real compiler-measured number on this
    host: the fused dataflow never materializes packed activation
    planes, the unfused one writes and re-reads them.
  - ``analytic_bytes``: the Pallas-kernel tile-streaming model (what the
    TPU kernel moves): unfused pays ``x read + plane write + plane read
    x n_j-tiles``; fused reads the float activations once per M tile
    (whole-K row block, re-fetched only when the M index changes).
* **wall clock** -- CPU wall time of the jitted ``reference`` dataflow
  (numerically identical to the kernels; interpret-mode Pallas is
  excluded from timing as it measures the Python interpreter).

Results go to ``BENCH_apmm.json``.  ``--smoke`` shrinks the shapes and
skips timing so the CI job finishes in seconds while still exercising
the full accounting path.

Usage:  PYTHONPATH=src:. python -m benchmarks.apmm_bench \
            [--out BENCH_apmm.json] [--smoke]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import hlo_analysis
from repro.kernels import apmm, ops
from repro.core import bipolar

# decode-step linears of a llama3-8b-shaped layer (M = decode batch)
FULL_SHAPES = [
    ("attn_qkv_o", 16, 4096, 4096),
    ("mlp_gate_up", 16, 14336, 4096),
    ("mlp_down", 16, 4096, 14336),
]
SMOKE_SHAPES = [
    ("attn_qkv_o", 8, 256, 256),
    ("mlp_gate_up", 8, 512, 256),
    ("mlp_down", 8, 256, 512),
]
W_BITS, A_BITS = 4, 8


# ---------------------------------------------------------------------------
# Kernel-launch census (jaxpr walk)
# ---------------------------------------------------------------------------

def _count_pallas_calls(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for v in eqn.params.values():
            vs = v if isinstance(v, (tuple, list)) else (v,)
            for u in vs:
                inner = getattr(u, "jaxpr", u)
                if type(inner).__name__ == "Jaxpr":
                    n += _count_pallas_calls(inner)
    return n


def kernel_launches(fn, *args) -> int:
    """Number of Pallas kernel launches in one call of ``fn``."""
    return _count_pallas_calls(jax.make_jaxpr(fn)(*args).jaxpr)


# ---------------------------------------------------------------------------
# HBM traffic
# ---------------------------------------------------------------------------

def hlo_bytes(fn, *args) -> float:
    """Loop-aware HLO traffic of the compiled graph (reference impl)."""
    comp = jax.jit(fn).lower(*args).compile()
    return float(hlo_analysis.analyze(comp.as_text())["bytes"])


def analytic_bytes(m: int, n: int, k: int, *, fused: bool,
                   n_weights: int = 1, x_bytes: int = 2,
                   out_bytes: int = 2) -> int:
    """Tile-streaming HBM model of the Pallas kernels (per linear).

    Both paths stream the packed weight once per M tile and write the
    output once; they differ on the activation side:

    * unfused: x read (pack kernel) + packed-plane write + packed-plane
      read once per N tile (A block index depends on the N grid dim);
    * fused: x read once per M tile (whole-K row block) -- the packed
      activation planes never exist in HBM.
    """
    bm = min(apmm.DEFAULT_BM, m)
    bn = min(apmm.DEFAULT_BN, n)
    n_i = -(-m // bm)
    n_j = -(-n // bn)
    kw = bipolar.packed_words(k)
    w_packed = n_weights * W_BITS * n * kw * 4
    a_planes = A_BITS * m * kw * 4
    total = n_i * w_packed + m * n * n_weights * out_bytes
    if fused:
        total += m * k * x_bytes
    else:
        # per weight operand: its own pack launch + plane stream
        total += n_weights * (m * k * x_bytes + a_planes + n_j * a_planes)
    return int(total)


# ---------------------------------------------------------------------------
# Workloads
# ---------------------------------------------------------------------------

def _time_call(fn, *args, reps: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def _operands(m, n, k, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.bfloat16)
    w = ops.pack_weight(jnp.asarray(rng.standard_normal((n, k)),
                                    jnp.float32), W_BITS, impl="reference")
    w2 = ops.pack_weight(jnp.asarray(rng.standard_normal((n, k)),
                                     jnp.float32), W_BITS, impl="reference")
    return x, w, w2


def bench_linear(name, m, n, k, *, dual: bool, smoke: bool) -> dict:
    x, w, w2 = _operands(m, n, k)

    def unfused(impl):
        def f(x):
            y = ops.ap_linear(x, w, a_bits=A_BITS, impl=impl)
            if dual:
                y2 = ops.ap_linear(x, w2, a_bits=A_BITS, impl=impl)
                y = (jax.nn.silu(y.astype(jnp.float32))
                     * y2.astype(jnp.float32)).astype(x.dtype)
            return y
        return f

    def fused(impl):
        def f(x):
            return ops.ap_linear_fused(
                x, w, w2=w2 if dual else None, a_bits=A_BITS,
                act="silu" if dual else "none", impl=impl)
        return f

    rec = dict(
        name=name, m=m, n=n, k=k, w_bits=W_BITS, a_bits=A_BITS, dual=dual,
        launches=dict(unfused=kernel_launches(unfused("interpret"), x),
                      fused=kernel_launches(fused("interpret"), x)),
        hlo_bytes=dict(unfused=hlo_bytes(unfused("reference"), x),
                       fused=hlo_bytes(fused("reference"), x)),
        analytic_bytes=dict(
            unfused=analytic_bytes(m, n, k, fused=False,
                                   n_weights=2 if dual else 1),
            fused=analytic_bytes(m, n, k, fused=True,
                                 n_weights=2 if dual else 1)),
    )
    if not smoke:
        rec["us"] = dict(
            unfused=_time_call(jax.jit(unfused("reference")), x, reps=3),
            fused=_time_call(jax.jit(fused("reference")), x, reps=3))
    for key in ("launches", "hlo_bytes", "analytic_bytes"):
        u, f = rec[key]["unfused"], rec[key]["fused"]
        rec[key]["fused_over_unfused"] = (f / u) if u else None
    return rec


def decode_layer_summary(linears) -> dict:
    """Per-decode-step launch budget of one dense SwiGLU layer:
    q, k, v, o projections + gate/up (dual) + down."""
    by = {r["name"]: r for r in linears}
    unf = 4 * by["attn_qkv_o"]["launches"]["unfused"] \
        + by["mlp_gate_up"]["launches"]["unfused"] \
        + by["mlp_down"]["launches"]["unfused"]
    fus = 4 * by["attn_qkv_o"]["launches"]["fused"] \
        + by["mlp_gate_up"]["launches"]["fused"] \
        + by["mlp_down"]["launches"]["fused"]
    return dict(launches_unfused=unf, launches_fused=fus,
                fused_over_unfused=fus / unf)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_apmm.json")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)
    shapes = SMOKE_SHAPES if args.smoke else FULL_SHAPES
    linears = []
    for name, m, n, k in shapes:
        rec = bench_linear(name, m, n, k, dual=(name == "mlp_gate_up"),
                           smoke=args.smoke)
        linears.append(rec)
        print(f"{name}: launches {rec['launches']['unfused']}->"
              f"{rec['launches']['fused']}, hlo bytes "
              f"{rec['hlo_bytes']['unfused']:.3g}->"
              f"{rec['hlo_bytes']['fused']:.3g} "
              f"({rec['hlo_bytes']['fused_over_unfused']:.3f}x), "
              f"analytic {rec['analytic_bytes']['unfused']:.3g}->"
              f"{rec['analytic_bytes']['fused']:.3g} "
              f"({rec['analytic_bytes']['fused_over_unfused']:.3f}x)")
    out = dict(
        meta=dict(smoke=bool(args.smoke), w_bits=W_BITS, a_bits=A_BITS,
                  x_dtype="bfloat16",
                  note="launches: pallas_call census of the traced "
                       "kernel graph; hlo_bytes: loop-aware traffic of "
                       "the compiled reference dataflow on this host "
                       "(weight-unpack dominated at decode M -- the "
                       "fused delta is the packed-activation round "
                       "trip); analytic_bytes: Pallas tile-streaming "
                       "model of what the TPU kernels move; us: CPU "
                       "wall time of the jnp reference PROXY (shares "
                       "the in-graph weight unpack both ways and has "
                       "no kernel-launch overhead to save -- not a "
                       "kernel wall clock)"),
        linears=linears,
        decode_layer=decode_layer_summary(linears),
    )
    print("decode layer:", out["decode_layer"])
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    return out


if __name__ == "__main__":
    main()
