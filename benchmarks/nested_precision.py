"""Nested-precision serving economics (ISSUE 10 tentpole).

Two questions, answered with numbers:

* **What does a narrower lane actually save?**  HBM traffic of the
  fused quantized linear (:func:`repro.kernels.ops.ap_linear_fused`,
  decode shape) when serving the top-k plane slice of an 8-bit nested
  checkpoint, measured two ways:

  - ``hlo_bytes``: loop-aware traffic estimate
    (:mod:`benchmarks.hlo_analysis`) of the compiled ``reference``-impl
    graph with the slice taken before the jit boundary -- exactly what
    the TPU kernel's BlockSpec does: the index map streams only the k
    kept planes, the dropped planes are never fetched;
  - ``weight_arg_bytes``: the packed-plane argument footprint itself
    (``k x ceil(K/32) x N x 4`` bytes), the analytic floor of the
    weight stream.

  The fused decode linear is weight-bound at decode M, so k=4 must
  read <= 0.55x the bytes of k=8 (the CI gate; 0.5x is the plane-count
  floor, the slack is the width-independent activation/output term).

* **What does the tier policy buy under overload?**  A deterministic
  discrete-event model of the serving loop at 2x sustained overload:
  requests arrive at half the 8-bit service interval, per-token decode
  cost proportional to granted bits (the weight-stream bound above),
  grants frozen at admission by :func:`repro.serving.engine.tier_bits`
  with a floor.  Reported: makespan, throughput ratio vs a fixed-8-bit
  run, mean granted bits, grant histogram, peak queue depth -- the
  policy sheds precision instead of latency, then recovers to full
  width as the queue drains (the last grants are 8-bit again).

Results go to ``BENCH_nested_precision.json``.  ``--smoke`` shrinks
the GEMM and the arrival count so the CI job finishes in seconds.

Usage:  PYTHONPATH=src:. python -m benchmarks.nested_precision \
            [--out BENCH_nested_precision.json] [--smoke]
"""

from __future__ import annotations

import argparse
import heapq
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import hlo_analysis
from repro.core import bipolar
from repro.kernels import ops
from repro.serving.engine import tier_bits

MAX_BITS, A_BITS = 8, 8
WIDTHS = (2, 4, 8)


# ---------------------------------------------------------------------------
# Weight-stream savings of a sliced lane
# ---------------------------------------------------------------------------

def _nested_operands(m, n, k, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.bfloat16)
    w = ops.quantize_rows(jnp.asarray(rng.standard_normal((n, k)),
                                      jnp.float32), MAX_BITS, pad_bit=1,
                          scale_search=True, impl="reference")
    return x, w


def bench_sliced_linear(m, n, k) -> dict:
    """Fused-linear HBM traffic per served width of ONE checkpoint."""
    x, w_full = _nested_operands(m, n, k)

    def fused(xx, ww):
        return ops.ap_linear_fused(xx, ww, a_bits=A_BITS,
                                   impl="reference")

    widths = {}
    for kbits in WIDTHS:
        wk = bipolar.nested_slice(w_full, kbits)
        comp = jax.jit(fused).lower(x, wk).compile()
        widths[str(kbits)] = dict(
            hlo_bytes=float(hlo_analysis.analyze(comp.as_text())["bytes"]),
            weight_arg_bytes=int(wk.packed.size * wk.packed.dtype.itemsize),
        )
    base = widths[str(MAX_BITS)]
    for rec in widths.values():
        rec["hlo_over_full"] = rec["hlo_bytes"] / base["hlo_bytes"]
        rec["weight_over_full"] = (rec["weight_arg_bytes"]
                                   / base["weight_arg_bytes"])
    return dict(m=m, n=n, k=k, a_bits=A_BITS, stored_bits=MAX_BITS,
                widths=widths)


# ---------------------------------------------------------------------------
# Tier policy under sustained overload
# ---------------------------------------------------------------------------

def simulate_overload(n_reqs: int, *, floor, overload: float = 2.0,
                      tokens_per_req: int = 32, pressure: int = 4) -> dict:
    """Discrete-event serving model: one decode lane, per-token cost
    proportional to granted bits (weight-stream bound), grants frozen
    at admission.  ``floor=None`` degenerates to fixed-8-bit serving."""
    unit = 1.0 / MAX_BITS                # time per token per bit
    svc8 = tokens_per_req * MAX_BITS * unit
    interval = svc8 / overload
    arrivals = [i * interval for i in range(n_reqs)]
    queue: list = []
    grants, depths = [], []
    t, i, done = 0.0, 0, 0
    while done < n_reqs:
        while i < n_reqs and arrivals[i] <= t:
            heapq.heappush(queue, (arrivals[i], i))
            i += 1
        if not queue:
            t = arrivals[i]
            continue
        _, req = heapq.heappop(queue)
        depth = len(queue)
        bits = tier_bits(None, max_bits=MAX_BITS, floor=floor,
                         queue_depth=depth, pressure=pressure)
        grants.append(bits)
        depths.append(depth)
        t += tokens_per_req * bits * unit
        done += 1
    hist = {str(b): grants.count(b) for b in sorted(set(grants))}
    return dict(n_reqs=n_reqs, overload=overload, floor=floor,
                makespan=t, throughput=n_reqs / t,
                mean_bits=float(np.mean(grants)), grant_hist=hist,
                peak_queue_depth=max(depths), final_grant=grants[-1])


def bench_tier_policy(n_reqs: int) -> dict:
    tiered = simulate_overload(n_reqs, floor=4)
    fixed = simulate_overload(n_reqs, floor=None)
    return dict(
        tiered=tiered, fixed_8bit=fixed,
        throughput_gain=tiered["throughput"] / fixed["throughput"],
        queue_depth_ratio=(tiered["peak_queue_depth"]
                           / max(fixed["peak_queue_depth"], 1)),
    )


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_nested_precision.json")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)
    m, n, k = (4, 256, 256) if args.smoke else (4, 1024, 1024)
    linear = bench_sliced_linear(m, n, k)
    for kb, rec in sorted(linear["widths"].items(), key=lambda kv: -int(kv[0])):
        print(f"k={kb}: hlo {rec['hlo_bytes']:.3g} "
              f"({rec['hlo_over_full']:.3f}x), weight arg "
              f"{rec['weight_arg_bytes']} ({rec['weight_over_full']:.3f}x)")
    policy = bench_tier_policy(24 if args.smoke else 256)
    print(f"2x overload: tiered {policy['tiered']['throughput']:.3f} req/u "
          f"(mean {policy['tiered']['mean_bits']:.2f} bits, grants "
          f"{policy['tiered']['grant_hist']}) vs fixed "
          f"{policy['fixed_8bit']['throughput']:.3f} -> "
          f"{policy['throughput_gain']:.3f}x, final grant back to "
          f"{policy['tiered']['final_grant']} bits")
    out = dict(
        meta=dict(smoke=bool(args.smoke), stored_bits=MAX_BITS,
                  a_bits=A_BITS,
                  note="hlo_bytes: loop-aware traffic of the compiled "
                       "reference fused linear with the plane slice "
                       "taken before jit (what the TPU BlockSpec "
                       "streams); weight_arg_bytes: packed-plane "
                       "argument footprint; overload sim: per-token "
                       "cost proportional to granted bits, grants from "
                       "engine.tier_bits frozen at admission"),
        fused_linear=linear,
        overload_2x=policy,
    )
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    return out


if __name__ == "__main__":
    main()
