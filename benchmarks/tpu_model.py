"""v5e roofline model used to *derive* TPU latencies on this CPU-only host.

Hardware constants (task spec): 197 TFLOP/s bf16 per chip (394 TOPS int8,
f32 modeled at 1/4 bf16), 819 GB/s HBM, ~50 GB/s/link ICI.

``gemm_time`` returns the roofline execution-time estimate for one GEMM
under a quantization scheme: compute term = MXU passes / peak, memory
term = exact operand/result bytes at the scheme's stored precision / HBM
bandwidth (this is where the paper's §4.1 bit-packed layout pays off --
an n-bit operand moves exactly n/16 of its bf16 bytes).

Scheme semantics on TPU (DESIGN.md §2):
* ``fused``      -- ceil(n_w/7) * ceil(n_x/7) int8 MXU passes (operand-
  level recovery; 1 pass for everything the paper evaluates).
* ``bitserial``  -- n_w * n_x int8 MXU passes (paper-faithful §3.2
  dataflow; on GPU these are 1-bit TC ops, the TPU has no 1-bit MXU).
"""

from __future__ import annotations

import dataclasses
import math

PEAK_FLOPS = {"f32": 197e12 / 4, "bf16": 197e12, "f16": 197e12,
              "int8": 394e12}
HBM_BW = 819e9
LINK_BW = 50e9
CHIPS_PER_POD = 256
VMEM_BYTES = 128 * 2**20
HBM_BYTES = 16 * 2**30


@dataclasses.dataclass(frozen=True)
class Scheme:
    name: str
    w_bits: float            # stored bits per weight element
    a_bits: float            # stored bits per activation element
    mxu: str                 # which MXU pipe the math runs on
    passes: int = 1          # MXU passes per GEMM (bit-serial > 1)


def fused_passes(w_bits: int, a_bits: int) -> int:
    return math.ceil(w_bits / 7) * math.ceil(a_bits / 7)


def scheme(name: str, variant: str = "fused") -> Scheme:
    """Parse 'FP32' | 'BF16' | 'INT8' | 'INT4' | 'W{n}A{m}'."""
    n = name.upper()
    if n == "FP32":
        return Scheme(name, 32, 32, "f32")
    if n in ("FP16", "BF16"):
        return Scheme(name, 16, 16, "bf16")
    if n == "INT8":
        return Scheme(name, 8, 8, "int8")
    if n == "INT4":
        # TPU v5e has no int4 MXU pipe: int4 data, int8 math
        return Scheme(name, 4, 4, "int8")
    if n.startswith("W"):
        w, a = n[1:].split("A")
        w, a = int(w), int(a)
        passes = (w * a) if variant == "bitserial" else fused_passes(w, a)
        return Scheme(name + ("-bs" if variant == "bitserial" else ""),
                      w, a, "int8", passes)
    raise ValueError(name)


def gemm_time(m: int, n: int, k: int, sch: Scheme,
              out_bits: int = 16) -> dict:
    """Roofline times (s) for Y(m,n) = A(m,k) @ B(n,k)^T on ONE chip."""
    flops = 2.0 * m * n * k * sch.passes
    t_compute = flops / PEAK_FLOPS[sch.mxu]
    bytes_moved = (m * k * sch.a_bits / 8 + n * k * sch.w_bits / 8
                   + m * n * out_bits / 8)
    t_memory = bytes_moved / HBM_BW
    return {
        "t_compute": t_compute,
        "t_memory": t_memory,
        "t": max(t_compute, t_memory),
        "flops": flops,
        "bytes": bytes_moved,
        "bound": "compute" if t_compute >= t_memory else "memory",
    }


def tops(m: int, n: int, k: int, sch: Scheme) -> float:
    """Effective Tera-ops/s counting *useful* ops 2mnk (like the paper)."""
    return 2.0 * m * n * k / gemm_time(m, n, k, sch)["t"] / 1e12
