"""Roofline analysis over the dry-run JSONs (task spec deliverable g).

Per (arch x shape) on the single-pod mesh, derives the three terms:

    compute    = dot_flops_bf16/197T + dot_flops_int/394T + dot_flops_f32/49T
    memory     = HLO bytes / 819 GB/s
    collective = per-chip collective wire bytes / 50 GB/s/link

All inputs are PER-CHIP (the dry-run HLO is SPMD-partitioned, loop-trip
weighted -- benchmarks/hlo_analysis.py).  Also reports MODEL_FLOPS
(6*N*D train / 2*N_active*D inference, per chip), the useful-compute
ratio MODEL_FLOPS/HLO_dot_FLOPs, the dominant bottleneck, and a one-line
"what would move it" note.

Usage:  PYTHONPATH=src:. python -m benchmarks.roofline [--dir DIR] [--mesh pod256]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_BF16 = 197e12
PEAK_INT8 = 394e12
PEAK_F32 = 197e12 / 4
HBM_BW = 819e9
LINK_BW = 50e9


def load_cells(directory: str, mesh: str = "pod256"):
    cells = []
    for path in sorted(glob.glob(os.path.join(directory, f"*__{mesh}.json"))):
        cells.append(json.load(open(path)))
    return cells


def terms(rec: dict) -> dict:
    h = rec["hlo"]
    chips = rec["n_chips"]
    t_c = (h.get("dot_flops_bf16", 0) / PEAK_BF16
           + h.get("dot_flops_int", 0) / PEAK_INT8
           + h.get("dot_flops_f32", 0) / PEAK_F32)
    if t_c == 0 and h.get("dot_flops", 0):
        # JSONs from before the dtype split: attribute by mode
        t_c = h["dot_flops"] / (PEAK_BF16 if rec["mode"] == "train"
                                else PEAK_INT8)
    t_m = h["bytes"] / HBM_BW
    t_x = h["collective_bytes"] / LINK_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])
    # MoE: only routed top-k experts execute -- use active params
    n = rec["active_params"]
    tokens = rec["batch"] * (rec["seq"] if rec["mode"] in ("train", "prefill")
                             else 1)
    mult = 6 if rec["mode"] == "train" else 2
    model_flops = mult * n * tokens / chips           # per chip
    ratio = model_flops / max(h["dot_flops"], 1.0)
    t_model = model_flops / (PEAK_INT8 if rec["mode"] != "train"
                             else PEAK_BF16)
    frac = t_model / max(dom[1], 1e-12)
    return dict(t_compute=t_c, t_memory=t_m, t_collective=t_x,
                dominant=dom[0], t_dominant=dom[1],
                model_flops=model_flops, useful_ratio=ratio,
                roofline_frac=frac)


def suggestion(rec: dict, t: dict) -> str:
    if t["dominant"] == "collective":
        top = max(rec["hlo"].get("collectives", {"?": 0}).items(),
                  key=lambda kv: kv[1])
        return (f"cut {top[0]} volume ({top[1]/2**20:.0f} MiB/chip): "
                f"resharding or comm/compute overlap")
    if t["dominant"] == "memory":
        if rec["mode"] != "train":
            return ("decode is weight/KV-HBM-bound: lower W-bits "
                    "(packed planes) or shard KV wider")
        return "reduce remat traffic / recompute-vs-store balance"
    if t["useful_ratio"] < 0.5:
        return (f"only {t['useful_ratio']*100:.0f}% of compiled dot flops "
                f"are model flops -- kill redundant/remat compute")
    return "near compute roofline: overlap the residual collectives"


def fmt_s(x: float) -> str:
    return (f"{x*1e6:.0f}us" if x < 0.01 else
            f"{x*1e3:.1f}ms" if x < 1 else f"{x:.2f}s")


def table(cells, include_suggestion=True) -> str:
    hdr = ("| arch | shape | mode | status | compute | memory | collective "
           "| dominant | peak GiB/chip | MF/HLO | note |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for rec in cells:
        if rec["status"] == "skipped":
            rows.append(
                f"| {rec['arch']} | {rec['shape']} | {rec['mode']} | "
                f"skipped | - | - | - | - | - | - | {rec['reason'][:60]} |")
            continue
        if rec["status"] != "ok":
            rows.append(
                f"| {rec['arch']} | {rec['shape']} | {rec['mode']} | "
                f"FAILED | - | - | - | - | - | - | "
                f"{rec.get('error', '')[:60]} |")
            continue
        t = terms(rec)
        peak = rec["memory"]["peak_bytes"] / 2**30
        note = suggestion(rec, t) if include_suggestion else ""
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mode']} | ok | "
            f"{fmt_s(t['t_compute'])} | {fmt_s(t['t_memory'])} | "
            f"{fmt_s(t['t_collective'])} | **{t['dominant']}** | "
            f"{peak:.2f} | {t['useful_ratio']:.2f} | {note} |")
    return hdr + "\n".join(rows) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="/root/repo/experiments/dryrun")
    ap.add_argument("--mesh", default="pod256")
    args = ap.parse_args()
    cells = load_cells(args.dir, args.mesh)
    print(table(cells))
    ok = [c for c in cells if c["status"] == "ok"]
    if ok:
        worst = min(ok, key=lambda c: terms(c)["roofline_frac"])
        coll = max(ok, key=lambda c: terms(c)["t_collective"]
                   / max(terms(c)["t_dominant"], 1e-12))
        print(f"\nworst roofline fraction: {worst['arch']}/{worst['shape']}")
        print(f"most collective-bound:  {coll['arch']}/{coll['shape']}")


if __name__ == "__main__":
    main()
