"""Prefix-cache benchmark: prefill compute saved and capacity gained.

Extends BENCH_paged_kv (capacity of the paged pool) to the ISSUE 3
refcounted copy-on-write prefix cache: when requests share a prompt
prefix -- a system prompt, a few-shot context -- the pool serves the
shared blocks from residency (acquire = refcount + 1) and the engine
prefills only the suffix.  That cuts the two resources prefill costs:

* **compute**: prefill FLOPs scale with the tokens actually pushed
  through the model.  Per token ~ ``2 * P`` MLP/projection FLOPs
  (P = non-embedding params) plus ``4 * d_model * T`` attention FLOPs
  against a context of T -- the attention term is where the shared
  prefix's quadratic cost would have gone;
* **memory**: shared blocks are resident ONCE, so steady-state
  concurrent requests at a fixed pool scale with unique-suffix bytes.

Per workload mix this script reports the analytic prefill-token /
FLOP / resident-block savings of N-way sharing, and cross-checks the
token accounting against the real ``Engine(paged=True)`` + scheduler +
``PagedKVPool`` on a reduced config (same acquire/register/COW code the
serving path runs, reference kernel impl on CPU).  Results go to
``BENCH_prefix_cache.json``.

Usage:  PYTHONPATH=src:. python -m benchmarks.prefix_cache_hit \
            [--out BENCH_prefix_cache.json] [--skip-empirical]
"""

from __future__ import annotations

import argparse
import dataclasses
import json

import numpy as np

# serving-shape reference arch for the analytic model (llama3-8b-like),
# matching benchmarks/paged_kv_capacity.py
N_LAYERS = 32
N_KV_HEADS = 8
N_HEADS = 32
HEAD_DIM = 128
D_MODEL = 4096
D_FF = 14336
VOCAB = 128256
BLOCK_SIZE = 16
KV_BITS = 8

# name -> (shared prefix tokens, unique suffix tokens, concurrent requests)
MIXES = {
    "shared_system_prompt": (512, 64, 32),   # chat: big system prompt
    "few_shot_8x": (1536, 48, 16),           # 8-shot context + question
    "light_sharing": (64, 256, 8),           # mostly-unique prompts
}


def _param_count() -> float:
    """Non-embedding params of the reference arch (per-token GEMM cost)."""
    attn = D_MODEL * (N_HEADS * HEAD_DIM) * 2 \
        + D_MODEL * (N_KV_HEADS * HEAD_DIM) * 2
    mlp = 3 * D_MODEL * D_FF
    return N_LAYERS * (attn + mlp) + D_MODEL * VOCAB


def prefill_flops(n_tokens: int, ctx_start: int = 0) -> float:
    """FLOPs to prefill ``n_tokens`` starting at context depth
    ``ctx_start``: 2*P per token for the GEMMs + the causal attention
    reads (each token t attends to ctx_start + local position)."""
    p = _param_count()
    gemm = 2.0 * p * n_tokens
    # sum_{i<n} 4 * d * (ctx_start + i) per layer-head-fold
    ctx_sum = n_tokens * ctx_start + n_tokens * (n_tokens - 1) / 2.0
    attn = 4.0 * D_MODEL * ctx_sum * N_LAYERS
    return gemm + attn


def blocks(n_tokens: int) -> int:
    return -(-n_tokens // BLOCK_SIZE)


def mix_stats(shared: int, unique: int, n_req: int) -> dict:
    """Analytic savings of N-way prefix sharing for one workload mix."""
    full_shared = (shared // BLOCK_SIZE) * BLOCK_SIZE  # whole blocks hit
    tail = shared - full_shared                         # recomputed w/ suffix
    total = shared + unique
    cold_tokens = n_req * total
    # request 1 computes everything; the rest prefill tail + unique
    warm_tokens = total + (n_req - 1) * (tail + unique)
    cold_flops = n_req * prefill_flops(total)
    warm_flops = prefill_flops(total) \
        + (n_req - 1) * prefill_flops(tail + unique, ctx_start=full_shared)
    # steady-state residency (every request decoding): shared full blocks
    # once + per-request tail/suffix blocks vs everything duplicated
    cold_blocks = n_req * blocks(total)
    warm_blocks = blocks(full_shared) + n_req * blocks(tail + unique)
    return dict(
        shared_tokens=shared, unique_tokens=unique, n_requests=n_req,
        shared_full_block_tokens=full_shared,
        prefill_tokens_cold=cold_tokens,
        prefill_tokens_warm=warm_tokens,
        prefill_token_savings=1.0 - warm_tokens / cold_tokens,
        prefill_flops_cold=cold_flops,
        prefill_flops_warm=warm_flops,
        prefill_flop_savings=1.0 - warm_flops / cold_flops,
        resident_blocks_cold=cold_blocks,
        resident_blocks_warm=warm_blocks,
        capacity_ratio=cold_blocks / warm_blocks,
    )


def empirical_crosscheck() -> dict:
    """Run the real paged engine on a reduced config: N requests over a
    shared prefix; the pool's hit accounting must match the analytic
    token model, and outputs must equal a prefix_cache=False run."""
    import jax
    from repro.configs import get_config
    from repro.models import model as M
    from repro.models.config import QuantConfig
    from repro.serving import engine as E

    cfg = get_config("llama3-8b").reduced(n_layers=2, d_head=32, vocab=256)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    kv8 = QuantConfig(kv_bits=8)
    rng = np.random.default_rng(0)
    shared, unique, n_req, bs = 24, 5, 4, 8
    prefix = rng.integers(0, cfg.vocab, (shared,), dtype=np.int32)
    prompts = [np.concatenate([
        prefix, rng.integers(0, cfg.vocab, (unique,), dtype=np.int32)
    ]).astype(np.int32) for _ in range(n_req)]

    def run(flag):
        eng = E.Engine(params, cfg, n_slots=4, max_len=64, quant=kv8,
                       paged=True, block_size=bs, max_batch=n_req,
                       prefix_cache=flag)
        reqs = [E.Request(prompt=p.copy(), max_new_tokens=4)
                for p in prompts]
        for r in reqs:
            eng.submit(r)
        eng.run()
        assert all(r.done and r.error is None for r in reqs)
        return [r.out for r in reqs], eng

    out_warm, eng_warm = run(True)
    out_cold, _ = run(False)
    assert out_warm == out_cold, "prefix cache changed tokens!"
    rep = eng_warm.report()
    full_shared = (shared // bs) * bs
    expect_hit = (n_req - 1) * full_shared
    total = n_req * (shared + unique)
    return dict(
        cfg="llama3-8b reduced(n_layers=2, d_head=32)",
        block_size=bs, shared_tokens=shared, unique_tokens=unique,
        n_requests=n_req,
        prompt_tokens_total=total,
        prefix_hit_tokens=int(rep["prefix_hit_tokens"]),
        prefix_hit_tokens_expected=int(expect_hit),
        prefix_hits=int(rep["prefix_hits"]),
        cow_copies=int(rep["cow_copies"]),
        prefill_token_savings=rep["prefix_hit_tokens"] / total,
        token_identical_to_cold=True,
        accounting_matches=bool(rep["prefix_hit_tokens"] == expect_hit),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_prefix_cache.json")
    ap.add_argument("--skip-empirical", action="store_true")
    args = ap.parse_args()

    result = {
        "arch": dict(n_layers=N_LAYERS, n_heads=N_HEADS,
                     n_kv_heads=N_KV_HEADS, head_dim=HEAD_DIM,
                     d_model=D_MODEL, d_ff=D_FF, vocab=VOCAB,
                     block_size=BLOCK_SIZE, kv_bits=KV_BITS),
        "mixes": {name: mix_stats(*spec) for name, spec in MIXES.items()},
    }
    if not args.skip_empirical:
        result["empirical"] = empirical_crosscheck()
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    for name, m in result["mixes"].items():
        print(f"{name:22s} token savings {m['prefill_token_savings']:.1%}  "
              f"flop savings {m['prefill_flop_savings']:.1%}  "
              f"capacity x{m['capacity_ratio']:.2f}")
    if "empirical" in result:
        e = result["empirical"]
        print(f"empirical: hit {e['prefix_hit_tokens']}/"
              f"{e['prompt_tokens_total']} prompt tokens "
              f"({e['prefill_token_savings']:.1%}), accounting "
              f"{'OK' if e['accounting_matches'] else 'MISMATCH'}, "
              f"tokens identical to cold run: "
              f"{e['token_identical_to_cold']}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
