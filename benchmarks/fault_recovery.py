"""Serving-hardening gates: fault recovery, shed rate, disabled cost.

ISSUE 9's acceptance surface, measured on the real reduced-model engine
(CPU interpret) and gated in CI's bench-smoke job:

* ``recovery_steps``: steps from a seeded memory-fault storm (alloc
  failures, forced evictions, admission races, preemption storms)
  until every request finishes -- bounded relative to the fault-free
  step count (faults delay, they must not wedge).  Token identity of
  the faulted run against the fault-free twin is asserted inline.
* ``shed_rate``: under a 2x overload against a bounded queue
  (``max_queue``), the fraction of requests shed with
  ``finish_reason='rejected'``.  Gated strictly inside (0, 1): some
  load must shed (the bound is real) and some must serve (shedding is
  not a blackout), and every shed carries a positive ``retry_after``.
* ``disabled_overhead_ratio``: min-of-repeats mean step time with the
  default ``NULL_FAULTS`` facade vs an *armed but all-zero*
  ``FaultInjector`` -- the armed-at-p=0 cost, a superset of the
  disabled cost.  Gated at the same loose CI-noise ceiling as
  BENCH_obs_overhead's enabled ratio (<= 1.5), plus
  ``token_identity_disabled`` (the facade must be invisible).
* ``watchdog_recovered``: a live block id smuggled onto the free list
  is caught by ``validate_every=1`` and repaired without changing any
  request's tokens.

Usage:  PYTHONPATH=src:. python -m benchmarks.fault_recovery \
            [--out BENCH_fault_recovery.json]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

PROMPTS = (5, 9, 14)
MAX_NEW = 8
REPEATS = 5
FAULT_SEED = 11
OVERLOAD = 8            # 2x the queue bound + lanes


def _build(*, faults=None, max_queue=None, validate_every=None,
           n_prompts=len(PROMPTS)):
    import jax
    from repro.configs import get_config
    from repro.models import model as M
    from repro.serving import engine as E

    cfg = get_config("mamba2-130m").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    kw = {}
    if faults is not None:
        kw["faults"] = faults
    if max_queue is not None:
        kw["max_queue"] = max_queue
    if validate_every is not None:
        kw["validate_every"] = validate_every
    eng = E.Engine(params, cfg, n_slots=2, max_len=32, paged=True,
                   block_size=4, chunk_tokens=3, **kw)
    rng = np.random.default_rng(3)
    sizes = [PROMPTS[i % len(PROMPTS)] for i in range(n_prompts)]
    reqs = [E.Request(prompt=rng.integers(0, cfg.vocab, (n,),
                                          dtype=np.int32),
                      max_new_tokens=MAX_NEW) for n in sizes]
    return eng, reqs


def _run(eng, reqs):
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run()
    return time.perf_counter() - t0


def bench_recovery() -> dict:
    """Seeded memory-fault storm: every request completes with
    fault-free tokens; recovery cost = extra steps vs the twin."""
    from repro.serving.faults import FaultInjector

    eng0, reqs0 = _build()
    _run(eng0, reqs0)
    base_steps = eng0.steps
    faults = FaultInjector(FAULT_SEED, p_alloc_fail=0.05,
                           p_forced_evict=0.2, p_admit_race=0.25,
                           p_preempt_storm=0.1)
    eng, reqs = _build(faults=faults)
    _run(eng, reqs)
    fired = sum(faults.fired.values())
    assert fired > 0, "the seeded schedule never fired; change FAULT_SEED"
    assert all(r.done and r.error is None for r in reqs), \
        [(r.finish_reason, r.error) for r in reqs]
    assert [r.out for r in reqs] == [r.out for r in reqs0], \
        "memory faults changed the tokens"
    eng.pool.validate()
    assert eng.pool.slots.free_slots == eng.pool.slots.n_slots
    return dict(base_steps=base_steps, faulted_steps=eng.steps,
                recovery_steps=eng.steps - base_steps,
                faults_fired=fired,
                recovery_token_identity=True)


def bench_shed_rate() -> dict:
    """2x overload against max_queue=2: shed fraction strictly inside
    (0, 1), every shed carries a positive retry_after hint."""
    eng, reqs = _build(max_queue=2, n_prompts=OVERLOAD)
    _run(eng, reqs)
    shed = [r for r in reqs if r.finish_reason == "rejected"]
    served = [r for r in reqs if r.finish_reason == "length"]
    assert len(shed) + len(served) == len(reqs)
    assert all(r.retry_after is not None and r.retry_after > 0
               and r.out == [] for r in shed)
    return dict(overload_requests=len(reqs), shed_requests=len(shed),
                shed_rate=len(shed) / len(reqs),
                sheds_carry_retry_after=True)


def bench_disabled_cost() -> dict:
    """NULL_FAULTS default vs armed-at-p=0 injector: step-time ratio
    and token identity (the facade must be invisible)."""
    from repro.serving.faults import FaultInjector

    def timed(faults):
        eng, reqs = _build(faults=faults)
        dt = _run(eng, reqs)
        assert all(r.done and r.error is None for r in reqs)
        return dt / max(eng.steps, 1), [r.out for r in reqs]

    timed(None)                           # warmup: JIT compilation
    off = min(timed(None)[0] for _ in range(REPEATS))
    on = min(timed(FaultInjector(0))[0] for _ in range(REPEATS))
    _, out_off = timed(None)
    _, out_on = timed(FaultInjector(0))
    return dict(step_time_null_faults_s=off, step_time_armed_p0_s=on,
                disabled_overhead_ratio=on / off,
                token_identity_disabled=out_off == out_on)


def bench_watchdog() -> dict:
    """Corrupt the free list mid-run; validate_every=1 must repair it
    without changing tokens."""
    eng0, reqs0 = _build()
    _run(eng0, reqs0)
    eng, reqs = _build(validate_every=1)
    for r in reqs:
        eng.submit(r)
    for _ in range(3):
        eng.step()
    # smuggle a live slot-less corruption: for the SSM pool the blocks
    # view is slots-only, so poison the slot pool's used-set instead
    pool = eng.pool
    if pool.needs_blocks and any(s.blocks for s in eng.scheduler.running):
        live = next(int(b) for s in eng.scheduler.running for b in s.blocks)
        pool._free.append(live)
    else:
        used = next(iter(pool.slots._used))
        pool.slots._free.append(used)     # a live slot on the free list
    eng.run()
    violations = pool.metrics.value("repro_engine_fault_watchdog_violations")
    assert violations >= 1, "the watchdog never caught the corruption"
    assert all(r.done and r.error is None for r in reqs)
    assert [r.out for r in reqs] == [r.out for r in reqs0], \
        "watchdog recovery changed the tokens"
    pool.validate()
    return dict(watchdog_violations=violations, watchdog_recovered=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_fault_recovery.json")
    args = ap.parse_args()
    result = bench_recovery()
    result.update(bench_shed_rate())
    result.update(bench_disabled_cost())
    result.update(bench_watchdog())
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"recovery   {result['base_steps']} steps fault-free -> "
          f"{result['faulted_steps']} under storm "
          f"({result['faults_fired']} faults fired, tokens identical)")
    print(f"shed       {result['shed_requests']}/{result['overload_requests']}"
          f" rejected under 2x overload "
          f"(rate {result['shed_rate']:.2f}, all carry retry_after)")
    print(f"disabled   null {result['step_time_null_faults_s']*1e3:.2f} ms"
          f"  armed-p0 {result['step_time_armed_p0_s']*1e3:.2f} ms"
          f"  (ratio {result['disabled_overhead_ratio']:.3f})")
    print(f"watchdog   {result['watchdog_violations']:.0f} violation(s) "
          f"caught and repaired")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
