"""Train a small LM with the WSD schedule + fault-tolerance demo.

Trains a ~6M-param llama-family model for a few hundred steps on the
deterministic synthetic pipeline, simulates a preemption mid-run, resumes
from the latest atomic checkpoint, and verifies the loss curve continues
seamlessly.  Uses int8-quantized optimizer state (the bit-level storage
idea applied beyond the paper).

Run:  PYTHONPATH=src python examples/train_wsd.py [--steps 300]
"""

import argparse
import shutil

import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataSpec
from repro.optim.optimizer import AdamWConfig
from repro.train.trainer import (SimulatedPreemption, TrainConfig, Trainer)

CKPT = "/tmp/repro_example_wsd"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()
    shutil.rmtree(CKPT, ignore_errors=True)

    cfg = get_config("llama3-8b").reduced(
        n_layers=4, d_model=128, n_heads=8, n_kv_heads=2, d_head=16,
        d_ff=256, vocab=512)
    spec = DataSpec(vocab=cfg.vocab, seq_len=64, global_batch=8, seed=7)
    print(f"model ~{cfg.param_count() / 1e6:.1f}M params, "
          f"WSD schedule, int8 AdamW state")

    tcfg = TrainConfig(
        num_steps=args.steps, peak_lr=1e-3, warmup_steps=20,
        schedule="wsd", adamw=AdamWConfig(state_bits=8),
        ckpt_dir=CKPT, ckpt_every=50, log_every=20,
        preempt_at=args.steps // 2)

    losses = []

    def log(step, loss):
        losses.append(loss)
        if step % tcfg.log_every == 0:
            print(f"  step {step:4d}  loss {loss:.3f}")

    t = Trainer(cfg, tcfg, spec)
    try:
        t.run(resume=False, on_step=log)
    except SimulatedPreemption as e:
        print(f"!! {e} -- restarting from checkpoint")

    tcfg2 = TrainConfig(**{**tcfg.__dict__, "preempt_at": None})
    t2 = Trainer(cfg, tcfg2, spec)
    state, _ = t2.run(resume=True, on_step=log)

    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"loss: {first:.3f} -> {last:.3f} over {len(losses)} steps "
          f"(preempted + resumed at step {args.steps // 2})")
    assert last < first - 0.5, "training did not converge"
    if t2.straggler_events:
        print(f"straggler watchdog flagged {len(t2.straggler_events)} "
              f"slow steps")
    print("done.")


if __name__ == "__main__":
    main()
