"""End-to-end driver: serve a small LLM with batched requests (the paper
is an inference-acceleration paper, so serving is the primary e2e demo).

Builds a ~15M-param llama-family model, quantizes its weights to the
paper's W2A8 packed bipolar format, and serves a mixed queue of requests
through the continuous-batching engine -- then does the same in bf16 and
compares tokens/s and greedy outputs.

``--paged`` switches the quantized run to the paged block-pool engine
(kv_bits=8 packed KV planes shared through block tables, scheduler with
FCFS admission + preemption -- see src/repro/serving/paged_cache.py) and
prints the pool occupancy report.  The demo prompts share a system-style
prefix, so the paged run also exercises the refcounted copy-on-write
prefix cache: later requests acquire the resident prefix blocks and
prefill only their suffix (watch the hit/COW counters in the report).

``--chunk-tokens N`` (with ``--paged``) turns on chunked prefill:
prompts stream through the step loop N tokens at a time, fused with the
decode batch, so running decodes never stall on an arriving prompt.
The demo streams one request live through the async API -- a
StreamHandle with an ``on_token`` callback printing tokens as they are
emitted while the rest of the queue decodes alongside.

``--metrics`` turns on the observability subsystem (ISSUE 7) for the
quantized run: the engine is stepped manually with a live one-line
stats bar (tokens/s, running/queued, pool occupancy, p50/p95
inter-token latency straight from the registry histograms), the
Prometheus snapshot is summarized at the end, and the per-request
Perfetto timeline is dumped to ``--trace-out`` (open it in
ui.perfetto.dev or chrome://tracing).

Run:  PYTHONPATH=src python examples/serve_llm.py [--new-tokens 12]
                                                  [--paged]
                                                  [--block-size 16]
                                                  [--chunk-tokens 8]
                                                  [--metrics]
                                                  [--trace-out t.json]
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.models.config import QuantConfig
from repro.serving import engine as E


def _stats_bar(eng, t0):
    """One line of live serving stats, read straight off the registry."""
    reg = eng.obs.registry
    toks = reg.value("repro_engine_tokens")
    dt = max(time.perf_counter() - t0, 1e-9)
    itl = reg.get("repro_request_intertoken_seconds")
    return (f"\r  [obs] {toks:4.0f} tok @ {toks / dt:6.1f} tok/s | "
            f"run {reg.value('repro_engine_running'):2.0f} "
            f"wait {reg.value('repro_engine_waiting'):2.0f} | "
            f"pool {reg.value('repro_pool_occupancy') * 100:3.0f}% | "
            f"itl p50 {itl.percentile(50) * 1e3:6.2f} ms "
            f"p95 {itl.percentile(95) * 1e3:6.2f} ms")


def serve(params, cfg, prompts, quant, new_tokens, *, paged=False,
          block_size=16, chunk_tokens=None, stream_one=False,
          metrics=False):
    eng = E.Engine(params, cfg, n_slots=4, max_len=128, quant=quant,
                   paged=paged, block_size=block_size,
                   chunk_tokens=chunk_tokens,
                   metrics=True if metrics else None)
    reqs = [E.Request(prompt=p, max_new_tokens=new_tokens) for p in prompts]
    if stream_one:
        # async API showcase: watch request 0's tokens arrive live while
        # the whole queue decodes around it
        reqs[0].on_token = lambda t: print(f"  stream req0 -> {t}",
                                           flush=True)
    handles = [eng.submit(r) for r in reqs]
    t0 = time.perf_counter()
    if metrics:
        # manual step loop so the stats bar refreshes every step
        while eng.step():
            print(_stats_bar(eng, t0), end="", flush=True)
        print()
    else:
        if stream_one:
            for _ in handles[0].tokens():   # drive via the handle...
                pass
        eng.run()                           # ...then drain the rest
    dt = time.perf_counter() - t0
    total = sum(len(r.out) for r in reqs)
    return reqs, total / dt, eng


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--paged", action="store_true",
                    help="serve the quantized run on the paged block-pool "
                         "engine (kv_bits=8 KV planes + block tables)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per pool block (--paged)")
    ap.add_argument("--chunk-tokens", type=int, default=None,
                    help="chunked prefill budget per step (--paged): "
                         "prompts stream in fused with the decode batch")
    ap.add_argument("--metrics", action="store_true",
                    help="instrument the quantized run: live stats bar, "
                         "Prometheus summary, Perfetto trace on exit")
    ap.add_argument("--trace-out", default="serve_trace.json",
                    help="Perfetto trace path (--metrics)")
    args = ap.parse_args()

    cfg = get_config("llama3-8b").reduced(
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=2, d_head=32,
        d_ff=512, vocab=2048)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    print(f"model: llama-family reduced, "
          f"{cfg.param_count() / 1e6:.1f}M params")

    rng = np.random.default_rng(0)
    # a shared "system prompt" head + unique tails: the paged engine's
    # prefix cache serves the head from residency for requests 2..8
    system = rng.integers(0, cfg.vocab, (24,), dtype=np.int32)
    prompts = [np.concatenate([
        system, rng.integers(0, cfg.vocab, (6 + i,), dtype=np.int32)
    ]).astype(np.int32) for i in range(8)]

    print("— serving bf16 …")
    reqs_bf, tps_bf, _ = serve(params, cfg, prompts, None, args.new_tokens)

    kv_bits = 8 if args.paged else None
    qcfg = QuantConfig(w_bits=2, a_bits=8, kv_bits=kv_bits)
    qparams = M.quantize_params(params, qcfg)
    label = "W2A8+paged-KV8" if args.paged else "W2A8"
    print(f"— serving {label} (paper technique: packed bipolar "
          f"{'weights + paged KV pool' if args.paged else 'weights'}) …")
    reqs_q, tps_q, eng_q = serve(qparams, cfg, prompts, qcfg,
                                 args.new_tokens, paged=args.paged,
                                 block_size=args.block_size,
                                 chunk_tokens=args.chunk_tokens,
                                 stream_one=args.paged
                                 and not args.metrics,
                                 metrics=args.metrics)

    agree = np.mean([
        np.mean(np.asarray(a.out[:4]) == np.asarray(b.out[:4]))
        for a, b in zip(reqs_bf, reqs_q)])
    print(f"bf16   : {tps_bf:6.1f} tok/s")
    print(f"{label:7s}: {tps_q:6.1f} tok/s   (CPU reference impl; on TPU "
          f"the W2 path moves 8x fewer weight bytes -> see benchmarks F7)")
    print(f"greedy agreement on first 4 tokens: {agree * 100:.0f}% "
          f"(W2 is aggressive; this is a random-weight toy)")
    if args.paged:
        rep = eng_q.report()
        print(f"pool: {rep['n_usable']} blocks x {rep['block_size']} tok "
              f"@ kv_bits={rep['kv_bits']}, "
              f"{rep['pool_bytes'] / 1024:.0f} KiB, "
              f"{rep['preemptions']} preemptions, "
              f"{rep['rejections']} rejections")
        print(f"prefix cache: {rep['prefix_hits']} hits / "
              f"{rep['prefix_lookups']} lookups, "
              f"{rep['prefix_hit_tokens']} prompt tokens served from "
              f"residency, {rep['cow_copies']} copy-on-writes, "
              f"{rep['evictions']} evictions")
        if rep["chunk_tokens"]:
            print(f"chunked prefill: {rep['chunk_tokens']} tokens/step "
                  f"budget, {rep['chunk_tokens_processed']} prompt tokens "
                  f"streamed through the step loop")
    if args.metrics:
        reg = eng_q.obs.registry
        ttft = reg.get("repro_request_ttft_seconds")
        eng_q.obs.tracer.validate_all()
        eng_q.obs.tracer.export_json(args.trace_out)
        print(f"metrics: {reg.value('repro_requests_submitted'):.0f} "
              f"submitted, "
              f"{reg.value('repro_requests_finished', reason='length'):.0f}"
              f" finished(length), ttft p50 "
              f"{ttft.percentile(50) * 1e3:.2f} ms p95 "
              f"{ttft.percentile(95) * 1e3:.2f} ms over {ttft.count} "
              f"requests")
        n_ev = len(eng_q.obs.tracer.export()["traceEvents"])
        print(f"perfetto timeline: {n_ev} events -> {args.trace_out} "
              f"(open in ui.perfetto.dev)")
    assert all(r.done for r in reqs_bf + reqs_q)
    print("done.")


if __name__ == "__main__":
    main()
