"""Accuracy-vs-bits sweep (the paper's §5.2 setting, scaled to CPU).

Trains a small LM briefly so its weights are meaningful, then measures
held-out cross-entropy under the paper's W{n}A8 bipolar quantization for
n in {1..8} plus the bf16 ceiling -- the quality/bits trade-off curve an
arbitrary-precision *scheme* exists to exploit (W3/W5/W6 are exactly the
points fixed-format kernels cannot serve).

Run:  PYTHONPATH=src python examples/quantize_sweep.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataSpec, batch_at
from repro.models import model as M
from repro.models.config import QuantConfig
from repro.train.trainer import TrainConfig, Trainer


def main():
    cfg = get_config("llama3-8b").reduced(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
        d_ff=256, vocab=512)
    spec = DataSpec(vocab=cfg.vocab, seq_len=64, global_batch=8, seed=3)
    tcfg = TrainConfig(num_steps=120, peak_lr=1e-3, warmup_steps=10,
                       ckpt_every=0, ckpt_dir="/tmp/repro_sweep")
    print("— pretraining a toy model (120 steps) …")
    state, hist = Trainer(cfg, tcfg, spec).run(resume=False)
    params = state["params"]

    held_out = [jax.tree.map(jnp.asarray, batch_at(spec, 10_000 + i))
                for i in range(4)]

    def ce(p, quant):
        return float(np.mean([
            float(M.loss_fn(p, b, cfg, quant=quant, remat=False))
            for b in held_out]))

    base = ce(params, None)
    print(f"bf16 ceiling: CE {base:.3f}")
    print(" bits |   CE   | ΔCE vs bf16")
    for bits in (8, 6, 5, 4, 3, 2, 1):
        q = QuantConfig(w_bits=bits, a_bits=8)
        qp = M.quantize_params(params, q)
        c = ce(qp, q)
        print(f"  W{bits}  | {c:6.3f} | +{c - base:.3f}")
    print("done. (W5/W6/W3 are the arbitrary-precision points the paper's "
          "scheme unlocks on hardware with int-only format catalogues)")


if __name__ == "__main__":
    main()
