"""Quickstart: the paper's arbitrary-precision MatMul in five minutes.

Demonstrates (on CPU, reference/interpret impls):
 1. bipolar-INT quantization + §4.1 bit-plane packing (exact n bits/elt),
 2. the §3.2 bit-serial MatMul == the fused operand-recovery MatMul ==
    the exact integer product (bit-for-bit),
 3. the Pallas kernel (interpret mode) matching the oracle,
 4. quantized-GEMM accuracy vs the float GEMM across bit-widths.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bipolar
from repro.kernels import ops, ref

rng = np.random.default_rng(0)
M, N, K = 64, 96, 300   # deliberately unaligned: pad correction in action

x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)   # activations
w = jnp.asarray(rng.standard_normal((N, K)), jnp.float32)   # weights

print("== 1. quantize + pack (paper §3.1 + §4.1) ==")
for bits in (1, 2, 3, 4):
    t = ops.pack_weight(w, bits, impl="reference")
    print(f"  W{bits}: packed {t.nbytes_packed:8d} B   "
          f"bf16 {t.nbytes_dense_bf16:8d} B   "
          f"({t.nbytes_dense_bf16 / t.nbytes_packed:.1f}x smaller)")

print("== 2. bit-serial == fused == exact (paper §3.2 / Fig. 2) ==")
at = ops.quantize_rows(x, 2, pad_bit=0, impl="reference")
bt = ops.quantize_rows(w, 3, pad_bit=1, impl="reference")
y_bs = ops.ap_matmul(at, bt, variant="bitserial", impl="reference", raw=True)
y_fu = ops.ap_matmul(at, bt, variant="fused", impl="reference", raw=True)
assert np.array_equal(np.asarray(y_bs), np.asarray(y_fu))
print(f"  W3A2 {M}x{N}x{K}: bit-serial and fused agree bit-for-bit "
      f"(checksum {int(np.asarray(y_fu).sum())})")

print("== 3. Pallas kernel (interpret mode) vs oracle ==")
y_k = ops.ap_matmul(at, bt, impl="interpret", raw=True)
assert np.array_equal(np.asarray(y_k), np.asarray(y_fu))
print("  pallas_call(interpret=True) matches the jnp oracle exactly")

print("== 4. accuracy vs float across bit-widths ==")
y_f = np.asarray(x) @ np.asarray(w).T
for wb, ab in ((1, 2), (2, 2), (3, 4), (4, 8), (8, 8)):
    wt = ops.pack_weight(w, wb, impl="reference")
    y_q = np.asarray(ops.ap_linear(x, wt, a_bits=ab, impl="reference"))
    rel = np.abs(y_q - y_f).mean() / np.abs(y_f).mean()
    print(f"  W{wb}A{ab}: mean relative error {rel * 100:6.2f}%")

print("done.")
