"""Serving tests: prefill+decode must reproduce the full-sequence forward,
and the continuous-batching engine must complete mixed workloads."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.specs import make_batch
from repro.models import model as M
from repro.serving import engine as E

# cover every cache type: pure attention, GQA, SWA ring, SSM, hybrid, encdec
CONSISTENCY_ARCHS = ["llama3-8b", "mixtral-8x7b", "mamba2-130m",
                     "jamba-1.5-large-398b"]


def _setup(name, **red):
    cfg = get_config(name).reduced(**red)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    return cfg, params


@pytest.mark.parametrize("name", CONSISTENCY_ARCHS)
def test_prefill_then_decode_matches_forward(name):
    """logits(prefill(x[:t]) -> decode x[t]) == logits(forward(x[:t+1]))."""
    cfg, params = _setup(name)
    b, s = 2, 24
    rng = np.random.default_rng(0)
    toks = jnp.array(rng.integers(0, cfg.vocab, (b, s), dtype=np.int32))

    # ground truth: full forward, logits at the last position
    x, _, _ = M.forward(params, toks, cfg, remat=False)
    ref_logits = np.asarray(
        M._logits(params, x[:, -1:, :], cfg)[:, 0], dtype=np.float32)

    # prefill s-1 tokens, then decode token s-1
    caches = M.init_caches(cfg, b, max_len=64)
    batch = {"tokens": toks[:, :s - 1]}
    _, caches = E.prefill_step(params, batch, caches, cfg)
    step_batch = {"tokens": toks[:, s - 1:s],
                  "positions": jnp.full((b, 1), s - 1, jnp.int32)}
    logits, _ = E.serve_step(params, step_batch, caches, cfg)
    got = np.asarray(logits, dtype=np.float32)

    np.testing.assert_allclose(got, ref_logits, rtol=0.15, atol=0.15)
    # ranking agreement is the real invariant at bf16 precision
    assert (np.argmax(got, -1) == np.argmax(ref_logits, -1)).mean() >= 0.5


def test_swa_ring_cache_evicts_correctly():
    """With window w, decoding past w tokens must equal a fresh prefill
    that only ever saw the last w tokens (ring eviction == true SWA)."""
    cfg, params = _setup("mixtral-8x7b", window=8, n_layers=2)
    s_total, w = 20, 8
    rng = np.random.default_rng(3)
    toks = jnp.array(rng.integers(0, cfg.vocab, (1, s_total), dtype=np.int32))

    # path A: prefill 12, decode the rest one by one
    caches = M.init_caches(cfg, 1, max_len=64)
    _, caches = E.prefill_step(params, {"tokens": toks[:, :12]}, caches, cfg)
    logits = None
    for t in range(12, s_total):
        logits, caches = E.serve_step(
            params, {"tokens": toks[:, t:t + 1],
                     "positions": jnp.full((1, 1), t, jnp.int32)},
            caches, cfg)

    # path B: single full forward (the SWA mask hides tokens beyond w anyway)
    x, _, _ = M.forward(params, toks, cfg, remat=False)
    ref = np.asarray(M._logits(params, x[:, -1:, :], cfg)[:, 0],
                     dtype=np.float32)
    got = np.asarray(logits, dtype=np.float32)
    np.testing.assert_allclose(got, ref, rtol=0.15, atol=0.15)
    assert np.argmax(got, -1) == np.argmax(ref, -1)


def test_engine_continuous_batching_completes():
    cfg, params = _setup("llama3-8b", n_layers=2)
    eng = E.Engine(params, cfg, n_slots=2, max_len=64)
    rng = np.random.default_rng(7)
    reqs = [E.Request(prompt=rng.integers(0, cfg.vocab, (5 + i,),
                                          dtype=np.int32),
                      max_new_tokens=4 + i) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        assert r.done
        assert len(r.out) == r.max_new_tokens
    # more requests than slots => batching actually cycled
    assert eng.steps >= max(r.max_new_tokens for r in reqs)


def test_engine_quantized_serving_runs():
    """End-to-end: paper technique (W2A8 packed weights) inside the engine."""
    cfg, params = _setup("llama3-8b", n_layers=2)
    qcfg = cfg.quant
    qparams = M.quantize_params(params, qcfg)
    eng = E.Engine(qparams, cfg, n_slots=2, max_len=32, quant=qcfg)
    rng = np.random.default_rng(9)
    reqs = [E.Request(prompt=rng.integers(0, cfg.vocab, (6,), dtype=np.int32),
                      max_new_tokens=3) for _ in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 3 for r in reqs)


def test_fused_linear_engine_token_identical_to_unfused():
    """The one-kernel fused linear (quantize-pack prologue + epilogue,
    dual-GEMM SwiGLU, fused residual) must greedy-decode EXACTLY the
    unfused two-launch baseline's tokens -- the epilogue's out-dtype
    cast points make the two paths bit-identical, so this is equality,
    not tolerance.  d_head=32 / vocab=512 is the regression config: a
    structurally different residual-add site once flipped a near-tie
    argmax here through XLA-CPU's fusion-dependent bf16 rounding."""
    cfg, params = _setup("llama3-8b", n_layers=2, d_head=32, vocab=512)
    qcfg = cfg.quant                       # W2A8 + kv8, fused by default
    assert qcfg.fused_linear
    qparams = M.quantize_params(params, qcfg)
    rng = np.random.default_rng(23)
    prompts = [rng.integers(0, cfg.vocab, (5 + i,), dtype=np.int32)
               for i in range(3)]

    def run(quant):
        eng = E.Engine(qparams, cfg, n_slots=2, max_len=32, quant=quant)
        reqs = [E.Request(prompt=p, max_new_tokens=6) for p in prompts]
        for r in reqs:
            eng.submit(r)
        eng.run()
        assert all(r.done and len(r.out) == 6 for r in reqs)
        return [list(r.out) for r in reqs]

    fused = run(qcfg)
    unfused = run(dataclasses.replace(qcfg, fused_linear=False))
    assert fused == unfused, (fused, unfused)
    # stronger: the full-forward logits agree BITWISE
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 9), dtype=np.int32))
    logit = {}
    for q in (qcfg, dataclasses.replace(qcfg, fused_linear=False)):
        x, _, _ = M.forward(qparams, toks, cfg, quant=q, remat=False)
        logit[q.fused_linear] = np.asarray(
            M._logits(qparams, x[:, -1:, :], cfg, q), np.float32)
    np.testing.assert_array_equal(logit[True], logit[False])


@pytest.mark.parametrize("name,chunk", [
    ("llama3-8b", None),          # pure attention, whole-prompt admission
    ("llama3-8b", 4),             # chunked prefill: fused mixed steps
    ("mixtral-8x7b", None),       # SWA window + MoE experts
])
def test_mixed_precision_batch_lane_token_identity(name, chunk):
    """Nested-precision serving: a lane inside a mixed {8, 4, 2}-bit
    paged batch emits tokens BIT-identical to the same request in a
    homogeneous batch at its own precision.  Per-precision grouped
    dispatch plus the precision-salted prefix cache mean batch
    composition changes scheduling, never math -- the same contract
    prefix sharing holds to, extended across widths.  The jit cache is
    cleared across the flip so agreement cannot ride a stale compiled
    program."""
    from repro.models.config import QuantConfig
    red = dict(n_layers=2) if name == "llama3-8b" \
        else dict(n_layers=2, window=8)
    cfg, params = _setup(name, **red)
    qcfg = QuantConfig(w_bits=8, a_bits=8, kv_bits=8)
    qparams = M.quantize_params(params, qcfg)
    rng = np.random.default_rng(17)
    prompts = [rng.integers(0, cfg.vocab, (5 + i,), dtype=np.int32)
               for i in range(3)]

    def run(precs):
        jax.clear_caches()
        eng = E.Engine(qparams, cfg, quant=qcfg, paged=True, n_slots=4,
                       max_len=64, chunk_tokens=chunk,
                       block_size=8 if cfg.window else 16)
        reqs = [E.Request(prompt=p.copy(), max_new_tokens=5, precision=b)
                for p, b in zip(prompts, precs)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        assert all(r.done and len(r.out) == 5 for r in reqs)
        return [list(r.out) for r in reqs]

    mixed = run([8, 4, 2])
    homo8 = run([8, 8, 8])
    assert mixed[0] == homo8[0], (mixed[0], homo8[0])
    if name == "llama3-8b" and chunk is None:
        # the bulk lanes hold too: every precision is its own closed lane
        assert mixed[1] == run([4, 4, 4])[1]
        assert mixed[2] == run([2, 2, 2])[2]


def test_engine_matches_direct_greedy_decode():
    """Slot-inserted caches must be content-correct: a 2-slot engine's
    output for one request equals direct prefill+greedy decoding (this
    guards the batch-dim offset of _tree_write_slot against the stacked
    (n_units, B, ...) cache layout)."""
    cfg, params = _setup("llama3-8b", n_layers=4)
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab, (7,), dtype=np.int32)

    # direct path
    caches = M.init_caches(cfg, 1, max_len=64)
    logits, caches = E.prefill_step(
        params, {"tokens": jnp.asarray(prompt)[None]}, caches, cfg)
    direct = [int(np.argmax(np.asarray(logits[0])))]
    for i in range(4):
        tok = jnp.asarray([[direct[-1]]], jnp.int32)
        pos = jnp.asarray([[len(prompt) + i]], jnp.int32)
        logits, caches = E.serve_step(
            params, {"tokens": tok, "positions": pos}, caches, cfg)
        direct.append(int(np.argmax(np.asarray(logits[0]))))

    # engine path: request placed in slot 1 (nonzero => offset-sensitive)
    eng = E.Engine(params, cfg, n_slots=2, max_len=64)
    filler = E.Request(prompt=prompt.copy(), max_new_tokens=5)
    eng.submit(E.Request(prompt=prompt.copy(), max_new_tokens=5))
    eng.submit(filler)          # same prompt lands in slot 1
    eng.run()
    assert filler.out == direct, (filler.out, direct)


def test_encdec_cross_cache_decode_exact():
    """Enc-dec decode via cached cross-K/V must equal the full forward
    (the encoder is not re-run per token)."""
    cfg, params = _setup("seamless-m4t-medium")
    rng = np.random.default_rng(0)
    b, s = 2, 12
    toks = jnp.array(rng.integers(0, cfg.vocab, (b, s), dtype=np.int32))
    frames = jnp.array(
        rng.standard_normal((b, 16, cfg.frontend_dim)).astype(np.float32)
        * 0.1)
    x, _, _ = M.forward(params, toks, cfg, frames=frames, remat=False)
    ref = np.asarray(M._logits(params, x[:, -1:, :], cfg)[:, 0],
                     dtype=np.float32)
    caches = M.init_caches(cfg, b, max_len=32, enc_len=16)
    _, caches = E.prefill_step(
        params, {"tokens": toks[:, :s - 1], "frames": frames}, caches, cfg)
    logits, _ = E.serve_step(
        params, {"tokens": toks[:, s - 1:],
                 "positions": jnp.full((b, 1), s - 1, jnp.int32)},
        caches, cfg)
    got = np.asarray(logits, dtype=np.float32)
    assert (np.argmax(got, -1) == np.argmax(ref, -1)).all()
    np.testing.assert_allclose(got, ref, atol=0.1)


def test_quantized_kv_engine_token_identical_and_2x_smaller(tmp_path):
    """The paper's bit-level storage on the serving KV cache: a kv_bits=8
    engine must greedy-decode the SAME tokens as the bf16-cache engine,
    from a cache whose K/V payload is >= 2x smaller per token.

    Uses a briefly-trained model: untrained logits are near-ties where
    argmax is decided by noise below the quantization step."""
    from repro.data.pipeline import DataSpec
    from repro.train.trainer import TrainConfig, Trainer
    cfg = get_config("llama3-8b").reduced(n_layers=2, d_head=32, vocab=256)
    spec = DataSpec(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=5)
    tcfg = TrainConfig(num_steps=30, peak_lr=1e-3, warmup_steps=5,
                       ckpt_dir=str(tmp_path), ckpt_every=100)
    state, _ = Trainer(cfg, tcfg, spec, async_ckpt=False).run(resume=False)
    params = state["params"]
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, (6 + i,), dtype=np.int32)
               for i in range(3)]

    def run(quant):
        eng = E.Engine(params, cfg, n_slots=2, max_len=32, quant=quant)
        reqs = [E.Request(prompt=p.copy(), max_new_tokens=5) for p in prompts]
        for r in reqs:
            eng.submit(r)
        eng.run()
        assert all(r.done for r in reqs)
        return [r.out for r in reqs], eng

    kv8 = dataclasses.replace(cfg.quant, w_bits=None, kv_bits=8)
    out_bf, eng_bf = run(None)
    out_q8, eng_q8 = run(kv8)
    assert out_q8 == out_bf, (out_q8, out_bf)

    # K/V payload bytes per cached token: bipolar 8-bit planes vs bf16.
    # d_head=32 divides the pack word exactly -> the ratio is the pure
    # bits-per-element ratio 16/8 = 2; scales are O(1/D) metadata on top.
    bf_bytes = E.kv_cache_bytes(eng_bf.caches, payload_only=True)
    q8_bytes = E.kv_cache_bytes(eng_q8.caches, payload_only=True)
    assert bf_bytes / q8_bytes >= 2.0, (bf_bytes, q8_bytes)
    # including the per-(token, head) scales it stays close to 2x
    assert bf_bytes / E.kv_cache_bytes(eng_q8.caches) >= 1.7


def test_bucketed_prefill_matches_unbucketed():
    """Engine prefill buckets prompt lengths to the next power of two
    (pad positions -1, logits gathered at the last real token): the
    logits must match the exact-length prefill and the bucket count must
    stay O(log max_len) over a stream of varied lengths."""
    cfg, params = _setup("llama3-8b", n_layers=2)
    eng = E.Engine(params, cfg, n_slots=1, max_len=64)
    rng = np.random.default_rng(2)
    for s in (3, 7, 11, 30):
        prompt = rng.integers(0, cfg.vocab, (s,), dtype=np.int32)
        logits_b, _ = eng._bucketed_prefill(prompt)
        caches = M.init_caches(cfg, 1, max_len=64)
        logits_u, _ = E.prefill_step(
            params, {"tokens": jnp.asarray(prompt)[None]}, caches, cfg)
        got = np.asarray(logits_b, np.float32)
        ref = np.asarray(logits_u, np.float32)
        np.testing.assert_allclose(got, ref, rtol=2e-2, atol=2e-2)
        assert (np.argmax(got, -1) == np.argmax(ref, -1)).all(), s
    # lengths 1..max_len compile at most O(log max_len) prefill programs
    buckets = {E.prefill_bucket(s, 64) for s in range(1, 65)}
    assert buckets == {8, 16, 32, 64}


def _direct_greedy(params, cfg, prompt, n_new, max_len=32):
    """Oracle: exact-length prefill + greedy decode, no engine."""
    caches = M.init_caches(cfg, 1, max_len=max_len)
    logits, caches = E.prefill_step(
        params, {"tokens": jnp.asarray(prompt)[None]}, caches, cfg)
    out = [int(np.argmax(np.asarray(logits[0])))]
    for i in range(n_new - 1):
        logits, caches = E.serve_step(
            params, {"tokens": jnp.asarray([[out[-1]]], jnp.int32),
                     "positions": jnp.asarray([[len(prompt) + i]],
                                              jnp.int32)},
            caches, cfg)
        out.append(int(np.argmax(np.asarray(logits[0]))))
    return out


def test_bucketed_prefill_ring_index_rewinds_to_real_length():
    """A prompt whose bucket reaches max_len must NOT wrap the ring and
    overwrite live prompt KV: the write index is rewound to the real
    length so decode consumes the pad slots first."""
    cfg, params = _setup("llama3-8b", n_layers=2)
    prompt = np.arange(17, dtype=np.int32) % cfg.vocab   # buckets to 32
    eng = E.Engine(params, cfg, n_slots=1, max_len=32)
    req = E.Request(prompt=prompt.copy(), max_new_tokens=6)
    eng.submit(req)
    eng.run()
    assert req.out == _direct_greedy(params, cfg, prompt, 6), req.out


def test_ssm_engine_prefill_stays_exact():
    """SSM recurrences consume pad tokens regardless of position
    masking, so the engine must prefill SSM archs at exact length --
    and still match the no-engine oracle."""
    cfg, params = _setup("mamba2-130m")
    prompt = np.arange(7, dtype=np.int32) % cfg.vocab
    eng = E.Engine(params, cfg, n_slots=1, max_len=32)
    req = E.Request(prompt=prompt.copy(), max_new_tokens=5)
    eng.submit(req)
    eng.run()
    assert req.out == _direct_greedy(params, cfg, prompt, 5), req.out


def test_contiguous_engine_serves_prompt_longer_than_ring():
    """Prompts past the ring take the exact-length SWA-tail prefill (no
    bucketing assert): the request completes and other requests are not
    stranded."""
    cfg, params = _setup("llama3-8b", n_layers=2)
    eng = E.Engine(params, cfg, n_slots=2, max_len=32)
    rng = np.random.default_rng(4)
    long_req = E.Request(prompt=rng.integers(0, cfg.vocab, (40,),
                                             dtype=np.int32),
                         max_new_tokens=4)
    short = E.Request(prompt=rng.integers(0, cfg.vocab, (6,),
                                          dtype=np.int32),
                      max_new_tokens=4)
    eng.submit(long_req)
    eng.submit(short)
    eng.run()
    assert long_req.done and short.done
    assert len(short.out) == 4


def test_cross_attention_cache_kv_bits_close():
    """ROADMAP open item: kv_bits on the enc-dec cross-K/V cache.  The
    quantized cross cache must decode close to the bf16 cross cache
    (reference impl; the cross stream re-reads every decode step).
    d_head=32 divides the pack word exactly, so the payload ratio is the
    pure bits-per-element ratio."""
    cfg, params = _setup("seamless-m4t-medium", d_head=32)
    rng = np.random.default_rng(0)
    b, s = 2, 12
    toks = jnp.array(rng.integers(0, cfg.vocab, (b, s), dtype=np.int32))
    frames = jnp.array(
        rng.standard_normal((b, 16, cfg.frontend_dim)).astype(np.float32)
        * 0.1)

    def run(c):
        caches = M.init_caches(c, b, max_len=32, enc_len=16)
        _, caches = E.prefill_step(
            params, {"tokens": toks[:, :s - 1], "frames": frames},
            caches, c)
        # the quantized cross cache holds packed planes + scales
        xc = caches["cross"][0]
        if c.kv_bits:
            assert xc["k"].dtype == jnp.uint32 and "k_scale" in xc
        logits, _ = E.serve_step(
            params, {"tokens": toks[:, s - 1:],
                     "positions": jnp.full((b, 1), s - 1, jnp.int32)},
            caches, c)
        return np.asarray(logits, dtype=np.float32)

    bf = run(cfg)
    q8 = run(dataclasses.replace(cfg, kv_bits=8))
    assert (np.argmax(bf, -1) == np.argmax(q8, -1)).all()
    np.testing.assert_allclose(q8, bf, rtol=0.1, atol=0.1)
    # payload shrinks ~2x: packed 8-bit planes vs bf16
    bf_caches = M.init_caches(cfg, b, max_len=32, enc_len=16)
    q8_caches = M.init_caches(dataclasses.replace(cfg, kv_bits=8), b,
                              max_len=32, enc_len=16)
    ratio = (E.kv_cache_bytes(bf_caches, payload_only=True)
             / E.kv_cache_bytes(q8_caches, payload_only=True))
    assert ratio >= 2.0, ratio


def test_int8_kv_cache_decode_close():
    """kv_bits=8 decode must track the bf16-cache decode closely (the
    bit-level KV stream; now stored as packed bipolar planes)."""
    cfg, params = _setup("llama3-8b", n_layers=2)
    cfg8 = dataclasses.replace(cfg, kv_bits=8)
    rng = np.random.default_rng(0)
    toks = jnp.array(rng.integers(0, cfg.vocab, (2, 16), dtype=np.int32))

    def run(c):
        caches = M.init_caches(c, 2, max_len=32)
        _, caches = E.prefill_step(params, {"tokens": toks[:, :15]}, caches, c)
        logits, _ = E.serve_step(
            params, {"tokens": toks[:, 15:],
                     "positions": jnp.full((2, 1), 15, jnp.int32)},
            caches, c)
        return np.asarray(logits, dtype=np.float32)

    bf, q8 = run(cfg), run(cfg8)
    assert (np.argmax(bf, -1) == np.argmax(q8, -1)).all()
    np.testing.assert_allclose(q8, bf, rtol=0.1, atol=0.1)
