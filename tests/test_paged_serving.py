"""Paged serving subsystem: block pool, scheduler policies, and the
paged engine's equivalence to the contiguous engine.

Key invariants (ISSUE 2 + ISSUE 3 acceptance):
* paged greedy decode at kv_bits=8 -- with and without the prefix
  cache -- is token-identical to the contiguous engine on the smoke
  configs;
* pool exhaustion preempts the youngest request, which is re-admitted
  (warm-restarting from its own cached blocks when they survive) and
  still produces the exact same tokens, at temperature 0 AND > 0
  (per-request RNG keyed by (seed, output index));
* same-prefix requests share >= 1 full block (refcount > 1) and a
  write into a shared partial block triggers copy-on-write;
* a request that could never fit the pool is rejected cleanly;
* freed blocks return to the free list and are reused;
* at equal cache bytes the paged pool admits >= 2x the concurrent
  requests of the slot engine on a mixed-length workload.

Pool-level prefix-cache/COW unit and property tests live in
tests/test_prefix_cache.py (no model forward needed there).
"""

import dataclasses

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:    # property tests skip (not error) without hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_config
from repro.models import model as M
from repro.serving import engine as E
from repro.serving.paged_cache import PagedKVPool, supports_paging


def _setup(name="llama3-8b", **red):
    cfg = get_config(name).reduced(**red)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    return cfg, params


def _kv8(cfg):
    return dataclasses.replace(cfg.quant, w_bits=None, kv_bits=8)


# ---------------------------------------------------------------------------
# Pool unit tests (no model forward)
# ---------------------------------------------------------------------------

def test_pool_alloc_free_reuse_and_null_block():
    cfg, _ = _setup(n_layers=2)
    pool = PagedKVPool(cfg, n_blocks=6, block_size=4, quant=_kv8(cfg))
    assert pool.n_usable == 5 and pool.free_blocks == 5
    a = pool.alloc(3)
    assert 0 not in a, "null block must never be allocated"
    assert pool.free_blocks == 2
    with pytest.raises(RuntimeError):
        pool.alloc(3)
    pool.free(a)
    assert pool.free_blocks == 5
    b = pool.alloc(5)
    assert set(a) <= set(b), "freed blocks must be reused"
    rep = pool.report(tokens_resident=11)
    assert rep["used_blocks"] == 5 and rep["free_blocks"] == 0
    # 11 tokens over 5 x 4 slots => 9 empty allocated slots
    assert rep["fragmentation"] == pytest.approx(9 / 20)
    assert rep["pool_bytes"] > rep["payload_bytes"] > 0


def test_pool_alloc_resets_positions():
    """Stale positions in a reused block would leak a freed request's
    tokens through the causal mask; alloc must reset them to -1."""
    import jax.numpy as jnp
    cfg, _ = _setup(n_layers=2)
    pool = PagedKVPool(cfg, n_blocks=4, block_size=4, quant=_kv8(cfg))
    (a,) = pool.alloc(1)
    for c, stacked in pool._attn_caches():
        c["pos"] = c["pos"].at[..., a, :].set(7)   # simulate resident tokens
    pool.free([a])
    (b,) = pool.alloc(1)
    assert b == a
    for c, stacked in pool._attn_caches():
        assert (np.asarray(c["pos"])[..., a, :] == -1).all()


def test_pool_requires_kv_bits_and_slot_sizing():
    cfg, _ = _setup(n_layers=2)
    with pytest.raises(AssertionError):
        PagedKVPool(cfg, n_blocks=4, block_size=4, quant=None)  # bf16 cache
    # every family pages now: attention KV in blocks, state in slots --
    # but stateful archs must size the slot pool
    ssm_cfg = get_config("mamba2-130m").reduced()
    assert supports_paging(ssm_cfg)
    with pytest.raises(ValueError, match="n_state_slots"):
        PagedKVPool(ssm_cfg, n_blocks=4, block_size=4)
    pool = PagedKVPool(ssm_cfg, n_blocks=4, block_size=4, n_state_slots=2)
    assert not pool.needs_blocks and pool.slots is not None
    a = pool.alloc_slot()
    b = pool.alloc_slot()
    assert 0 not in (a, b), "null slot must never be allocated"
    with pytest.raises(RuntimeError, match="slot pool exhausted"):
        pool.alloc_slot()
    pool.free_slot(a)
    with pytest.raises(ValueError, match="double free"):
        pool.free_slot(a)
    pool.validate()


def test_pool_block_size_beyond_window_raises_descriptive():
    """The old opaque `assert window >= max_len` is gone (out-of-window
    reclaim handles window < max_len); the one genuinely invalid combo
    left raises a ValueError naming the knobs."""
    cfg, _ = _setup("mixtral-8x7b", n_layers=2, window=8)
    with pytest.raises(ValueError, match="block_size.*window"):
        PagedKVPool(cfg, n_blocks=4, block_size=16, quant=_kv8(cfg))


def test_admission_headroom_for_block_aligned_prompts():
    """A prompt that exactly fills its blocks opens a new block on the
    very first decode append; admission must reserve that headroom or
    the request is preempted (prefill discarded) on the same step.
    (prefix_cache=False: the arange prompts share a prefix, and a cache
    hit would legitimately shrink b's need -- tested elsewhere.)"""
    from repro.serving.scheduler import Scheduler
    cfg, _ = _setup(n_layers=2)
    pool = PagedKVPool(cfg, n_blocks=4, block_size=4, quant=_kv8(cfg),
                       prefix_cache=False)
    sch = Scheduler(pool, max_len=32, max_batch=4)

    def stub_prefill(seq, tokens):
        seq.length = len(tokens)
        seq.last_tok = 1
        seq.req.out.append(1)

    a = E.Request(prompt=np.arange(4, dtype=np.int32), max_new_tokens=4)
    b = E.Request(prompt=np.arange(8, dtype=np.int32), max_new_tokens=4)
    sch.submit(a)
    sch.submit(b)
    sch.admit(stub_prefill)
    # a (1 block + headroom) fits the 3-block pool; b (2 blocks +
    # headroom) must stay queued rather than be admitted into certain
    # same-step preemption
    assert len(sch.running) == 1 and len(sch.waiting) == 1
    sch.ensure_append_capacity()       # a grows into its reserved block
    assert sch.n_preemptions == 0
    assert len(sch.running[0].blocks) == 2


# ---------------------------------------------------------------------------
# Engine equivalence + scheduler edge cases
# ---------------------------------------------------------------------------

def _run_engine(params, cfg, prompts, *, quant, max_new=5, **kw):
    eng = E.Engine(params, cfg, n_slots=2, max_len=32, quant=quant, **kw)
    reqs = [E.Request(prompt=p.copy(), max_new_tokens=max_new)
            for p in prompts]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    return [r.out for r in reqs], eng


def test_paged_engine_token_identical_to_contiguous(tmp_path):
    """Engine(paged=True, kv_bits=8) greedy decode == contiguous engine,
    token for token (the pool stores the exact same packed planes).

    Briefly trained model: untrained logits are near-ties where argmax
    is decided by noise below the padding-induced reduction reordering."""
    from repro.data.pipeline import DataSpec
    from repro.train.trainer import TrainConfig, Trainer
    cfg = get_config("llama3-8b").reduced(n_layers=2, d_head=32, vocab=256)
    spec = DataSpec(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=5)
    tcfg = TrainConfig(num_steps=30, peak_lr=1e-3, warmup_steps=5,
                       ckpt_dir=str(tmp_path), ckpt_every=100)
    state, _ = Trainer(cfg, tcfg, spec, async_ckpt=False).run(resume=False)
    params = state["params"]
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, (5 + i,), dtype=np.int32)
               for i in range(4)]
    kv8 = _kv8(cfg)
    out_c, eng_c = _run_engine(params, cfg, prompts, quant=kv8)
    out_p, eng_p = _run_engine(params, cfg, prompts, quant=kv8,
                               paged=True, block_size=8)
    assert out_p == out_c, (out_p, out_c)
    rep = eng_p.report()
    assert rep["preemptions"] == 0 and rep["rejections"] == 0
    assert rep["free_blocks"] == rep["n_usable"]   # all blocks returned


def test_pool_exhaustion_preempts_and_readmits():
    """A pool too small for the workload must evict the youngest request
    (blocks freed, re-queued for re-prefill) and still complete every
    request with the same tokens an uncontended pool produces."""
    cfg, params = _setup(n_layers=2)
    kv8 = _kv8(cfg)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, (6,), dtype=np.int32)
               for _ in range(3)]
    out_small, eng_small = _run_engine(
        params, cfg, prompts, quant=kv8, max_new=8,
        paged=True, block_size=4, n_blocks=6, max_batch=4)
    assert eng_small.scheduler.n_preemptions > 0, \
        "5-usable-block pool with 3 growing requests must preempt"
    out_big, eng_big = _run_engine(
        params, cfg, prompts, quant=kv8, max_new=8,
        paged=True, block_size=4, n_blocks=40, max_batch=4)
    assert eng_big.scheduler.n_preemptions == 0
    assert out_small == out_big, "preemption must not change outputs"
    assert eng_small.pool.free_blocks == eng_small.pool.n_usable


def test_request_longer_than_pool_rejected_cleanly():
    """A request whose lifetime block need exceeds the pool must fail
    fast with an error -- not hang the engine or starve the queue."""
    cfg, params = _setup(n_layers=2)
    kv8 = _kv8(cfg)
    rng = np.random.default_rng(5)
    eng = E.Engine(params, cfg, max_len=32, quant=kv8, paged=True,
                   block_size=4, n_blocks=6, max_batch=4)
    big = E.Request(prompt=rng.integers(0, cfg.vocab, (28,),
                                        dtype=np.int32), max_new_tokens=8)
    ok = E.Request(prompt=rng.integers(0, cfg.vocab, (6,), dtype=np.int32),
                   max_new_tokens=4)
    eng.submit(big)
    eng.submit(ok)
    eng.run(max_steps=200)
    assert big.done and big.error and "rejected" in big.error
    assert big.out == []
    assert ok.done and ok.error is None and len(ok.out) == 4
    # over-long prompts reject too (contiguous engines would silently
    # truncate at max_len; the scheduler refuses)
    toolong = E.Request(prompt=rng.integers(0, cfg.vocab, (40,),
                                            dtype=np.int32))
    eng.submit(toolong)
    assert toolong.done and "rejected" in toolong.error


def test_block_freelist_reuse_across_sequential_requests():
    """PR-2 reclamation semantics, pinned behind prefix_cache=False
    (with the cache on, released blocks deliberately park in the LRU
    instead of returning to the free list)."""
    cfg, params = _setup(n_layers=2)
    kv8 = _kv8(cfg)
    rng = np.random.default_rng(5)
    eng = E.Engine(params, cfg, max_len=32, quant=kv8, paged=True,
                   block_size=4, n_blocks=6, max_batch=1,
                   prefix_cache=False)
    used = []
    for i in range(3):
        req = E.Request(prompt=rng.integers(0, cfg.vocab, (6,),
                                            dtype=np.int32),
                        max_new_tokens=3)
        eng.submit(req)
        # capture the blocks while the request is running
        eng.step()
        used.append(set(eng.scheduler.running[0].blocks)
                    if eng.scheduler.running else set())
        eng.run()
        assert req.done
        assert eng.pool.free_blocks == eng.pool.n_usable
    assert used[0] and used[0] == used[1] == used[2], \
        "sequential requests must reuse the same freed blocks"


def test_paged_capacity_2x_contiguous_at_equal_bytes():
    """The point of paging: at equal pool bytes, a mixed-length workload
    admits >= 2x the concurrent requests of the fixed-slot engine."""
    cfg, _ = _setup(n_layers=2)
    kv8 = _kv8(cfg)
    max_len, block_size, n_slots = 256, 16, 2
    contiguous = M.init_caches(cfg, n_slots, max_len, quant=kv8)
    budget = E.kv_cache_bytes(contiguous)
    pool_probe = PagedKVPool(cfg, 2, block_size, quant=kv8)
    per_block = E.kv_cache_bytes(pool_probe.caches) // 2
    n_blocks = budget // per_block
    pool = PagedKVPool(cfg, n_blocks, block_size, quant=kv8)
    assert E.kv_cache_bytes(pool.caches) <= budget

    rng = np.random.default_rng(0)
    admitted = 0
    while True:     # mixed short/long requests, FCFS until the pool is dry
        ln = int(rng.integers(8, 65))
        need = pool.blocks_for(ln)
        if need > pool.free_blocks:
            break
        pool.alloc(need)
        admitted += 1
    assert admitted >= 2 * n_slots, (admitted, n_slots)


# ---------------------------------------------------------------------------
# Prefix cache + copy-on-write (engine level)
# ---------------------------------------------------------------------------

def test_prefix_cache_shares_blocks_and_cow_on_divergence():
    """Two live requests over the same 12-token prefix (8 = one full
    block + 4 = a partial tail at block_size=8): the second request must
    acquire BOTH cached blocks (full block refcount 2 while both run)
    and, because its continuation diverges inside the shared partial
    block, copy-on-write it before writing its suffix.  Outputs must
    match a cold cache-less run token for token."""
    cfg, params = _setup(n_layers=2)
    kv8 = _kv8(cfg)
    rng = np.random.default_rng(5)
    shared = rng.integers(0, cfg.vocab, (12,), dtype=np.int32)
    p2 = np.concatenate([shared, [3, 5, 8]]).astype(np.int32)

    eng = E.Engine(params, cfg, n_slots=4, max_len=32, quant=kv8,
                   paged=True, block_size=8)
    r1 = E.Request(prompt=shared.copy(), max_new_tokens=6)
    r2 = E.Request(prompt=p2.copy(), max_new_tokens=4)
    eng.submit(r1)
    eng.submit(r2)
    eng.step()          # both admitted in one admit pass: r1 prefills +
    rep = eng.report()  # registers, r2 hits r1's blocks in the same call
    assert rep["prefix_hits"] == 1
    assert rep["prefix_hit_tokens"] == 12, rep["prefix_hit_tokens"]
    assert rep["shared_blocks"] >= 1 and rep["max_refcount"] >= 2, \
        "a full cached block must be mapped by both tables"
    assert rep["cow_copies"] == 1, \
        "divergence inside the shared partial tail must copy-on-write"
    eng.run()
    eng.pool.validate(check_contents=True)

    for proto in (r1, r2):
        cold = E.Engine(params, cfg, n_slots=4, max_len=32, quant=kv8,
                        paged=True, block_size=8, prefix_cache=False)
        rr = E.Request(prompt=proto.prompt.copy(),
                       max_new_tokens=proto.max_new_tokens)
        cold.submit(rr)
        cold.run()
        assert rr.out == proto.out, (rr.out, proto.out)


def test_prefix_cache_warm_restart_after_finish():
    """A duplicate prompt submitted after the first request finished
    must hit the released (LRU-cached) blocks and produce the same
    greedy tokens -- the serving analogue of §4.2's never-re-move rule:
    resident packed planes are reused, not recomputed."""
    cfg, params = _setup(n_layers=2)
    kv8 = _kv8(cfg)
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab, (12,), dtype=np.int32)
    eng = E.Engine(params, cfg, n_slots=4, max_len=32, quant=kv8,
                   paged=True, block_size=4)
    a = E.Request(prompt=prompt.copy(), max_new_tokens=4)
    eng.submit(a)
    eng.run()
    assert eng.report()["cached_blocks"] > 0, \
        "released blocks must park in the LRU cache, not the free list"
    b = E.Request(prompt=prompt.copy(), max_new_tokens=4)
    eng.submit(b)
    eng.run()
    rep = eng.report()
    assert rep["prefix_hits"] >= 1 and rep["prefix_hit_tokens"] >= 8
    assert b.out == a.out, (b.out, a.out)
    eng.pool.validate(check_contents=True)


def test_preemption_warm_restart_reproducible_at_temperature():
    """ISSUE 3 satellite: preempted-then-resumed requests must
    reproduce the same *sampled* tokens.  Sampling is keyed by
    (request seed, output index) through SequenceState.sample_rng, so a
    contended pool (preemptions + warm restarts) and an uncontended one
    draw identical streams."""
    cfg, params = _setup(n_layers=2)
    kv8 = _kv8(cfg)

    def run(n_blocks):
        rng = np.random.default_rng(7)
        eng = E.Engine(params, cfg, max_len=32, quant=kv8, paged=True,
                       block_size=4, n_blocks=n_blocks, max_batch=4)
        reqs = [E.Request(prompt=rng.integers(0, cfg.vocab, (6,),
                                              dtype=np.int32),
                          max_new_tokens=8, temperature=0.8, seed=i)
                for i in range(3)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        assert all(r.done and r.error is None for r in reqs)
        return [r.out for r in reqs], eng

    out_small, eng_small = run(7)
    assert eng_small.scheduler.n_preemptions > 0, \
        "the 6-usable-block pool must be contended"
    assert eng_small.pool.n_hit_tokens > 0, \
        "re-admission must warm-restart from the preempted blocks"
    out_big, _ = run(40)
    assert out_small == out_big, \
        "preemption must not change sampled outputs (per-request RNG)"


def test_empty_prompt_rejected_cleanly():
    """An empty prompt has no position to take logits from: it must be
    rejected at submit, not crash the suffix prefill mid-run."""
    cfg, params = _setup(n_layers=2)
    eng = E.Engine(params, cfg, max_len=32, quant=_kv8(cfg), paged=True,
                   block_size=4)
    empty = E.Request(prompt=np.array([], np.int32), max_new_tokens=4)
    ok = E.Request(prompt=np.arange(5, dtype=np.int32), max_new_tokens=2)
    eng.submit(empty)
    eng.submit(ok)
    eng.run()
    assert empty.done and empty.error and "empty prompt" in empty.error
    assert ok.done and ok.error is None and len(ok.out) == 2


def test_default_seeds_give_diverse_samples_per_request():
    """Without an explicit Request.seed the engine assigns a distinct
    stream per request: identical prompts at temperature > 0 must not
    collapse onto identical completions."""
    cfg, params = _setup(n_layers=2)
    eng = E.Engine(params, cfg, max_len=32, quant=_kv8(cfg), paged=True,
                   block_size=4)
    prompt = np.arange(6, dtype=np.int32)
    reqs = [E.Request(prompt=prompt.copy(), max_new_tokens=8,
                      temperature=2.0) for _ in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert len({tuple(r.out) for r in reqs}) > 1, \
        "identical prompts drew from one shared RNG stream"
    assert len({r.seed for r in reqs}) == 3


def test_suffix_prefill_writes_bit_identical_planes():
    """The block-table suffix prefill (cached_len=0 -> the whole prompt
    is the suffix) must land byte-identical packed planes in the pool
    as the PR-2 contiguous-prefill-then-copy path (write_prefill).
    Quantization is per-token, so the two write paths differ only in
    routing."""
    cfg, params = _setup(n_layers=2)
    kv8 = _kv8(cfg)
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab, (11,), dtype=np.int32)

    # new path: block-table suffix prefill into an empty pool
    eng = E.Engine(params, cfg, n_slots=2, max_len=32, quant=kv8,
                   paged=True, block_size=4)
    eng.submit(E.Request(prompt=prompt.copy(), max_new_tokens=1))
    eng.scheduler.admit(eng._paged_prefill)
    new_blocks = list(eng.scheduler.running[0].blocks)

    # old path: contiguous B=1 prefill + verbatim plane copy
    old = E.Engine(params, cfg, n_slots=2, max_len=32, quant=kv8,
                   paged=True, block_size=4, prefix_cache=False)
    old_blocks = old.pool.alloc(old.pool.blocks_for(len(prompt)))
    _, one = old._bucketed_prefill(prompt)
    old.pool.write_prefill(one, old_blocks, len(prompt))

    assert len(new_blocks) == len(old_blocks) == 3
    for (nc, stacked), (oc, _) in zip(eng.pool._attn_caches(),
                                      old.pool._attn_caches()):
        for key in ("k", "v", "k_scale", "v_scale", "pos"):
            for j, (nb, ob) in enumerate(zip(new_blocks, old_blocks)):
                # compare only slots holding real tokens: tail-block pad
                # slots legitimately differ (dropped writes vs verbatim
                # copy of the bucketed cache's quantized pads)
                n = min((j + 1) * 4, len(prompt)) - j * 4
                n_leaf = nc[key][:, nb, :n] if stacked else nc[key][nb, :n]
                o_leaf = oc[key][:, ob, :n] if stacked else oc[key][ob, :n]
                np.testing.assert_array_equal(np.asarray(n_leaf),
                                              np.asarray(o_leaf),
                                              err_msg=key)


def test_paged_engine_moe_and_window_arch():
    """Paged decode on an SWA + MoE arch (mixtral family): ring-free
    paging with window masking by absolute position."""
    cfg, params = _setup("mixtral-8x7b", n_layers=2)
    kv8 = _kv8(cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, (5 + i,), dtype=np.int32)
               for i in range(3)]
    out_c, _ = _run_engine(params, cfg, prompts, quant=kv8, max_new=4)
    out_p, _ = _run_engine(params, cfg, prompts, quant=kv8, max_new=4,
                           paged=True, block_size=8)
    assert out_p == out_c


# ---------------------------------------------------------------------------
# Sliding-window reclaim (window < max_len) -- ISSUE 5 tentpole
# ---------------------------------------------------------------------------

def test_windowed_paged_token_identical_and_reclaims():
    """`mixtral-8x7b` smoke with window < max_len: the paged engine must
    (a) greedy-decode token-identically to the contiguous ring engine at
    equal kv_bits, (b) return out-of-window blocks to the pool *during*
    the generation (report's window_reclaimed), and (c) hold a
    steady-state table bounded by ~window/block_size + 1 blocks."""
    cfg, params = _setup("mixtral-8x7b", n_layers=2, window=8)
    kv8 = _kv8(cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, (5 + i,), dtype=np.int32)
               for i in range(2)]
    out_c, _ = _run_engine(params, cfg, prompts, quant=kv8, max_new=18)
    eng = E.Engine(params, cfg, n_slots=2, max_len=32, quant=kv8,
                   paged=True, block_size=4)
    reqs = [E.Request(prompt=p.copy(), max_new_tokens=18) for p in prompts]
    for r in reqs:
        eng.submit(r)
    max_live = 0
    while eng.step():
        live = max((len(s.blocks) for s in eng.scheduler.running),
                   default=0)
        max_live = max(max_live, live)
    assert all(r.done for r in reqs)
    assert [r.out for r in reqs] == out_c, \
        "window reclaim must not change the tokens (masking already " \
        "hid the reclaimed blocks)"
    rep = eng.report()
    assert rep["window_reclaimed"] > 0, \
        "a 23-token generation at window=8 must return dead blocks"
    assert rep["free_blocks"] == rep["n_usable"]
    # steady state: in-window blocks + the write-target block
    assert max_live <= 8 // 4 + 1, max_live
    eng.pool.validate(check_contents=False)


def test_windowed_paged_preemption_still_token_identical():
    """Preempting a windowed request after its table rolled (prefix
    blocks already reclaimed) must recompute the exact same tokens: the
    re-prefill writes the whole chain again and re-reclaims."""
    cfg, params = _setup("mixtral-8x7b", n_layers=2, window=8)
    kv8 = _kv8(cfg)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, (6,), dtype=np.int32)
               for _ in range(3)]
    out_small, eng_small = _run_engine(
        params, cfg, prompts, quant=kv8, max_new=12,
        paged=True, block_size=4, n_blocks=8, max_batch=4)
    assert eng_small.scheduler.n_preemptions > 0, \
        "7-usable-block pool with 3 growing requests must preempt"
    out_big, _ = _run_engine(
        params, cfg, prompts, quant=kv8, max_new=12,
        paged=True, block_size=4, n_blocks=40, max_batch=4)
    assert out_small == out_big
    assert eng_small.pool.free_blocks == eng_small.pool.n_usable


def test_window_reclaim_spares_shared_prefix_blocks():
    """Reclaim goes through the refcount path: a block out of MY window
    but still mapped by another request's table must survive for that
    reader -- only my reference drops."""
    cfg, _ = _setup("mixtral-8x7b", n_layers=2, window=8)
    from repro.serving.scheduler import Scheduler
    pool = PagedKVPool(cfg, n_blocks=20, block_size=4, quant=_kv8(cfg))
    # tail_compaction off: this test stages a STRADDLING shared block
    # (compaction would release it at admission before b arrives --
    # covered by the compaction suite); here we pin the pre-compaction
    # layout to prove block-granular reclaim is refcount-safe
    sch = Scheduler(pool, max_len=64, max_batch=4,
                    tail_compaction=False)

    def stub_prefill(seq, tokens):
        seq.length = len(tokens)
        seq.last_tok = 1
        if not seq.req.out:
            seq.req.out.append(1)

    base = np.arange(12, dtype=np.int32)
    a = E.Request(prompt=base.copy(), max_new_tokens=20)
    b = E.Request(prompt=base[:10].copy(), max_new_tokens=2)  # stays in-window
    sch.submit(a)
    sch.submit(b)
    sch.admit(stub_prefill)
    seq_a, seq_b = sch.running
    shared = set(seq_a.blocks) & set(seq_b.blocks)
    assert shared, "same-prefix admissions must share prefix blocks"
    # grow a alone until the shared blocks fall out of a's window (b, at
    # 10 resident tokens, reclaims nothing)
    for _ in range(8):
        sch.ensure_append_capacity()
        seq_a.length += 1
        seq_a.req.out.append(1)
    sch.reclaim_out_of_window()
    assert seq_a.freed_prefix >= 3, seq_a.freed_prefix
    assert seq_b.freed_prefix == 0
    rolled = [blk for blk in shared if blk not in seq_a.blocks]
    assert rolled, "a's dead prefix included shared blocks"
    for blk in rolled:
        assert pool.refcount(blk) >= 1 and blk in seq_b.blocks, \
            "b still maps the block: reclaim may only drop a's reference"
    pool.validate()
    for s in list(sch.running):
        sch.finish(s)
    assert pool.free_blocks == pool.n_usable


# ---------------------------------------------------------------------------
# State slot pool: ssm / hybrid / enc-dec through Engine(paged=True)
# ---------------------------------------------------------------------------

def _token_identity(name, *, quant_fn=None, max_new=5, **red):
    cfg = get_config(name).reduced(**red)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    quant = quant_fn(cfg) if quant_fn else None
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, (5 + i,), dtype=np.int32)
               for i in range(3)]
    out_c, _ = _run_engine(params, cfg, prompts, quant=quant,
                           max_new=max_new)
    out_p, eng = _run_engine(params, cfg, prompts, quant=quant,
                             max_new=max_new, paged=True, block_size=4)
    assert out_p == out_c, (name, out_p, out_c)
    rep = eng.report()
    assert rep["used_state_slots"] == 0 and rep["free_state_slots"] > 0
    eng.pool.validate()
    return eng


def test_paged_engine_serves_ssm_through_slot_pool():
    """Pure-SSM arch: no blocks at all, per-request conv+state rows in
    the slot pool; greedy decode token-identical to the contiguous
    engine (slot addressing is memory management, not math)."""
    eng = _token_identity("mamba2-130m")
    assert not eng.pool.needs_blocks
    assert eng.pool.free_blocks == eng.pool.n_usable  # untouched


def test_paged_engine_serves_hybrid_blocks_plus_slots():
    """Hybrid (jamba-style, attn_every=2 so the smoke config really
    interleaves): attention layers page KV blocks, mamba layers ride
    the slot pool, one scheduler owns both."""
    eng = _token_identity("jamba-1.5-large-398b", quant_fn=_kv8,
                          n_layers=2, attn_every=2)
    assert eng.pool.needs_blocks and eng.pool.slots is not None
    assert eng.pool.free_blocks == eng.pool.n_usable


def test_paged_engine_serves_encdec_cross_slots():
    """Enc-dec (audio): decoder self-attention KV pages in blocks, the
    projected cross-K/V lives in slot rows filled at prefill and
    replayed every decode step."""
    eng = _token_identity("seamless-m4t-medium", quant_fn=_kv8)
    assert eng.pool.needs_blocks and eng.pool.slots is not None


# ---------------------------------------------------------------------------
# Property sweep: random scheduler walks at window < max_len
# ---------------------------------------------------------------------------

class _WalkReq:
    """Minimal stand-in for engine.Request (identity the scheduler needs)."""
    def __init__(self, prompt, max_new_tokens):
        self.prompt = prompt
        self.max_new_tokens = max_new_tokens
        self.temperature = 0.0
        self.out = []
        self.done = False
        self.error = None


def _walk_stub_prefill(seq, tokens):
    seq.length = len(tokens)
    if seq.req.out:
        seq.last_tok = seq.req.out[-1]
    else:
        seq.last_tok = int(tokens[-1] * 31 % 97)
        seq.req.out.append(seq.last_tok)


def _check_windowed(pool, sch, window):
    """Pool invariants + the reclaim contract: after a reclaim point, no
    running request holds a block whose tokens are ALL out of its
    window, and (external refcount model) a block's refcount equals the
    number of running tables mapping it -- shared-prefix reclaim drops
    exactly the reclaimer's reference."""
    from collections import Counter
    pool.validate()
    bs = pool.block_size
    for s in sch.running:
        for i, _ in enumerate(s.blocks):
            logical = s.freed_prefix + i
            last_pos = (logical + 1) * bs - 1
            assert last_pos > s.length - window, \
                (f"request holds fully-out-of-window block: logical "
                 f"{logical} ends at {last_pos}, length {s.length}, "
                 f"window {window}")
    model = Counter(int(b) for s in sch.running for b in s.blocks)
    actual = {b: r for b, r in pool._ref.items() if r > 0}
    assert dict(model) == actual, (dict(model), actual)


def _windowed_walk(ops, lengths, max_news, *, window=8, prefix_cache=True):
    cfg = get_config("mixtral-8x7b").reduced(n_layers=2, window=window)
    kv8 = dataclasses.replace(cfg.quant, w_bits=None, kv_bits=8)
    pool = PagedKVPool(cfg, n_blocks=9, block_size=4, quant=kv8,
                       prefix_cache=prefix_cache)
    from repro.serving.scheduler import Scheduler
    sch = Scheduler(pool, max_len=32, max_batch=4)
    # prompts drawn from two base chains so prefixes collide often
    bases = [np.arange(24, dtype=np.int32),
             np.concatenate([np.arange(8),
                             np.arange(50, 66)]).astype(np.int32)]
    for i, op in enumerate(ops):
        ln = 1 + lengths[i % len(lengths)] % 20
        if op == 0:                                    # submit + admit
            base = bases[i % 2]
            sch.submit(_WalkReq(base[:ln].copy(),
                                1 + max_news[i % len(max_news)] % 16))
            sch.admit(_walk_stub_prefill)
        elif op == 1 and sch.running:                  # one decode step
            sch.ensure_append_capacity()   # reclaims, then allocates
            for s in list(sch.running):
                tok = int((s.length * 13 + 7) % 97)
                s.last_tok = tok
                s.req.out.append(tok)
                s.length += 1
                if len(s.req.out) >= s.req.max_new_tokens \
                        or s.length >= sch.max_len - 1:
                    sch.finish(s)
        elif op == 2 and sch.running:                  # preempt youngest
            sch.preempt(max(sch.running, key=lambda s: s.admitted_at))
            sch.admit(_walk_stub_prefill)
        elif op == 3 and sch.running:                  # finish oldest
            sch.finish(min(sch.running, key=lambda s: s.admitted_at))
        sch.reclaim_out_of_window()        # the step's reclaim point
        _check_windowed(pool, sch, window)
    for s in list(sch.running):                        # drain
        sch.finish(s)
    _check_windowed(pool, sch, window)
    assert pool.free_blocks == pool.n_usable


@settings(max_examples=30, deadline=None)
@given(ops=st.lists(st.integers(0, 3), min_size=4, max_size=40),
       lengths=st.lists(st.integers(0, 1000), min_size=1, max_size=8),
       max_news=st.lists(st.integers(0, 1000), min_size=1, max_size=8))
def test_property_windowed_walk_keeps_invariants(ops, lengths, max_news):
    """ISSUE 5 satellite: random admit/decode/preempt walks with
    window < max_len hold the reclaim + refcount invariants at every
    step, with the prefix cache sharing blocks across the walk."""
    _windowed_walk(ops, lengths, max_news)


@settings(max_examples=15, deadline=None)
@given(ops=st.lists(st.integers(0, 3), min_size=4, max_size=30),
       lengths=st.lists(st.integers(0, 1000), min_size=1, max_size=8),
       max_news=st.lists(st.integers(0, 1000), min_size=1, max_size=8))
def test_property_windowed_walk_no_prefix_cache(ops, lengths, max_news):
    """Same walk with the prefix cache off: reclaimed blocks go straight
    back to the free list (PR-2 reclamation + window rolling)."""
    _windowed_walk(ops, lengths, max_news, prefix_cache=False)


def test_ssm_slot_exhaustion_queues_fcfs():
    """More requests than state slots: admission must wait for a slot
    (FCFS), not crash or starve -- every request still completes."""
    cfg = get_config("mamba2-130m").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    eng = E.Engine(params, cfg, n_slots=2, max_len=32, paged=True,
                   block_size=4, max_batch=2)     # 2 state slots
    rng = np.random.default_rng(9)
    reqs = [E.Request(prompt=rng.integers(0, cfg.vocab, (4 + i,),
                                          dtype=np.int32),
                      max_new_tokens=3) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done and r.error is None for r in reqs)
    assert all(len(r.out) == 3 for r in reqs)
    assert eng.pool.slots.free_slots == eng.pool.slots.n_slots
