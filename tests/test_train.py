"""Training-stack tests: schedules, int8 optimizer state, checkpointing,
bit-exact restart, preemption recovery, straggler watchdog, convergence."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as CM
from repro.configs import get_config
from repro.data.pipeline import DataSpec, batch_at
from repro.optim.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   wsd_schedule)
from repro.train.trainer import SimulatedPreemption, TrainConfig, Trainer


def _tiny(tmp, **tkw):
    cfg = get_config("llama3-8b").reduced(n_layers=2)
    spec = DataSpec(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=1)
    tcfg = TrainConfig(num_steps=12, ckpt_dir=str(tmp), ckpt_every=5,
                       warmup_steps=2, peak_lr=1e-3, **tkw)
    return cfg, spec, tcfg


# --- schedules --------------------------------------------------------------

def test_wsd_schedule_shape():
    s = wsd_schedule(peak_lr=1.0, warmup_steps=10, total_steps=100,
                     decay_frac=0.2)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6          # warmup done
    assert abs(float(s(50)) - 1.0) < 1e-6          # stable
    assert float(s(85)) < 0.5                      # decaying
    assert abs(float(s(100)) - 0.01) < 1e-3        # floor


# --- int8 optimizer state ----------------------------------------------------

def test_int8_adamw_tracks_fp32():
    """int8 m/v AdamW must follow the f32 trajectory closely on a quadratic."""
    key = jax.random.PRNGKey(0)
    w0 = {"w": jax.random.normal(key, (16, 64))}
    target = jax.random.normal(jax.random.PRNGKey(1), (16, 64))

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2)

    trajs = {}
    for bits in (None, 8):
        cfg = AdamWConfig(state_bits=bits, weight_decay=0.0)
        p, st = dict(w0), adamw_init(w0, cfg)
        losses = []
        for _ in range(60):
            g = jax.grad(loss)(p)
            p, st, _ = adamw_update(g, st, p, lr=3e-2, cfg=cfg)
            losses.append(float(loss(p)))
        trajs[bits] = losses
    assert trajs[8][-1] < trajs[None][0] * 0.2     # actually optimizes
    # quantized trajectory tracks f32 within a small factor
    assert trajs[8][-1] < max(trajs[None][-1] * 3.0, 1e-3)


def test_int8_state_memory_is_quarter():
    w = {"w": jnp.zeros((128, 256), jnp.float32)}
    st8 = adamw_init(w, AdamWConfig(state_bits=8))
    stf = adamw_init(w, AdamWConfig())
    bytes8 = sum(x.size * x.dtype.itemsize
                 for x in jax.tree.leaves((st8.m, st8.v, st8.m_scale,
                                           st8.v_scale)))
    bytesf = sum(x.size * x.dtype.itemsize
                 for x in jax.tree.leaves((stf.m, stf.v)))
    assert bytes8 < bytesf * 0.27                  # ~2.03 vs 8 bytes/param


# --- data pipeline -----------------------------------------------------------

def test_data_is_stateless_and_sharded():
    spec = DataSpec(vocab=100, seq_len=16, global_batch=8, seed=3)
    b1, b2 = batch_at(spec, 5), batch_at(spec, 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(batch_at(spec, 6)["tokens"], b1["tokens"])
    # shards partition the RNG stream deterministically
    s0 = DataSpec(vocab=100, seq_len=16, global_batch=8, seed=3,
                  num_shards=2, shard=0)
    s1 = DataSpec(vocab=100, seq_len=16, global_batch=8, seed=3,
                  num_shards=2, shard=1)
    a, b = batch_at(s0, 5), batch_at(s1, 5)
    assert a["tokens"].shape == (4, 16)
    assert not np.array_equal(a["tokens"], b["tokens"])
    # labels are tokens shifted by one
    full = batch_at(spec, 0)
    assert np.array_equal(full["tokens"][:, 1:], full["labels"][:, :-1])


# --- checkpoint manager ------------------------------------------------------

def test_checkpoint_roundtrip_and_keep(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": [jnp.ones((2,), jnp.int8), jnp.zeros((), jnp.int32)]}
    for step in (1, 2, 3, 4):
        CM.save_tree(tree, str(tmp_path), step, keep=2)
    assert CM.all_steps(str(tmp_path)) == [3, 4]
    out, meta = CM.restore_tree(tree, str(tmp_path))
    assert meta["step"] == 4
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_checkpoint_tmp_dir_never_visible(tmp_path):
    CM.save_tree({"x": jnp.ones(3)}, str(tmp_path), 7)
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


# --- trainer: restart & fault tolerance --------------------------------------

def test_restart_is_bit_exact(tmp_path):
    """Train 12 steps straight vs 6 + restart + 6: identical final params."""
    cfg, spec, tcfg = _tiny(tmp_path / "a")
    t1 = Trainer(cfg, tcfg, spec, async_ckpt=False)
    state_full, hist_full = t1.run(resume=False)

    cfg2, spec2, tcfg2 = _tiny(tmp_path / "b")
    tcfg2.num_steps = 6
    t2 = Trainer(cfg2, tcfg2, spec2, async_ckpt=False)
    t2.run(resume=False)
    tcfg3 = TrainConfig(**{**tcfg2.__dict__, "num_steps": 12})
    t3 = Trainer(cfg2, tcfg3, spec2, async_ckpt=False)
    state_resumed, hist_resumed = t3.run(resume=True)

    np.testing.assert_array_equal(hist_full[6:], hist_resumed)
    for a, b in zip(jax.tree.leaves(state_full["params"]),
                    jax.tree.leaves(state_resumed["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_preemption_recovery(tmp_path):
    cfg, spec, tcfg = _tiny(tmp_path, preempt_at=7)
    t = Trainer(cfg, tcfg, spec, async_ckpt=False)
    with pytest.raises(SimulatedPreemption):
        t.run(resume=False)
    assert t.ckpt.latest_step() == 7
    # recover: fresh trainer resumes from step 7 and completes
    tcfg2 = TrainConfig(**{**tcfg.__dict__, "preempt_at": None})
    t2 = Trainer(cfg, tcfg2, spec, async_ckpt=False)
    state, hist = t2.run(resume=True)
    assert len(hist) == 12 - 7
    assert int(state["opt"].step) == 12


def test_straggler_watchdog_detects_slow_steps():
    cfg, spec, tcfg = _tiny("/tmp/unused_wd")
    tcfg.ckpt_every = 0
    t = Trainer(cfg, tcfg, spec, async_ckpt=False)
    for i, dt in enumerate([0.1] * 10 + [0.9] + [0.1] * 5):
        t._watchdog(i, dt)
    assert len(t.straggler_events) == 1
    assert t.straggler_events[0]["step"] == 10


def test_microbatch_equals_full_batch(tmp_path):
    """Gradient accumulation (A=2) must match the single-batch step."""
    cfg, spec, tcfg = _tiny(tmp_path / "m1")
    tcfg.num_steps = 3
    tcfg.ckpt_every = 0
    tA = Trainer(cfg, tcfg, spec, async_ckpt=False)
    sA, hA = tA.run(resume=False)
    tcfgB = TrainConfig(**{**tcfg.__dict__, "microbatches": 2,
                           "ckpt_dir": str(tmp_path / "m2")})
    tB = Trainer(cfg, tcfgB, spec, async_ckpt=False)
    sB, hB = tB.run(resume=False)
    np.testing.assert_allclose(hA, hB, rtol=2e-2)
    for a, b in zip(jax.tree.leaves(sA["params"]),
                    jax.tree.leaves(sB["params"])):
        np.testing.assert_allclose(np.asarray(a, dtype=np.float32),
                                   np.asarray(b, dtype=np.float32),
                                   rtol=0.05, atol=1e-2)


def test_loss_decreases_on_learnable_stream(tmp_path):
    cfg, spec, tcfg = _tiny(tmp_path)
    tcfg.num_steps = 30
    tcfg.ckpt_every = 0
    t = Trainer(cfg, tcfg, spec, async_ckpt=False)
    _, hist = t.run(resume=False)
    assert np.mean(hist[-5:]) < np.mean(hist[:5]) - 0.3
