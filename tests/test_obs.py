"""Observability subsystem: metrics registry, request traces, hooks.

ISSUE 7 added ``src/repro/obs``: a dependency-free metrics registry
(Prometheus text exposition), per-request lifecycle span trees with
Chrome/Perfetto export, and the ``ServingObs`` facade the engine /
scheduler / pool report through.  This suite is its contract:

* **Registry semantics**: get-or-create declaration, kind-conflict
  rejection, label children, cumulative histogram exposition, and the
  exact Prometheus text format ``render()`` promises.
* **Span discipline**: double-begin / end-unopened / double-close all
  raise; ``finish`` auto-closes; ``validate`` rejects events outside
  the request envelope.
* **Trace integrity under churn**: a scheduler walk mixing submits,
  chunked steps, cancellations and preemptions -- plus engine-level
  cancellation and deadline expiry -- leaves EVERY submitted request
  with a balanced span tree (``Tracer.validate_all``), mirroring the
  zero-leak block/slot invariants in tests/test_continuous_batching.py
  on the metrics side: the registry's accounting must agree with the
  pool's ``validate()``-checked state after the drain.
* **Token identity off**: ``metrics=None`` (the default) produces
  byte-identical outputs to an instrumented run and leaves no trace
  state on the requests -- observability is a pure overlay.
* **Deterministic timestamps**: under an injected clock two identical
  runs export identical Perfetto JSON.
"""

import json

import numpy as np
import pytest

from repro.configs import get_config
from repro.obs import (NULL_OBS, LATENCY_BUCKETS, Counter, Histogram,
                       MetricsRegistry, ServingObs, Tracer)
from repro.obs.trace import RequestTrace
from repro.serving.paged_cache import PagedKVPool
from repro.serving.scheduler import Scheduler


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

def test_counter_renders_prometheus_total_convention():
    r = MetricsRegistry()
    c = r.counter("repro_test_events", "things that happened")
    c.inc()
    c.inc(2)
    text = r.render()
    assert "# HELP repro_test_events things that happened" in text
    assert "# TYPE repro_test_events counter" in text
    assert "repro_test_events_total 3" in text
    assert r.value("repro_test_events") == 3


def test_labeled_counter_children_are_cached_and_rendered_sorted():
    r = MetricsRegistry()
    c = r.counter("repro_test_finished", "by reason",
                  labelnames=("reason",))
    a = c.labels(reason="length")
    assert c.labels(reason="length") is a      # cached child
    a.inc()
    c.labels(reason="cancelled").inc(2)
    text = r.render()
    i_c = text.index('repro_test_finished_total{reason="cancelled"} 2')
    i_l = text.index('repro_test_finished_total{reason="length"} 1')
    assert i_c < i_l                           # children sorted by value
    assert r.value("repro_test_finished", reason="cancelled") == 2
    with pytest.raises(ValueError):
        c.labels(kind="length")                # wrong label name


def test_registry_get_or_create_and_kind_conflict():
    r = MetricsRegistry()
    c1 = r.counter("repro_test_x", "first")
    c2 = r.counter("repro_test_x", "ignored duplicate help")
    assert c1 is c2
    with pytest.raises(ValueError):
        r.gauge("repro_test_x", "now a gauge")
    with pytest.raises(ValueError):
        r.counter("repro_test_x", "relabeled", labelnames=("a",))


def test_histogram_cumulative_buckets_sum_count_percentile():
    r = MetricsRegistry()
    h = r.histogram("repro_test_lat", "latency", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):
        h.observe(v)
    text = h.render()
    assert 'repro_test_lat_bucket{le="0.1"} 1' in text
    assert 'repro_test_lat_bucket{le="1"} 3' in text    # cumulative
    assert 'repro_test_lat_bucket{le="10"} 4' in text
    assert 'repro_test_lat_bucket{le="+Inf"} 5' in text
    assert "repro_test_lat_sum 56.05" in text
    assert "repro_test_lat_count 5" in text
    assert h.percentile(50) == 1.0             # upper edge of q-bucket
    assert h.percentile(99) == float("inf")    # overflow bucket
    assert Histogram("empty", "").percentile(50) == 0.0


def test_gauge_set_inc_dec():
    r = MetricsRegistry()
    g = r.gauge("repro_test_occ", "occupancy")
    g.set(0.5)
    g.inc(0.25)
    g.dec(0.5)
    assert g.value == pytest.approx(0.25)
    snap = r.snapshot()
    assert snap["repro_test_occ"] == pytest.approx(0.25)


def test_snapshot_flattens_all_kinds():
    r = MetricsRegistry()
    r.counter("repro_test_c").inc(2)
    r.histogram("repro_test_h", buckets=LATENCY_BUCKETS).observe(0.5)
    snap = r.snapshot()
    assert snap["repro_test_c_total"] == 2
    assert snap["repro_test_h_sum"] == pytest.approx(0.5)
    assert snap["repro_test_h_count"] == 1


# ---------------------------------------------------------------------------
# Span discipline and trace validation
# ---------------------------------------------------------------------------

def test_span_double_begin_end_unopened_double_close_raise():
    tr = RequestTrace(0, "r", t_submit=0.0)
    tr.begin("queued", 1.0)
    with pytest.raises(RuntimeError, match="already open"):
        tr.begin("queued", 2.0)
    with pytest.raises(RuntimeError, match="unopened"):
        tr.end("decode", 2.0)
    tr.end("queued", 2.0)
    with pytest.raises(RuntimeError, match="unopened"):
        tr.end("queued", 3.0)                  # popped: cannot end twice
    s = tr.spans[0]
    with pytest.raises(RuntimeError, match="closed twice"):
        s.close(4.0)


def test_finish_autocloses_open_spans_and_validate_passes():
    tr = RequestTrace(0, "r", t_submit=0.0)
    tr.begin("queued", 0.0)
    tr.end("queued", 1.0)
    tr.begin("running", 1.0)
    tr.begin("decode", 2.0)                    # both left open on purpose
    tr.token(3.0, 0, 17)
    with pytest.raises(AssertionError, match="not finished"):
        tr.validate()
    tr.finish(4.0, "cancelled")
    tr.validate()                              # balanced now
    assert tr.ttft == pytest.approx(3.0)
    assert all(not s.open for s in tr.spans)
    assert tr.finish_reason == "cancelled"


def test_validate_rejects_events_outside_envelope():
    tr = RequestTrace(0, "r", t_submit=1.0)
    tr.complete("chunk_prefill", 0.2, 0.5)     # before submission
    tr.finish(2.0, "length")
    with pytest.raises(AssertionError, match="outside envelope"):
        tr.validate()
    tr2 = RequestTrace(1, "r", t_submit=0.0)
    tr2.instant("token", 5.0)
    tr2.finish(2.0, "length")
    with pytest.raises(AssertionError, match="outside envelope"):
        tr2.validate()


def test_tracer_export_perfetto_schema():
    tc = Tracer()
    tr = tc.start(0.0, "req A")
    tr.begin("queued", 0.0)
    tr.end("queued", 0.001)
    tr.complete("chunk_prefill", 0.001, 0.002, dict(index=0, tokens=4))
    tr.token(0.003, 0, 42)
    tr.finish(0.004, "length")
    doc = tc.export()
    json.loads(json.dumps(doc))                # serializable round-trip
    evs = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    roots = [e for e in evs if e["ph"] == "X" and e["name"] == "request"]
    assert len(roots) == 1
    assert roots[0]["ts"] == 0.0 and roots[0]["dur"] == \
        pytest.approx(4000.0)                  # seconds -> microseconds
    assert roots[0]["args"]["finish_reason"] == "length"
    assert roots[0]["args"]["n_tokens"] == 1
    names = {e["name"] for e in evs}
    assert {"process_name", "thread_name", "queued",
            "chunk_prefill", "token"} <= names
    inst = [e for e in evs if e["ph"] == "i"]
    assert inst and all(e["s"] == "t" for e in inst)


# ---------------------------------------------------------------------------
# Trace integrity + metrics accounting under scheduler churn
# ---------------------------------------------------------------------------

class _Tick:
    """Deterministic strictly-increasing clock (1ms per read)."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 0.001
        return self.t


class _WalkReq:
    def __init__(self, prompt, max_new_tokens):
        self.prompt = prompt
        self.max_new_tokens = max_new_tokens
        self.temperature = 0.0
        self.out = []
        self.done = False
        self.error = None
        self.finish_reason = None


def _obs_stub_step(sch, chunk, obs):
    """One chunked engine step without the model, with the engine's hook
    placement: admit, plan, capacity, then advance exactly the way
    Engine._advance reports chunks and decode starts."""
    sch.admit_chunked()
    plan = sch.plan_step()
    plan = sch.ensure_step_capacity(plan)
    t0 = obs.t()
    for seq, n in plan:
        if seq.prefilling:
            seq.length += n
            sch.register_progress(seq)
            obs.on_chunk(seq, n, t0, obs.t())
            if seq.length < len(seq.pending):
                continue
            seq.pending = None
            obs.on_decode_begin(seq)
            if seq.req.out:                    # warm resume
                seq.last_tok = seq.req.out[-1]
                continue
            tok = int((seq.length * 13 + 7) % 97)
            seq.last_tok = tok
            seq.req.out.append(tok)
            obs.on_token(seq.req, tok)
        else:
            tok = int((seq.length * 13 + 7) % 97)
            seq.last_tok = tok
            seq.req.out.append(tok)
            obs.on_token(seq.req, tok)
            seq.length += 1
        if len(seq.req.out) >= seq.req.max_new_tokens \
                or seq.length >= sch.max_len - 1:
            sch.finish(seq)


def test_walk_every_request_traces_balanced_and_metrics_agree():
    """Deterministic churn walk: submits, chunked steps, cancellations
    (running + waiting) and preemptions, then a full drain.  Every
    request's span tree must validate, and the registry's accounting
    must mirror the pool's zero-leak state."""
    import dataclasses
    cfg = get_config("mixtral-8x7b").reduced(n_layers=2, window=8)
    kv8 = dataclasses.replace(cfg.quant, w_bits=None, kv_bits=8)
    obs = ServingObs(clock=_Tick())
    pool = PagedKVPool(cfg, n_blocks=9, block_size=4, quant=kv8,
                       metrics=obs.registry)
    sch = Scheduler(pool, max_len=32, max_batch=4, chunk_tokens=3,
                    obs=obs)
    base = np.arange(24, dtype=np.int32)
    reqs = []

    def submit(n, max_new):
        req = _WalkReq(base[:n].copy(), max_new)
        obs.on_submit(req)                     # the engine's duty
        sch.submit(req)
        reqs.append(req)
        return req

    a = submit(20, 4)
    b = submit(18, 4)                          # shares a's chain
    _obs_stub_step(sch, 3, obs)
    _obs_stub_step(sch, 3, obs)
    assert any(s.prefilling for s in sch.running)
    c = submit(6, 6)
    # preempt the youngest mid-walk: its trace reopens "queued"
    victim = max(sch.running, key=lambda s: s.admitted_at)
    sch.preempt(victim)
    _obs_stub_step(sch, 3, obs)
    assert sch.cancel(b)                       # cancel wherever b lives
    d = submit(2, 30)                          # long decode, then cancel
    for _ in range(6):
        _obs_stub_step(sch, 3, obs)
    assert sch.cancel(d)
    steps = 0
    while sch.has_work:
        _obs_stub_step(sch, 3, obs)
        steps += 1
        assert steps < 500
    # every submitted request finished with a balanced span tree
    assert all(r.done for r in reqs)
    obs.tracer.validate_all()
    assert len(obs.tracer.traces) == len(reqs)
    for r in (b, d):
        assert r._trace.finish_reason == "cancelled"
    assert victim.req._trace.n_preemptions >= 1
    q_spans = [s for s in victim.req._trace.spans if s.name == "queued"]
    assert len(q_spans) >= 2, "preemption must re-open the queued span"
    # metrics mirror the pool's zero-leak invariants
    pool.validate()
    assert pool.free_blocks == pool.n_usable
    reg = obs.registry
    pool.sync_gauges()
    assert reg.value("repro_pool_blocks", state="used") == 0
    assert reg.value("repro_pool_blocks", state="free") \
        + reg.value("repro_pool_blocks", state="cached") == pool.n_usable
    # lifecycle accounting: everything submitted was finished, queue
    # waits were observed once per admission, tokens balance.  The
    # finished{reason} label set is Request.FINISH_REASONS -- summing
    # over THE enum (not a hand list) proves no reason escapes it
    from repro.serving.engine import Request
    fin = reg.get("repro_requests_finished")
    assert set(fin._children) <= {(rs,) for rs in Request.FINISH_REASONS}, \
        (set(fin._children), Request.FINISH_REASONS)
    n_fin = sum(reg.value("repro_requests_finished", reason=rs)
                for rs in Request.FINISH_REASONS)
    assert reg.value("repro_requests_submitted") == len(reqs) == n_fin
    hq = reg.get("repro_request_queue_wait_seconds")
    assert hq.count == reg.value("repro_sched_admissions")
    n_toks = sum(len(r.out) for r in reqs)
    assert reg.value("repro_engine_tokens") == n_toks
    emitted = sum(1 for r in reqs if r.out)
    assert reg.get("repro_request_ttft_seconds").count == emitted
    assert reg.get("repro_request_intertoken_seconds").count \
        == n_toks - emitted
    assert reg.value("repro_sched_preemptions") == sch.n_preemptions >= 1


def test_scheduler_without_obs_runs_on_null_obs():
    """A standalone scheduler (no engine) must run against NULL_OBS and
    untraced requests without error -- hooks tolerate both."""
    cfg = get_config("mamba2-130m").reduced()
    pool = PagedKVPool(cfg, n_blocks=4, block_size=4, n_state_slots=4,
                       prefix_cache=False)
    sch = Scheduler(pool, max_len=32, max_batch=4, chunk_tokens=3)
    assert sch.obs is NULL_OBS
    req = _WalkReq(np.arange(5, dtype=np.int32), 2)
    sch.submit(req)
    while sch.has_work:
        _obs_stub_step(sch, 3, NULL_OBS)
    assert req.done and not hasattr(req, "_trace")
    # a TRACED scheduler still accepts untraced requests (e.g. mixed
    # callers): hooks fall through on the missing _trace
    obs = ServingObs(clock=_Tick())
    sch2 = Scheduler(PagedKVPool(cfg, n_blocks=4, block_size=4,
                                 n_state_slots=4, prefix_cache=False,
                                 metrics=obs.registry),
                     max_len=32, max_batch=4, chunk_tokens=3, obs=obs)
    req2 = _WalkReq(np.arange(5, dtype=np.int32), 2)
    sch2.submit(req2)                          # no on_submit first
    while sch2.has_work:
        _obs_stub_step(sch2, 3, obs)
    assert req2.done and not obs.tracer.traces


# ---------------------------------------------------------------------------
# Engine integration (real model, reduced configs)
# ---------------------------------------------------------------------------

def _engine(cfg, params, **kw):
    from repro.serving import engine as E
    return E.Engine(params, cfg, n_slots=2, max_len=32, **kw)


def _setup(name="mamba2-130m", **red):
    import jax
    from repro.models import model as M
    cfg = get_config(name).reduced(**red)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    return cfg, params


def _mk_reqs(cfg, lens, max_new=4, seed=3, **kw):
    from repro.serving import engine as E
    rng = np.random.default_rng(seed)
    return [E.Request(prompt=rng.integers(0, cfg.vocab, (n,),
                                          dtype=np.int32),
                      max_new_tokens=max_new, **kw) for n in lens]


def test_metrics_disabled_is_token_identical_and_traceless():
    """``metrics=None`` (the default) must be a pure overlay: the same
    tokens as an instrumented run, NULL_OBS on the engine, and no trace
    state attached to the requests."""
    cfg, params = _setup()
    outs = {}
    for on in (False, True):
        eng = _engine(cfg, params, paged=True, block_size=4,
                      chunk_tokens=3, metrics=(True if on else None))
        reqs = _mk_reqs(cfg, (5, 9, 14))
        for r in reqs:
            eng.submit(r)
        eng.run()
        assert all(r.done and r.error is None for r in reqs)
        outs[on] = [r.out for r in reqs]
        if on:
            eng.obs.tracer.validate_all()
            assert all(hasattr(r, "_trace") for r in reqs)
        else:
            assert eng.obs is NULL_OBS
            assert not any(hasattr(r, "_trace") for r in reqs)
    assert outs[False] == outs[True]


def test_engine_mixed_workload_traces_prometheus_and_report_agree():
    """The acceptance scenario: a mixed workload (prefix sharing, a
    mid-flight cancellation) on the instrumented chunked engine yields
    (a) valid Perfetto JSON, (b) a Prometheus snapshot whose counters
    exactly match the pool's validate()-checked accounting and the
    legacy report() dict, (c) balanced traces for every request."""
    import dataclasses
    cfg, params = _setup("mixtral-8x7b", n_layers=2, window=8)
    kv8 = dataclasses.replace(cfg.quant, w_bits=None, kv_bits=8)
    eng = _engine(cfg, params, quant=kv8, paged=True, block_size=4,
                  chunk_tokens=3, metrics=True, clock=_Tick())
    base = np.arange(20, dtype=np.int32)
    from repro.serving import engine as E
    a = E.Request(prompt=base.copy(), max_new_tokens=4)
    b = E.Request(prompt=base[:14].copy(), max_new_tokens=4)  # shares a
    c = E.Request(prompt=base[:16].copy(), max_new_tokens=8)
    eng.submit(a)
    eng.run()                                  # a's chain lands + parks
    eng.submit(b)                              # re-acquires a's blocks
    eng.submit(c)
    eng.step()
    eng.step()
    assert eng.cancel(c)                       # mid-flight cancellation
    eng.run()
    assert a.done and b.done and a.finish_reason == "length"

    reg = eng.obs.registry
    eng.obs.tracer.validate_all()
    assert len(eng.obs.tracer.traces) == 3
    assert c._trace.finish_reason == "cancelled"
    doc = eng.obs.tracer.export()
    json.loads(json.dumps(doc))                # valid Perfetto JSON
    assert len([e for e in doc["traceEvents"]
                if e["name"] == "request"]) == 3

    # registry == legacy report() == pool properties, one source of truth
    rep = eng.report()
    assert reg.value("repro_pool_cow") == eng.pool.n_cow \
        == rep["cow_copies"]
    assert reg.value("repro_pool_prefix_hits") == eng.pool.n_prefix_hits \
        == rep["prefix_hits"]
    assert reg.value("repro_pool_prefix_hit_tokens") \
        == eng.pool.n_hit_tokens == rep["prefix_hit_tokens"]
    assert reg.value("repro_sched_preemptions") \
        == eng.scheduler.n_preemptions == rep["preemptions"]
    assert reg.value("repro_engine_prefill_tokens") \
        == rep["chunk_tokens_processed"] == eng.chunk_tokens_processed
    assert rep["prefix_hit_tokens"] > 0, "b must share a's chain"

    # lifecycle balance
    assert reg.value("repro_requests_submitted") == 3
    assert reg.value("repro_requests_finished", reason="length") == 2
    assert reg.value("repro_requests_finished", reason="cancelled") == 1
    n_toks = sum(len(r.out) for r in (a, b, c))
    assert reg.value("repro_engine_tokens") == n_toks
    emitted = sum(1 for r in (a, b, c) if r.out)
    assert reg.get("repro_request_ttft_seconds").count == emitted
    assert reg.get("repro_request_intertoken_seconds").count \
        == n_toks - emitted
    assert reg.value("repro_engine_steps") == eng.steps > 0

    # the Prometheus text itself carries the counters
    text = reg.render()
    assert 'repro_requests_finished_total{reason="cancelled"} 1' in text
    assert f"repro_engine_tokens_total {n_toks}" in text
    # drained: the used-blocks gauge agrees with the empty pool
    assert reg.value("repro_pool_blocks", state="used") == 0
    assert eng.pool.free_blocks == eng.pool.n_usable


def test_timeout_and_rejection_traces_close_balanced():
    """Deadline expiry (running mid-prefill AND still waiting) and
    submit-time rejection must all close their traces with the right
    finish_reason -- no dangling spans on any exit path."""
    import dataclasses
    cfg, params = _setup("mixtral-8x7b", n_layers=2, window=8)
    kv8 = dataclasses.replace(cfg.quant, w_bits=None, kv_bits=8)
    t = [0.0]
    eng = _engine(cfg, params, quant=kv8, paged=True, block_size=4,
                  max_batch=2, chunk_tokens=3, metrics=True,
                  clock=lambda: t[0])
    rng = np.random.default_rng(6)
    from repro.serving import engine as E
    a = E.Request(prompt=rng.integers(0, cfg.vocab, (4,), dtype=np.int32),
                  max_new_tokens=6)
    b = E.Request(prompt=rng.integers(0, cfg.vocab, (24,), dtype=np.int32),
                  max_new_tokens=2, timeout=5.0)
    c = E.Request(prompt=rng.integers(0, cfg.vocab, (4,), dtype=np.int32),
                  max_new_tokens=2, timeout=7.0)
    big = E.Request(prompt=rng.integers(0, cfg.vocab, (40,),
                                        dtype=np.int32),
                    max_new_tokens=2)          # prompt >= max_len - 1
    for r in (a, b, c, big):
        eng.submit(r)
    assert big.done and big.finish_reason == "rejected"
    for _ in range(3):                         # b mid-prefill, c waiting
        assert eng.step()
    assert any(s.req is b and s.prefilling
               for s in eng.scheduler.running)
    t[0] = 10.0
    eng.run()
    assert a.done and a.finish_reason == "length"
    for r in (b, c):
        assert r.finish_reason == "timeout" and r.out == []
    eng.obs.tracer.validate_all()
    reg = eng.obs.registry
    assert reg.value("repro_requests_finished", reason="timeout") == 2
    assert reg.value("repro_requests_finished", reason="rejected") == 1
    assert big._trace.finish_reason == "rejected"
    assert b._trace.finish_reason == "timeout"
    # timeout/rejection emitted nothing: no token instants on them
    for r in (b, c, big):
        assert r._trace.token_times == []
    assert eng.pool.free_blocks == eng.pool.n_usable


def test_contiguous_engine_is_instrumented_too():
    """The same hooks cover the contiguous (non-paged) engine: traces
    balance through queue-cancel, lane expiry, and length finish."""
    cfg, params = _setup()
    t = [0.0]
    eng = _engine(cfg, params, metrics=True, clock=lambda: t[0])
    from repro.serving import engine as E
    rng = np.random.default_rng(12)
    mk = lambda n, **kw: E.Request(
        prompt=rng.integers(0, cfg.vocab, (4,), dtype=np.int32),
        max_new_tokens=n, **kw)
    a, b, c = mk(6), mk(8, timeout=5.0), mk(2)
    eng.submit(a), eng.submit(b), eng.submit(c)
    assert eng.cancel(c)                       # straight from the queue
    eng.step()
    t[0] = 10.0
    eng.run()
    assert a.finish_reason == "length" and b.finish_reason == "timeout"
    eng.obs.tracer.validate_all()
    reg = eng.obs.registry
    assert reg.value("repro_requests_submitted") == 3
    assert reg.value("repro_requests_finished", reason="cancelled") == 1
    assert reg.value("repro_requests_finished", reason="timeout") == 1
    assert reg.value("repro_engine_tokens") \
        == len(a.out) + len(b.out)


def test_identical_runs_export_identical_timelines():
    """Full determinism under an injected clock: two engines driven by
    identical tick clocks over identical workloads must export equal
    Perfetto documents and equal metric snapshots."""
    cfg, params = _setup()
    docs, snaps = [], []
    for _ in range(2):
        eng = _engine(cfg, params, paged=True, block_size=4,
                      chunk_tokens=3, metrics=True, clock=_Tick())
        reqs = _mk_reqs(cfg, (5, 9), max_new=3)
        for r in reqs:
            eng.submit(r)
        eng.run()
        docs.append(eng.obs.tracer.export())
        snaps.append({k: v for k, v in eng.obs.registry.snapshot()
                      .items() if "step_seconds" not in k})
    assert docs[0] == docs[1]
    assert snaps[0] == snaps[1]


def test_engine_adopts_obs_clock_and_binds_its_own():
    """Clock unification (satellite 2): an engine given a ServingObs
    with a clock adopts it for deadlines; an engine given its own clock
    binds that clock onto the obs facade."""
    cfg, params = _setup()
    tick = _Tick()
    obs = ServingObs(clock=tick)
    eng = _engine(cfg, params, metrics=obs)
    assert eng._clock is tick and eng.obs is obs
    t = [0.0]
    reg = MetricsRegistry()
    eng2 = _engine(cfg, params, metrics=reg, clock=lambda: t[0])
    assert eng2.obs.clock() == 0.0 and eng2.obs.registry is reg
    with pytest.raises(TypeError):
        _engine(cfg, params, metrics=object())
