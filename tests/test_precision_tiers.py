"""Precision-tier property suite: the load-adaptive nested-precision
policy and its serving integration (ISSUE 10).

The claims under test, each locked by a property sweep (hypothesis,
skipping cleanly without the dev extra) plus a deterministic pinned
twin that always runs in tier-1:

* **Floor clamp**: :func:`repro.serving.engine.tier_bits` never grants
  below ``min(floor, requested)`` and never above ``max_bits``,
  whatever the queue depth.
* **Monotone degrade / full recovery**: deeper queues never grant MORE
  bits, and a drained queue grants exactly the request's choice.
* **Precision never changes mid-request**: the engine freezes the
  grant at first admission; preemption storms re-admit at the SAME
  bits even though the queue depth changed.
* **Pool exactness while tiers shift**: the chaos walk's
  exact-refcount / zero-leak invariants hold with a precision policy
  installed and the prefix cache salted per width, under injected
  faults, preemption, and cancellation.
* **Config validation**: QuantConfig rejects out-of-range bits,
  ``nested_bits`` without/above ``w_bits``, and a floor above the
  served width with descriptive ``ValueError`` at construction -- not
  deep inside pack/dispatch.
"""

import dataclasses
from collections import Counter

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:    # property tests skip (not error) without hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_config
from repro.models import model as M
from repro.models.config import QuantConfig
from repro.serving import engine as E
from repro.serving.engine import tier_bits
from repro.serving.faults import FaultInjector
from repro.serving.paged_cache import PagedKVPool
from repro.serving.scheduler import Scheduler


# ---------------------------------------------------------------------------
# tier_bits: the pure policy
# ---------------------------------------------------------------------------

def _check_tier(requested, max_bits, floor, depth, pressure):
    bits = tier_bits(requested, max_bits=max_bits, floor=floor,
                     queue_depth=depth, pressure=pressure)
    top = min(requested or max_bits, max_bits)
    assert 1 <= bits <= max_bits
    assert bits <= top, "the policy never grants above the request"
    if floor is not None:
        assert bits >= min(floor, top), "floor clamp violated"
    else:
        assert bits == top, "no floor -> no degradation"
    # monotone in depth: one more waiting request never grants more
    more = tier_bits(requested, max_bits=max_bits, floor=floor,
                     queue_depth=depth + 1, pressure=pressure)
    assert more <= bits, "deeper queue granted MORE bits"
    # full recovery at zero depth
    drained = tier_bits(requested, max_bits=max_bits, floor=floor,
                        queue_depth=0, pressure=pressure)
    assert drained == top, "drained queue must grant the request's choice"


@settings(max_examples=200, deadline=None)
@given(requested=st.one_of(st.none(), st.integers(1, 12)),
       max_bits=st.integers(1, 8),
       floor=st.one_of(st.none(), st.integers(1, 8)),
       depth=st.integers(0, 200),
       pressure=st.integers(1, 16))
def test_tier_bits_properties(requested, max_bits, floor, depth, pressure):
    _check_tier(requested, max_bits, floor, depth, pressure)


def test_tier_bits_pinned():
    """Deterministic twin of the property sweep + exact spot checks."""
    for requested in (None, 1, 2, 4, 8, 12):
        for max_bits in (2, 4, 8):
            for floor in (None, 2, 4, 8):
                for depth in (0, 1, 4, 7, 8, 40, 200):
                    _check_tier(requested, max_bits, floor, depth, 4)
    assert tier_bits(None, max_bits=8) == 8
    assert tier_bits(4, max_bits=8) == 4
    assert tier_bits(12, max_bits=8) == 8          # capped at the store
    assert tier_bits(8, max_bits=8, floor=4, queue_depth=8) == 6
    assert tier_bits(8, max_bits=8, floor=4, queue_depth=999) == 4
    # an explicit request below the floor is honored (the floor bounds
    # degradation, not choice)
    assert tier_bits(2, max_bits=8, floor=4, queue_depth=999) == 2


# ---------------------------------------------------------------------------
# Engine integration: the grant freezes at first admission
# ---------------------------------------------------------------------------

def test_precision_frozen_across_preemption():
    """A tiny pool forces preemption + warm re-admission; every
    re-admission must re-grant the SAME bits the first admission froze,
    even though the queue depth (the policy input) keeps changing --
    precision never changes mid-request."""
    cfg = get_config("llama3-8b").reduced(n_layers=2)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    qcfg = QuantConfig(w_bits=8, a_bits=8, kv_bits=8, precision_floor=2)
    qparams = M.quantize_params(params, qcfg)
    # pool sized so concurrent decodes evict each other
    eng = E.Engine(qparams, cfg, quant=qcfg, paged=True, n_slots=4,
                   max_len=64, block_size=4, n_blocks=6, max_batch=4)
    grants: dict = {}
    inner = eng.scheduler.precision_policy
    assert inner is not None

    def recording(req):
        bits = inner(req)
        grants.setdefault(id(req), []).append(bits)
        return bits

    eng.scheduler.precision_policy = recording
    rng = np.random.default_rng(5)
    reqs = [E.Request(prompt=rng.integers(0, cfg.vocab, (6,),
                                          dtype=np.int32),
                      max_new_tokens=8) for _ in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done and r.finish_reason == "length" for r in reqs)
    assert eng.scheduler.n_preemptions > 0, \
        "pool was meant to be small enough to force preemption"
    for r in reqs:
        seen = grants[id(r)]
        assert len(set(seen)) == 1, \
            f"precision changed across admissions: {seen}"
        assert seen[0] == r._tier_bits


def test_mixed_tier_lanes_complete_and_count():
    """Mixed premium/bulk lanes complete under one engine and the
    per-width token counters account for every emitted token."""
    cfg = get_config("llama3-8b").reduced(n_layers=2)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    qcfg = QuantConfig(w_bits=8, a_bits=8, kv_bits=8)
    qparams = M.quantize_params(params, qcfg)
    eng = E.Engine(qparams, cfg, quant=qcfg, paged=True, n_slots=4,
                   max_len=64, block_size=16, metrics=True)
    rng = np.random.default_rng(7)
    precs = [8, 8, 4, 2]
    reqs = [E.Request(prompt=rng.integers(0, cfg.vocab, (6,),
                                          dtype=np.int32),
                      max_new_tokens=3, precision=b) for b in precs]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done and len(r.out) == 3 for r in reqs)
    rend = eng.pool.metrics.render()
    counts = {}
    for line in rend.splitlines():
        if line.startswith("repro_engine_precision_total{"):
            label, val = line.split("}")
            counts[label.split('"')[1]] = int(float(val))
    assert counts == {"8": 6, "4": 3, "2": 3}, counts


# ---------------------------------------------------------------------------
# Pool exactness while tiers shift (chaos-walk invariants, salted cache)
# ---------------------------------------------------------------------------

class _WalkReq:
    """Minimal stand-in for engine.Request (identity the scheduler
    needs, plus the nested-precision request knob)."""
    def __init__(self, prompt, max_new_tokens, precision=None):
        self.prompt = prompt
        self.max_new_tokens = max_new_tokens
        self.precision = precision
        self.temperature = 0.0
        self.out = []
        self.done = False
        self.error = None
        self.finish_reason = None


def _check_pool(pool, sch):
    """Exactness under chaos: pool internals self-consistent and every
    block's refcount equals the number of running tables mapping it."""
    pool.validate()
    model = Counter(int(b) for s in sch.running for b in s.blocks)
    actual = {b: r for b, r in pool._ref.items() if r > 0}
    assert dict(model) == actual, (dict(model), actual)


def _tier_stub_step(sch):
    """One model-free engine step (the chaos suite's stub) that also
    asserts the tier invariant: a running sequence's precision never
    drifts from the bits its request froze."""
    try:
        sch.admit_chunked()
        plan = sch.ensure_step_capacity(sch.plan_step())
    except RuntimeError:
        return
    for seq in sch.running:
        assert seq.precision == seq.req._tier_bits, \
            (seq.precision, seq.req._tier_bits)
    for seq, n in plan:
        if seq.req.done:
            continue
        if seq.prefilling:
            seq.length += n
            sch.register_progress(seq)
            if seq.length < len(seq.pending):
                continue
            seq.pending = None
            if seq.req.out:                     # warm resume
                seq.last_tok = seq.req.out[-1]
                continue
            tok = int((seq.length * 13 + 7) % 97)
            seq.last_tok = tok
            seq.req.out.append(tok)
        else:
            tok = int((seq.length * 13 + 7) % 97)
            seq.last_tok = tok
            seq.req.out.append(tok)
            seq.length += 1
        if len(seq.req.out) >= seq.req.max_new_tokens \
                or seq.length >= sch.max_len - 1:
            sch.finish(seq)


def _tier_walk(ops, lengths, max_news, precs, chunk, fseed):
    """Random chunked traffic with a LIVE tier policy (grants shift
    with queue depth), the prefix cache salted per width, and memory
    faults armed: refcounts stay exact after every op, grants respect
    the floor, frozen grants never change, and the drain leaks zero
    blocks."""
    faults = FaultInjector(fseed, p_alloc_fail=0.1, p_forced_evict=0.25,
                           p_admit_race=0.25, p_preempt_storm=0.1)
    cfg = get_config("mixtral-8x7b").reduced(n_layers=2, window=8)
    qcfg = dataclasses.replace(cfg.quant, w_bits=8, kv_bits=8,
                               precision_floor=2)
    pool = PagedKVPool(cfg, n_blocks=9, block_size=4, quant=qcfg,
                       faults=faults)

    def policy(req):
        frozen = getattr(req, "_tier_bits", None)
        if frozen is not None:
            return frozen
        bits = tier_bits(getattr(req, "precision", None),
                         max_bits=qcfg.w_bits, floor=qcfg.precision_floor,
                         queue_depth=len(sch.waiting))
        req._tier_bits = bits
        return bits

    sch = Scheduler(pool, max_len=32, max_batch=4, chunk_tokens=chunk,
                    precision_policy=policy)
    bases = [np.arange(24, dtype=np.int32),
             np.concatenate([np.arange(8),
                             np.arange(50, 66)]).astype(np.int32)]
    submitted = []
    for i, op in enumerate(ops):
        ln = 1 + lengths[i % len(lengths)] % 20
        if op == 0:                                    # submit
            p = precs[i % len(precs)]
            req = _WalkReq(bases[i % 2][:ln].copy(),
                           1 + max_news[i % len(max_news)] % 16,
                           precision=p if p else None)
            submitted.append(req)
            sch.submit(req)
        elif op in (1, 2):                             # one engine step
            _tier_stub_step(sch)
        elif op == 3:                                  # cancel anywhere
            reqs = [s.req for s in sch.running] + list(sch.waiting)
            if reqs:
                assert sch.cancel(reqs[i % len(reqs)])
        elif op == 4 and sch.running:                  # preempt youngest
            sch.preempt(max(sch.running, key=lambda s: s.admitted_at))
        _check_pool(pool, sch)
    steps = 0
    while sch.has_work:                                # drain
        _tier_stub_step(sch)
        _check_pool(pool, sch)
        steps += 1
        assert steps < 8000, "drain did not terminate under faults"
    assert pool.free_blocks == pool.n_usable, "tier walk leaked blocks"
    for req in submitted:
        granted = getattr(req, "_tier_bits", None)
        if granted is None:
            continue                                   # never admitted
        top = min(req.precision or qcfg.w_bits, qcfg.w_bits)
        assert granted >= min(qcfg.precision_floor, top), \
            "grant below the floor clamp"
        assert granted <= top


@settings(max_examples=25, deadline=None)
@given(ops=st.lists(st.integers(0, 4), min_size=4, max_size=40),
       lengths=st.lists(st.integers(0, 1000), min_size=1, max_size=8),
       max_news=st.lists(st.integers(0, 1000), min_size=1, max_size=8),
       precs=st.lists(st.integers(0, 8), min_size=1, max_size=6),
       chunk=st.integers(1, 6),
       fseed=st.integers(0, 1000))
def test_pool_exact_under_tier_shifts(ops, lengths, max_news, precs,
                                      chunk, fseed):
    _tier_walk(ops, lengths, max_news, precs, chunk, fseed)


def test_pool_exact_under_tier_shifts_pinned():
    """Deterministic twin: heavy submit/step/cancel/preempt mix with
    mixed requested widths, three fault seeds."""
    rng = np.random.default_rng(123)
    for fseed in (3, 11, 42):
        ops = list(rng.integers(0, 5, 36))
        _tier_walk(ops, list(rng.integers(0, 1000, 8)),
                   list(rng.integers(0, 1000, 8)),
                   [0, 8, 4, 2, 6], chunk=3, fseed=fseed)


# ---------------------------------------------------------------------------
# QuantConfig validation (fail fast, descriptive)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kwargs,match", [
    (dict(w_bits=0), "w_bits"),
    (dict(w_bits=9), "w_bits"),
    (dict(a_bits=0), "a_bits"),
    (dict(kv_bits=12), "kv_bits"),
    (dict(nested_bits=4), "nested_bits requires w_bits"),
    (dict(w_bits=4, nested_bits=6), "exceeds"),
    (dict(w_bits=8, nested_bits=0), "nested_bits"),
    (dict(w_bits=8, precision_floor=9), "precision_floor"),
    (dict(w_bits=8, nested_bits=4, precision_floor=6), "precision_floor"),
    (dict(w_bits=4, variant="turbo"), "variant"),
])
def test_quant_config_rejects_bad_settings(kwargs, match):
    with pytest.raises(ValueError, match=match):
        QuantConfig(**kwargs)


def test_quant_config_accepts_valid_nested_settings():
    q = QuantConfig(w_bits=8, a_bits=8, kv_bits=4, nested_bits=4,
                    precision_floor=2)
    assert q.serve_bits == 4
    assert QuantConfig(w_bits=8).serve_bits == 8
    assert QuantConfig().serve_bits is None
