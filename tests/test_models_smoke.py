"""Per-architecture smoke tests: reduced config, one forward + train step
on CPU, asserting output shapes and no NaNs (task spec deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.launch.specs import make_batch
from repro.models import model as M
from repro.models.config import QuantConfig

SEQ, BATCH = 32, 2


@pytest.fixture(scope="module", params=ARCHS)
def arch_setup(request):
    cfg = get_config(request.param).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_forward_shapes_and_finite(arch_setup):
    cfg, params = arch_setup
    batch = make_batch(cfg, BATCH, SEQ, "train")
    x, _, aux = M.forward(params, batch["tokens"], cfg,
                          positions=batch.get("positions"),
                          patch_embeds=batch.get("patch_embeds"),
                          frames=batch.get("frames"))
    assert x.shape == (BATCH, SEQ, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(x, dtype=np.float32))), cfg.name
    assert np.isfinite(float(aux))


def test_train_step_loss_and_grads_finite(arch_setup):
    cfg, params = arch_setup
    batch = make_batch(cfg, BATCH, SEQ, "train")

    @jax.jit
    def step(p):
        return jax.value_and_grad(lambda q: M.loss_fn(q, batch, cfg))(p)

    loss, grads = step(params)
    assert np.isfinite(float(loss)), cfg.name
    # loss should be near ln(vocab) for random init
    assert 0.5 * np.log(cfg.vocab) < float(loss) < 3 * np.log(cfg.vocab)
    leaves = jax.tree.leaves(grads)
    assert leaves, "no grads"
    for g in leaves:
        assert np.all(np.isfinite(np.asarray(g, dtype=np.float32))), cfg.name


def test_quantized_forward_close_to_bf16(arch_setup):
    """Serving-time W8A8 quantization must track the bf16 forward."""
    cfg, params = arch_setup
    q8 = QuantConfig(w_bits=8, a_bits=8)
    qparams = M.quantize_params(params, q8)
    batch = make_batch(cfg, BATCH, SEQ, "train")
    kw = dict(positions=batch.get("positions"),
              patch_embeds=batch.get("patch_embeds"),
              frames=batch.get("frames"))
    x0, _, _ = M.forward(params, batch["tokens"], cfg, **kw)
    x1, _, _ = M.forward(qparams, batch["tokens"], cfg, quant=q8, **kw)
    a0 = np.asarray(x0, dtype=np.float32)
    a1 = np.asarray(x1, dtype=np.float32)
    assert np.all(np.isfinite(a1))
    rel = np.abs(a1 - a0).mean() / (np.abs(a0).mean() + 1e-9)
    assert rel < 0.15, (cfg.name, rel)


def test_paper_w2a8_forward_finite(arch_setup):
    """The arch's assigned ultra-low-bit config stays finite end to end."""
    cfg, params = arch_setup
    qcfg = cfg.quant
    qparams = M.quantize_params(params, qcfg)
    batch = make_batch(cfg, BATCH, SEQ, "train")
    x, _, _ = M.forward(qparams, batch["tokens"], cfg, quant=qcfg,
                        positions=batch.get("positions"),
                        patch_embeds=batch.get("patch_embeds"),
                        frames=batch.get("frames"))
    assert np.all(np.isfinite(np.asarray(x, dtype=np.float32))), cfg.name
