"""Property tests for the bipolar-INT format (paper §3.1) and packing (§4.1)."""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:    # property tests skip (not error) without hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.core import bipolar
from repro.kernels import ref

BITS = st.integers(min_value=1, max_value=8)


def odd_values(n_bits: int, shape, rng):
    m = bipolar.max_value(n_bits)
    return rng.choice(np.arange(-m, m + 1, 2), size=shape).astype(np.int32)


@given(n=BITS)
@settings(max_examples=8, deadline=None)
def test_representable_set_is_symmetric_odd(n):
    """Bipolar-INT represents exactly the 2^n odd ints in [-(2^n-1), 2^n-1]."""
    vals = np.arange(-(2**n - 1), 2**n, 2)
    assert len(vals) == 2**n
    assert np.array_equal(vals, -vals[::-1])            # symmetric range
    u = np.asarray(bipolar.encode(jnp.array(vals), n))
    assert u.min() == 0 and u.max() == 2**n - 1         # dense bit field
    back = np.asarray(bipolar.decode(jnp.array(u), n))
    assert np.array_equal(back, vals)


@given(n=BITS, seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_decompose_recover_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    v = odd_values(n, (5, 7), rng)
    planes = bipolar.decompose(jnp.array(v), n)
    assert planes.shape == (n, 5, 7)
    assert set(np.unique(np.asarray(planes))) <= {0, 1}
    rec = np.asarray(bipolar.recover(planes, n))
    assert np.array_equal(rec, v)


@given(n=BITS, seed=st.integers(0, 2**31 - 1),
       k=st.integers(1, 130))
@settings(max_examples=10, deadline=None)
def test_pack_unpack_roundtrip_any_k(n, seed, k):
    """§4.1 packing is lossless for any reduction length (incl. padding)."""
    rng = np.random.default_rng(seed)
    v = odd_values(n, (3, k), rng)
    planes = bipolar.decompose(jnp.array(v), n)
    padded = bipolar.pad_for_packing(planes, 1, pad_bit=1)
    packed = bipolar.pack_planes(padded, 1)
    assert packed.dtype == jnp.uint32
    assert packed.shape == (n, 3, bipolar.packed_words(k))
    unpacked = bipolar.unpack_planes(packed, 1, k)
    assert np.array_equal(np.asarray(unpacked), np.asarray(planes))


@given(n=BITS)
@settings(max_examples=8, deadline=None)
def test_packed_memory_is_exactly_n_bits_per_element(n):
    """The §4.1 layout stores an n-bit matrix in exactly n bits/element
    (modulo the 32-element word rounding) -- no 4/8-bit container waste."""
    m, k = 16, 256
    x = np.random.default_rng(0).standard_normal((m, k)).astype(np.float32)
    t = bipolar.quantize_pack(jnp.array(x), n, pack_axis=1, scale_axis=1)
    plane_bytes = int(np.prod(t.packed.shape)) * 4
    assert plane_bytes == n * m * k // 8
    # vs bf16 dense: 16/n compression on the matrix body
    assert t.nbytes_dense_bf16 / plane_bytes == 16 / n


@given(nw=st.integers(1, 7), nx=st.integers(1, 7),
       seed=st.integers(0, 2**31 - 1),
       k=st.integers(1, 100))
@settings(max_examples=12, deadline=None)
def test_apmm_formulations_bit_identical(nw, nx, seed, k):
    """exact == bit-serial (§3.2) == fused operand-recovery (NT layout)."""
    rng = np.random.default_rng(seed)
    aq = jnp.array(odd_values(nw, (6, k), rng))     # A (M, K)
    bq = jnp.array(odd_values(nx, (5, k), rng))     # B (N, K)
    y0 = np.asarray(ref.apmm_exact(aq, bq))
    assert np.array_equal(np.asarray(ref.apmm_bitserial(aq, bq, nw, nx)), y0)
    assert np.array_equal(np.asarray(ref.apmm_fused(aq, bq, nw, nx)), y0)


@given(nw=st.integers(1, 6), nx=st.integers(1, 6),
       seed=st.integers(0, 2**31 - 1), k=st.integers(1, 96))
@settings(max_examples=10, deadline=None)
def test_apmm_packed_matches_exact(nw, nx, seed, k):
    """Packed §4.1 layout reproduces the exact integer product (NT)."""
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((8, k)).astype(np.float32)   # activations (M,K)
    b = rng.standard_normal((6, k)).astype(np.float32)   # weights (N,K)
    sa = bipolar.absmax_scale(jnp.array(a), nx, axis=1)
    sb = bipolar.absmax_scale(jnp.array(b), nw, axis=1)
    aq = bipolar.quantize_values(jnp.array(a), nx, sa)
    bq = bipolar.quantize_values(jnp.array(b), nw, sb)
    y0 = np.asarray(ref.apmm_exact(aq, bq))
    at = bipolar.quantize_pack(jnp.array(a), nx, pack_axis=-1,
                               scale_axis=-1, pad_bit=0)
    bt = bipolar.quantize_pack(jnp.array(b), nw, pack_axis=-1,
                               scale_axis=-1, pad_bit=1)
    for fused in (True, False):
        y = np.asarray(ref.apmm_packed_ref(at, bt, fused=fused))
        assert np.array_equal(y, y0), (nw, nx, fused)


def test_binary_case_needs_no_correction_matrix():
    """1-bit bipolar W/X multiply exactly with a single 1-bit matmul --
    the APNN-TC J-matrix correction (paper §3.1) is unnecessary."""
    rng = np.random.default_rng(3)
    a = jnp.array(rng.choice([-1, 1], size=(8, 32)).astype(np.int32))
    b = jnp.array(rng.choice([-1, 1], size=(4, 32)).astype(np.int32))
    y = ref.apmm_bitserial(a, b, 1, 1)
    assert np.array_equal(np.asarray(y), np.asarray(a) @ np.asarray(b).T)


@given(n=st.integers(2, 8), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_quantize_error_bound(n, seed):
    """Symmetric absmax bipolar quantization error <= scale (odd-grid step 2)."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((64,)).astype(np.float32) * 3.0
    s = bipolar.absmax_scale(jnp.array(x), n)
    q = bipolar.quantize_values(jnp.array(x), n, s)
    err = np.abs(np.asarray(q) * np.asarray(s) - x)
    assert err.max() <= float(np.asarray(s).squeeze()) * 1.0001
