"""Continuous batching: chunked prefill + the async streaming engine.

ISSUE 6 rewrote the serving loop's liveness argument: admission no
longer prefills a whole prompt in one pass (stalling every decode for
O(prompt) and transiently demanding O(prompt) blocks) -- prompts stream
through the step loop ``chunk_tokens`` at a time, fused with the decode
bucket, and out-of-window blocks are reclaimed *between chunks*.  This
suite is the proof the new argument leans on:

* **Property walks** drive the real :class:`Scheduler` (stub execution,
  no model forward) through random submit/chunk/decode/cancel/preempt
  sequences and assert, after every step: (a) no decode is ever crowded
  out of a step -- the starvation bound; (b) the per-step prefill
  budget is saturated oldest-first; (c) block refcounts exactly match
  the running tables (external Counter model) and ``pool.validate()``
  holds; (d) windowed requests never hold more than the
  ``lifetime_need`` block bound; (e) cancellation -- mid-prefill
  included -- and the end-of-walk drain leak zero blocks and zero
  state slots.
* **Token identity**: chunked greedy decode at several chunk sizes
  (including non-divisors of block_size and window) is token-identical
  to the whole-prompt paged path and to the contiguous engine, across
  mixtral (window < max_len, fused mixed-Sq dispatch), mamba2
  (slot-state continuation) and jamba attn_every=2 (split hybrid path).
* **Async API**: ``on_token`` callbacks fire in emission order with the
  emitted ids, deadline expiry finishes with ``finish_reason='timeout'``
  and frees memory, and a cancelled request never sees another callback.
* **Liveness win**: a windowed prompt whose whole-prompt block need
  exceeds the pool is rejected by the old gate but served -- correctly
  -- by the chunked one.

Kernel-level mixed-Sq parity (decode rows riding a chunk lane's Sq>1
dispatch) lives in tests/kernels/test_paged_attention.py.
"""

import dataclasses
from collections import Counter

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:    # property tests skip (not error) without hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_config
from repro.models import model as M
from repro.serving import engine as E
from repro.serving.paged_cache import PagedKVPool
from repro.serving.scheduler import Scheduler


def _setup(name="llama3-8b", **red):
    cfg = get_config(name).reduced(**red)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    return cfg, params


def _kv8(cfg):
    return dataclasses.replace(cfg.quant, w_bits=None, kv_bits=8)


def _run(params, cfg, prompts, *, quant, max_new=4, **kw):
    eng = E.Engine(params, cfg, n_slots=2, max_len=32, quant=quant, **kw)
    reqs = [E.Request(prompt=p.copy(), max_new_tokens=max_new)
            for p in prompts]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done and r.error is None for r in reqs)
    return [r.out for r in reqs], eng


# ---------------------------------------------------------------------------
# Token identity: chunked == whole-prompt == contiguous, per family
# ---------------------------------------------------------------------------

def _chunked_identity(name, chunks, *, quant_fn=None, max_new=4, **red):
    """Greedy decode through three memory regimes must agree token for
    token: chunking changes *when* prompt KV lands, never what it is.
    Prompt lengths 5/9/14 straddle block (4) and chunk boundaries so
    partial tails, non-divisor chunks and the fused mixed decode+chunk
    steps all occur."""
    cfg = get_config(name).reduced(**red)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    quant = quant_fn(cfg) if quant_fn else None
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, (n,), dtype=np.int32)
               for n in (5, 9, 14)]
    out_c, _ = _run(params, cfg, prompts, quant=quant, max_new=max_new)
    out_w, _ = _run(params, cfg, prompts, quant=quant, max_new=max_new,
                    paged=True, block_size=4)
    assert out_w == out_c, (name, out_w, out_c)
    for ck in chunks:
        out_k, eng = _run(params, cfg, prompts, quant=quant,
                          max_new=max_new, paged=True, block_size=4,
                          chunk_tokens=ck)
        assert out_k == out_c, (name, ck, out_k, out_c)
        eng.pool.validate()
        assert eng.pool.free_blocks == eng.pool.n_usable
        if eng.pool.slots is not None:
            assert eng.pool.slots.free_slots == eng.pool.slots.n_slots
        rep = eng.report()
        assert rep["chunk_tokens"] == ck
        assert rep["chunk_tokens_processed"] > 0, \
            "prompts must have streamed through the chunked path"


def test_chunked_identity_mixtral_windowed():
    """Attention family at window(8) < max_len(32): chunks 3 and 6 divide
    neither block_size=4 nor the window, and the fused mixed-Sq dispatch
    carries decode lanes alongside chunk lanes once the first request
    starts decoding."""
    _chunked_identity("mixtral-8x7b", [3, 4, 6], quant_fn=_kv8,
                      n_layers=2, window=8)


def test_chunked_identity_mamba2():
    """Pure SSM: chunks continue the slot-resident conv tail + SSD state
    exactly where the previous chunk stopped (no pad tokens touch the
    recurrence)."""
    _chunked_identity("mamba2-130m", [3, 5])


def test_chunked_identity_jamba_hybrid():
    """Hybrid attn_every=2: attention layers write paged KV through the
    chunk's block table while mamba layers ride the state continuation
    -- the split (non-fused) mixed-step path."""
    _chunked_identity("jamba-1.5-large-398b", [3, 8], quant_fn=_kv8,
                      n_layers=2, attn_every=2)


# ---------------------------------------------------------------------------
# The liveness win: prompts longer than the pool, and the stall bound
# ---------------------------------------------------------------------------

def test_windowed_prompt_beyond_pool_only_serves_chunked():
    """A 40-token prompt needs blocks_for(43) = 11 blocks held at once
    under whole-prompt admission -- more than this 7-usable-block pool,
    so the old gate must reject it.  Chunked prefill peaks at
    blocks_for(window + chunk) + 2 = 5 blocks (the table rolls between
    chunks), so the same pool serves it -- with the same tokens the
    contiguous engine produces."""
    cfg, params = _setup("mixtral-8x7b", n_layers=2, window=8)
    kv8 = _kv8(cfg)
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab, (40,), dtype=np.int32)

    whole = E.Engine(params, cfg, n_slots=2, max_len=64, quant=kv8,
                     paged=True, block_size=4, n_blocks=8)
    r_w = E.Request(prompt=prompt.copy(), max_new_tokens=3)
    whole.submit(r_w)
    assert r_w.done and r_w.finish_reason == "rejected"
    assert "blocks" in r_w.error

    chunked = E.Engine(params, cfg, n_slots=2, max_len=64, quant=kv8,
                       paged=True, block_size=4, n_blocks=8,
                       chunk_tokens=4)
    r_c = E.Request(prompt=prompt.copy(), max_new_tokens=3)
    chunked.submit(r_c)
    chunked.run()
    assert r_c.done and r_c.error is None and len(r_c.out) == 3
    assert chunked.scheduler.n_rejections == 0
    chunked.pool.validate()
    assert chunked.pool.free_blocks == chunked.pool.n_usable

    # oracle: the contiguous engine at the same max_len
    eng = E.Engine(params, cfg, n_slots=2, max_len=64, quant=kv8)
    r_o = E.Request(prompt=prompt.copy(), max_new_tokens=3)
    eng.submit(r_o)
    eng.run()
    assert r_c.out == r_o.out, (r_c.out, r_o.out)


def test_decode_emits_every_step_while_long_prompt_prefills():
    """The acceptance bound, measured on the real engine: once a 30-token
    prompt starts streaming in, the already-decoding request still emits
    exactly one token on *every* engine step (zero stall steps), and the
    prompt work co-scheduled per step never exceeds the chunk budget."""
    cfg, params = _setup("mixtral-8x7b", n_layers=2, window=8)
    kv8 = _kv8(cfg)
    rng = np.random.default_rng(11)
    eng = E.Engine(params, cfg, n_slots=2, max_len=64, quant=kv8,
                   paged=True, block_size=4, chunk_tokens=3)
    a = E.Request(prompt=rng.integers(0, cfg.vocab, (5,), dtype=np.int32),
                  max_new_tokens=24)
    eng.submit(a)
    while not a.out:               # stream a's own prompt in, first token
        assert eng.step()
    b = E.Request(prompt=rng.integers(0, cfg.vocab, (30,), dtype=np.int32),
                  max_new_tokens=2)
    eng.submit(b)
    while not b.done:
        n_a = len(a.out)
        work = eng.chunk_tokens_processed
        assert eng.step()
        assert len(a.out) == n_a + 1, \
            "decode stalled while the long prompt prefilled"
        assert eng.chunk_tokens_processed - work <= 3, \
            "per-step prompt work exceeded the chunk budget"
    assert b.error is None and len(b.out) == 2
    eng.run()
    assert a.done and len(a.out) == 24
    assert eng.pool.free_blocks == eng.pool.n_usable


# ---------------------------------------------------------------------------
# Property walks: the scheduler under random chunked traffic
# ---------------------------------------------------------------------------

class _WalkReq:
    """Minimal stand-in for engine.Request (identity the scheduler needs)."""
    def __init__(self, prompt, max_new_tokens):
        self.prompt = prompt
        self.max_new_tokens = max_new_tokens
        self.temperature = 0.0
        self.out = []
        self.done = False
        self.error = None
        self.finish_reason = None


def _check_pool(pool, sch, *, held_bound=None):
    """The exactness invariants: pool internals are self-consistent, a
    block's refcount equals the number of running tables mapping it
    (external Counter model -- cancellation/preemption/reclaim drop
    exactly one reference each), windowed tables never exceed the
    submit-gate block bound, and every running stateful request holds
    exactly one slot."""
    pool.validate()
    if pool.needs_blocks:
        model = Counter(int(b) for s in sch.running for b in s.blocks)
        actual = {b: r for b, r in pool._ref.items() if r > 0}
        assert dict(model) == actual, (dict(model), actual)
        if held_bound is not None:
            for s in sch.running:
                assert len(s.blocks) <= held_bound, \
                    (len(s.blocks), held_bound, s.length)
    if pool.slots is not None:
        assert all(s.slot >= 0 for s in sch.running)
        assert pool.slots.free_slots \
            == pool.slots.n_slots - len(sch.running)


def _stub_step(sch, chunk):
    """One engine step without the model: admit, plan, assert the
    scheduling contract, make capacity, then advance exactly the way
    Engine._advance does (deterministic stub tokens)."""
    sch.admit_chunked()
    plan = sch.plan_step()
    # budget saturation, oldest-first: prefill work in the plan is
    # min(budget, total remaining), and the head of the prefill line
    # gets min(budget, its own remaining)
    pre = sum(n for s, n in plan if s.prefilling)
    rem = sum(len(s.pending) - s.length
              for s in sch.running if s.prefilling)
    assert pre == min(chunk, rem), (pre, chunk, rem)
    heads = sorted((s for s in sch.running if s.prefilling),
                   key=lambda s: s.admitted_at)
    if heads:
        got = dict((id(s), n) for s, n in plan if s.prefilling)
        want = min(chunk, len(heads[0].pending) - heads[0].length)
        assert got.get(id(heads[0]), 0) == want
    for s, n in plan:
        assert 1 <= n <= (chunk if s.prefilling else 1), (n, s.prefilling)

    plan = sch.ensure_step_capacity(plan)
    # the starvation bound: every request still running in decode phase
    # is in the step -- prompt streaming can never crowd a decode out
    planned = {id(s) for s, _ in plan}
    for s in sch.running:
        if not s.prefilling:
            assert id(s) in planned, "decode crowded out of a step"

    for seq, n in plan:
        if seq.prefilling:
            seq.length += n
            sch.register_progress(seq)
            if seq.length < len(seq.pending):
                continue
            seq.pending = None
            if seq.req.out:                     # warm resume
                seq.last_tok = seq.req.out[-1]
                continue
            tok = int((seq.length * 13 + 7) % 97)
            seq.last_tok = tok
            seq.req.out.append(tok)
        else:
            tok = int((seq.length * 13 + 7) % 97)
            seq.last_tok = tok
            seq.req.out.append(tok)
            seq.length += 1
        if len(seq.req.out) >= seq.req.max_new_tokens \
                or seq.length >= sch.max_len - 1:
            sch.finish(seq)


def _chunked_walk(ops, lengths, max_news, chunk, *, name="mixtral-8x7b",
                  window=8, prefix_cache=True):
    if name == "mamba2-130m":
        cfg = get_config(name).reduced()
        pool = PagedKVPool(cfg, n_blocks=4, block_size=4,
                           n_state_slots=4, prefix_cache=False)
    else:
        red = dict(n_layers=2, **(dict(window=window) if window else {}))
        cfg = get_config(name).reduced(**red)
        kv8 = dataclasses.replace(cfg.quant, w_bits=None, kv_bits=8)
        pool = PagedKVPool(cfg, n_blocks=9, block_size=4, quant=kv8,
                           prefix_cache=prefix_cache)
    sch = Scheduler(pool, max_len=32, max_batch=4, chunk_tokens=chunk)
    bound = pool.blocks_for(window + chunk) + 2 if window else None
    # prompts drawn from two base chains so prefixes collide often
    bases = [np.arange(24, dtype=np.int32),
             np.concatenate([np.arange(8),
                             np.arange(50, 66)]).astype(np.int32)]
    cancelled = []
    for i, op in enumerate(ops):
        ln = 1 + lengths[i % len(lengths)] % 20
        if op == 0:                                    # submit
            base = bases[i % 2]
            sch.submit(_WalkReq(base[:ln].copy(),
                                1 + max_news[i % len(max_news)] % 16))
        elif op in (1, 2):                             # one engine step
            _stub_step(sch, chunk)
        elif op == 3:                                  # cancel anywhere
            reqs = [s.req for s in sch.running] + list(sch.waiting)
            if reqs:
                req = reqs[i % len(reqs)]
                was_prefilling = any(s.req is req and s.prefilling
                                     for s in sch.running)
                assert sch.cancel(req)
                assert req.done and req.finish_reason == "cancelled"
                cancelled.append((req, was_prefilling))
        elif op == 4 and sch.running:                  # preempt youngest
            sch.preempt(max(sch.running, key=lambda s: s.admitted_at))
        _check_pool(pool, sch, held_bound=bound)
    steps = 0
    while sch.has_work:                                # drain
        _stub_step(sch, chunk)
        _check_pool(pool, sch, held_bound=bound)
        steps += 1
        assert steps < 4000, "drain did not terminate (liveness broken)"
    assert pool.free_blocks == pool.n_usable, \
        "drained walk leaked blocks (cancellation or finish path)"
    if pool.slots is not None:
        assert pool.slots.free_slots == pool.slots.n_slots
    for req, _ in cancelled:
        assert req.done and req.finish_reason == "cancelled"


@settings(max_examples=25, deadline=None)
@given(ops=st.lists(st.integers(0, 4), min_size=4, max_size=40),
       lengths=st.lists(st.integers(0, 1000), min_size=1, max_size=8),
       max_news=st.lists(st.integers(0, 1000), min_size=1, max_size=8),
       chunk=st.integers(1, 6))
def test_property_chunked_walk_windowed(ops, lengths, max_news, chunk):
    """Random chunked traffic at window < max_len: starvation bound,
    budget saturation, exact refcounts, pool.validate, the held-block
    bound, and zero leaks through cancel/preempt/drain."""
    _chunked_walk(ops, lengths, max_news, chunk)


@settings(max_examples=15, deadline=None)
@given(ops=st.lists(st.integers(0, 4), min_size=4, max_size=30),
       lengths=st.lists(st.integers(0, 1000), min_size=1, max_size=8),
       max_news=st.lists(st.integers(0, 1000), min_size=1, max_size=8),
       chunk=st.integers(1, 6))
def test_property_chunked_walk_unwindowed(ops, lengths, max_news, chunk):
    """Same walk without a window (llama): nothing reclaims mid-prefill,
    so the full-transient submit gate and the preemption loop carry the
    liveness argument alone."""
    _chunked_walk(ops, lengths, max_news, chunk, name="llama3-8b",
                  window=None)


@settings(max_examples=10, deadline=None)
@given(ops=st.lists(st.integers(0, 4), min_size=4, max_size=30),
       lengths=st.lists(st.integers(0, 1000), min_size=1, max_size=8),
       max_news=st.lists(st.integers(0, 1000), min_size=1, max_size=8),
       chunk=st.integers(1, 6))
def test_property_chunked_walk_slots_only(ops, lengths, max_news, chunk):
    """Pure-SSM walk: no blocks at all -- admission, cancellation and
    the drain must hand every state slot back."""
    _chunked_walk(ops, lengths, max_news, chunk, name="mamba2-130m",
                  window=None)


def test_cancel_mid_prefill_walk_deterministic():
    """Pinned regression (no hypothesis needed): cancel a request whose
    prompt is mid-stream -- acquired prefix blocks, freshly chunk-filled
    blocks and the COW tail all return through the refcount path."""
    cfg = get_config("mixtral-8x7b").reduced(n_layers=2, window=8)
    kv8 = dataclasses.replace(cfg.quant, w_bits=None, kv_bits=8)
    pool = PagedKVPool(cfg, n_blocks=9, block_size=4, quant=kv8)
    sch = Scheduler(pool, max_len=32, max_batch=4, chunk_tokens=3)
    base = np.arange(20, dtype=np.int32)
    a, b = _WalkReq(base.copy(), 4), _WalkReq(base[:18].copy(), 4)
    sch.submit(a)
    sch.submit(b)
    _stub_step(sch, 3)                 # a streams; b shares a's chain
    _stub_step(sch, 3)
    pre = [s for s in sch.running if s.prefilling]
    assert pre, "walk must cancel while a prefill is actually in flight"
    for req in (a, b):
        assert sch.cancel(req)
        _check_pool(pool, sch)
    assert pool.free_blocks == pool.n_usable
    assert not sch.running and not sch.waiting


# ---------------------------------------------------------------------------
# Async API: callbacks, deadlines, cancellation
# ---------------------------------------------------------------------------

def test_stream_callbacks_fire_in_emission_order():
    """Per-request ``on_token`` callbacks must see exactly the request's
    output tokens, in emission order, across interleaved chunked
    requests."""
    cfg, params = _setup("mamba2-130m")
    eng = E.Engine(params, cfg, n_slots=2, max_len=32, paged=True,
                   block_size=4, chunk_tokens=3)
    rng = np.random.default_rng(2)
    calls = []
    reqs = []
    for i in range(3):
        r = E.Request(prompt=rng.integers(0, cfg.vocab, (5 + i,),
                                          dtype=np.int32),
                      max_new_tokens=4)
        r.on_token = (lambda rr: lambda t: calls.append((id(rr), t)))(r)
        reqs.append(r)
        eng.submit(r)
    eng.run()
    assert all(r.done and len(r.out) == 4 for r in reqs)
    for r in reqs:
        assert [t for rid, t in calls if rid == id(r)] == r.out
    assert len(calls) == sum(len(r.out) for r in reqs)


def test_stream_handle_tokens_drives_the_engine():
    """Iterating a StreamHandle steps the engine until the request
    finishes; a second in-flight request advances alongside and its
    handle replays already-emitted tokens before stepping further."""
    cfg, params = _setup("mamba2-130m")
    eng = E.Engine(params, cfg, n_slots=2, max_len=32, paged=True,
                   block_size=4, chunk_tokens=3)
    rng = np.random.default_rng(4)
    r1 = E.Request(prompt=rng.integers(0, cfg.vocab, (5,), dtype=np.int32),
                   max_new_tokens=4)
    r2 = E.Request(prompt=rng.integers(0, cfg.vocab, (7,), dtype=np.int32),
                   max_new_tokens=6)
    h1, h2 = eng.submit(r1), eng.submit(r2)
    toks = list(h1.tokens())
    assert toks == r1.out and len(toks) == 4
    assert h1.done and h1.finish_reason == "length"
    assert list(h2.tokens()) == r2.out and h2.done
    assert h2.result().out == r2.out   # already finished: no more steps


def test_deadline_expiry_finishes_with_timeout_and_frees_memory():
    """An injected clock expires one running (mid-prefill) and one
    waiting request: both finish with ``finish_reason='timeout'``, fire
    no callbacks, and hand every block back; the surviving request is
    untouched."""
    cfg, params = _setup("mixtral-8x7b", n_layers=2, window=8)
    kv8 = _kv8(cfg)
    t = [0.0]
    eng = E.Engine(params, cfg, n_slots=2, max_len=32, quant=kv8,
                   paged=True, block_size=4, max_batch=2,
                   chunk_tokens=3, clock=lambda: t[0])
    rng = np.random.default_rng(6)
    a = E.Request(prompt=rng.integers(0, cfg.vocab, (4,), dtype=np.int32),
                  max_new_tokens=6)
    b_calls, c_calls = [], []
    b = E.Request(prompt=rng.integers(0, cfg.vocab, (24,), dtype=np.int32),
                  max_new_tokens=2, timeout=5.0, on_token=b_calls.append)
    c = E.Request(prompt=rng.integers(0, cfg.vocab, (4,), dtype=np.int32),
                  max_new_tokens=2, timeout=7.0, on_token=c_calls.append)
    for r in (a, b, c):
        eng.submit(r)
    assert b.deadline == 5.0 and c.deadline == 7.0
    for _ in range(3):                 # t=0: b mid-prefill, c waiting
        assert eng.step()
    assert any(s.req is b and s.prefilling for s in eng.scheduler.running)
    assert c in eng.scheduler.waiting
    t[0] = 10.0
    assert eng.step()                  # expiry sweep, then a's decode
    for r in (b, c):
        assert r.done and r.finish_reason == "timeout"
        assert r.out == [] and r.error is None
    assert b_calls == [] and c_calls == []
    model = Counter(int(blk) for s in eng.scheduler.running
                    for blk in s.blocks)
    assert dict(model) == {blk: n for blk, n in eng.pool._ref.items()
                           if n > 0}, "expired requests leaked references"
    eng.run()
    assert a.done and a.finish_reason == "length" and len(a.out) == 6
    eng.pool.validate()
    assert eng.pool.free_blocks == eng.pool.n_usable


@pytest.mark.parametrize("name,red,quant_fn", [
    ("mixtral-8x7b", dict(n_layers=2, window=8), _kv8),
    ("jamba-1.5-large-398b", dict(n_layers=2, attn_every=2), _kv8),
])
def test_cancel_mid_prefill_leaks_nothing(name, red, quant_fn):
    """Cancelling through the engine while the prompt is mid-stream must
    release every block AND the state slot, emit nothing, and leave the
    engine idle."""
    cfg, params = _setup(name, **red)
    eng = E.Engine(params, cfg, n_slots=2, max_len=32,
                   quant=quant_fn(cfg), paged=True, block_size=4,
                   chunk_tokens=3)
    calls = []
    r = E.Request(prompt=np.arange(20, dtype=np.int32), max_new_tokens=4,
                  on_token=calls.append)
    h = eng.submit(r)
    eng.step()
    eng.step()
    seq = eng.scheduler.running[0]
    assert seq.prefilling and 0 < seq.length < 20
    assert h.cancel()
    assert r.done and r.finish_reason == "cancelled"
    assert r.out == [] and calls == []
    assert not eng.scheduler.running and not eng.scheduler.waiting
    eng.pool.validate()
    assert eng.pool.free_blocks == eng.pool.n_usable
    if eng.pool.slots is not None:
        assert eng.pool.slots.free_slots == eng.pool.slots.n_slots
    assert h.cancel() is False         # already finished
    assert eng.step() is False         # nothing left to do


def test_cancelled_request_never_sees_another_callback():
    """A peer's callback cancels request b mid-step: b's lane in the
    same step is skipped, its output stops growing, and its callback
    count equals its emitted tokens exactly."""
    cfg, params = _setup("mamba2-130m")
    eng = E.Engine(params, cfg, n_slots=2, max_len=32, paged=True,
                   block_size=4, chunk_tokens=3)
    rng = np.random.default_rng(8)
    a = E.Request(prompt=rng.integers(0, cfg.vocab, (4,), dtype=np.int32),
                  max_new_tokens=6)
    b = E.Request(prompt=rng.integers(0, cfg.vocab, (4,), dtype=np.int32),
                  max_new_tokens=6)
    b_calls = []
    b.on_token = b_calls.append

    def a_cb(tok):                     # a runs first in the step's plan
        if len(a.out) == 2:
            eng.cancel(b)
    a.on_token = a_cb
    eng.submit(a)
    eng.submit(b)
    eng.run()
    assert a.done and a.finish_reason == "length" and len(a.out) == 6
    assert b.done and b.finish_reason == "cancelled"
    assert len(b.out) < 6 and b_calls == b.out, (b_calls, b.out)
    assert eng.pool.slots.free_slots == eng.pool.slots.n_slots


def test_async_api_on_the_contiguous_engine():
    """The same request-level API (cancel from the queue, deadline
    expiry on a lane) works on the contiguous engine -- it is a Request
    contract, not a paged feature."""
    cfg, params = _setup("mamba2-130m")
    t = [0.0]
    eng = E.Engine(params, cfg, n_slots=2, max_len=32,
                   clock=lambda: t[0])
    rng = np.random.default_rng(12)
    mk = lambda n, **kw: E.Request(
        prompt=rng.integers(0, cfg.vocab, (4,), dtype=np.int32),
        max_new_tokens=n, **kw)
    a, b, c = mk(6), mk(8, timeout=5.0), mk(2)
    ha, hb, hc = eng.submit(a), eng.submit(b), eng.submit(c)
    assert hc.cancel()                 # straight out of the queue
    assert c.done and c.finish_reason == "cancelled" and c.out == []
    eng.step()                         # a + b occupy the two lanes
    t[0] = 10.0
    eng.step()                         # b's lane expires
    assert b.done and b.finish_reason == "timeout"
    n_b = len(b.out)
    eng.run()
    assert a.done and a.finish_reason == "length" and len(a.out) == 6
    assert len(b.out) == n_b, "expired lane kept emitting"
