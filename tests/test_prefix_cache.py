"""Pool-level tests for the refcounted copy-on-write prefix cache.

No model forward runs here: these exercise PagedKVPool's accounting --
acquire/release refcounts, the prompt-chain hash index, LRU eviction,
copy-on-write, and the strict free()/release() misuse errors (ISSUE 3
satellites).  The property test drives the *real* Scheduler admission /
append-capacity / preemption / finish paths with a stub prefill and
asserts the pool invariants plus an external refcount model after every
step.  Engine-level behavior (token identity, COW on divergence, warm
restarts) lives in tests/test_paged_serving.py.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:    # property tests skip (not error) without hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_config
from repro.serving.paged_cache import PagedKVPool
from repro.serving.scheduler import Scheduler


def _pool(n_blocks=8, block_size=4, **red):
    import dataclasses
    cfg = get_config("llama3-8b").reduced(n_layers=2, **red)
    kv8 = dataclasses.replace(cfg.quant, w_bits=None, kv_bits=8)
    return PagedKVPool(cfg, n_blocks=n_blocks, block_size=block_size,
                       quant=kv8)


# ---------------------------------------------------------------------------
# free()/release() misuse is an error, not silent corruption (satellite)
# ---------------------------------------------------------------------------

def test_double_free_raises_and_preserves_state():
    pool = _pool()
    a = pool.alloc(2)
    pool.free(a)
    before = (pool.free_blocks, sorted(pool._free))
    with pytest.raises(ValueError, match="double free"):
        pool.free(a)
    assert (pool.free_blocks, sorted(pool._free)) == before, \
        "a rejected double-free must leave the free list untouched"
    pool.free([])          # idempotent no-op on nothing
    pool.validate()


def test_free_rejects_null_block_duplicates_and_shared():
    pool = _pool()
    (a,) = pool.alloc(1)
    with pytest.raises(ValueError, match="null block"):
        pool.free([0])
    with pytest.raises(ValueError, match="duplicate"):
        pool.free([a, a])
    pool.acquire([a])      # refcount 2: another table still maps it
    with pytest.raises(ValueError, match="refcount"):
        pool.free([a])
    pool.release([a])
    pool.free([a])
    with pytest.raises(ValueError, match="double release|no live"):
        pool.release([a])
    pool.validate()


# ---------------------------------------------------------------------------
# Refcounts, LRU caching, eviction, COW
# ---------------------------------------------------------------------------

def _register(pool, tokens, blocks, pos_too=True):
    """Register a chain and (optionally) write the matching positions so
    validate(check_contents=True) has something to verify."""
    if pos_too:
        import jax.numpy as jnp
        bs = pool.block_size
        for j, bid in enumerate(blocks):
            n = min((j + 1) * bs, len(tokens)) - j * bs
            if n <= 0:
                break
            vals = jnp.arange(j * bs, j * bs + n, dtype=jnp.int32)
            for c, stacked in pool._attn_caches():
                if stacked:
                    c["pos"] = c["pos"].at[:, bid, :n].set(vals)
                else:
                    c["pos"] = c["pos"].at[bid, :n].set(vals)
    pool.register_chain(tokens, blocks)


def test_release_caches_then_lru_eviction_reclaims():
    pool = _pool(n_blocks=6, block_size=4)
    chain_a = np.arange(8, dtype=np.int32)
    chain_b = np.arange(100, 108, dtype=np.int32)
    a = pool.alloc(2)
    _register(pool, chain_a, a)
    b = pool.alloc(2)
    _register(pool, chain_b, b)
    pool.release(a)
    pool.release(b)
    assert pool.cached_blocks == 4 and pool.free_blocks == 5
    pool.validate(check_contents=True)

    # a full re-lookup hits chain_b (both blocks still cached)
    hit = pool.acquire_prefix(np.concatenate([chain_b, [9]]))
    assert hit.cached_len == 8 and [int(i) for i in hit.ids] == list(b)
    pool.release(hit.ids)

    # allocating past the free list evicts in LRU order: chain_a's
    # blocks (released first) go before chain_b's
    pool.alloc(3)
    assert pool.n_evictions == 2
    miss = pool.acquire_prefix(np.concatenate([chain_a, [9]]))
    assert miss.cached_len == 0 and not miss.ids, \
        "evicted blocks must leave the prefix index"
    still = pool.acquire_prefix(np.concatenate([chain_b, [9]]))
    assert still.cached_len >= 4, "LRU must evict oldest-released first"
    pool.release(still.ids)
    pool.validate()


def test_acquire_prefix_caps_at_len_minus_one():
    """A full-chain hit must leave >= 1 token to recompute: the caller
    needs logits at the last position to sample from."""
    pool = _pool(n_blocks=8, block_size=4)
    chain = np.arange(8, dtype=np.int32)
    a = pool.alloc(2)
    _register(pool, chain, a)
    pool.release(a)
    hit = pool.acquire_prefix(chain)       # exact duplicate, block-aligned
    assert hit.cached_len == 4 and len(hit.ids) == 1, \
        "the block ending at the last token must not be taken"
    pool.release(hit.ids)


def test_cow_copies_contents_and_drops_one_ref():
    import jax.numpy as jnp
    pool = _pool(n_blocks=6, block_size=4)
    (a,) = pool.alloc(1)
    for c, stacked in pool._attn_caches():
        if stacked:
            c["pos"] = c["pos"].at[:, a].set(jnp.arange(4, dtype=jnp.int32))
        else:
            c["pos"] = c["pos"].at[a].set(jnp.arange(4, dtype=jnp.int32))
    pool.acquire([a])
    assert pool.refcount(a) == 2
    b = pool.cow(a)
    assert b != a and pool.refcount(a) == 1 and pool.refcount(b) == 1
    for c, stacked in pool._attn_caches():
        pa = np.asarray(c["pos"])[..., a, :]
        pb = np.asarray(c["pos"])[..., b, :]
        np.testing.assert_array_equal(pa, pb)
    assert pool.n_cow == 1
    pool.free([a])
    pool.free([b])
    pool.validate()


def test_hash_hit_verifies_tokens_exactly():
    """The chain hash routes the lookup but token contents decide: a
    different chain that happened to collide could only MISS, never
    alias (we can't force a collision, so check the exact-compare arm:
    same length, different tokens => miss)."""
    pool = _pool(n_blocks=8, block_size=4)
    a = pool.alloc(2)
    _register(pool, np.arange(8, dtype=np.int32), a)
    pool.release(a)
    other = np.concatenate([np.arange(4), [99, 98, 97, 96], [1]]).astype(np.int32)
    hit = pool.acquire_prefix(other)
    assert hit.cached_len == 4, "shared first block should hit"
    miss = pool.acquire_prefix(
        np.concatenate([[99], np.arange(8)]).astype(np.int32))
    assert miss.cached_len == 0, "shifted chain must miss from the root"
    pool.release(hit.ids)
    pool.validate()


# ---------------------------------------------------------------------------
# Property test: random scheduler walks keep every pool invariant
# ---------------------------------------------------------------------------

class _Req:
    """Minimal stand-in for engine.Request (identity the scheduler needs)."""
    def __init__(self, prompt, max_new_tokens):
        self.prompt = prompt
        self.max_new_tokens = max_new_tokens
        self.temperature = 0.0
        self.out = []
        self.done = False
        self.error = None


def _stub_prefill(seq, tokens):
    seq.length = len(tokens)
    if seq.req.out:
        seq.last_tok = seq.req.out[-1]
    else:
        seq.last_tok = int(tokens[-1] * 31 % 97)
        seq.req.out.append(seq.last_tok)


def _check(pool, sch):
    """Pool invariants + external refcount model: at rest, a block's
    refcount equals the number of running block tables mapping it."""
    pool.validate()
    from collections import Counter
    model = Counter(int(b) for s in sch.running for b in s.blocks)
    actual = {b: r for b, r in pool._ref.items() if r > 0}
    assert dict(model) == actual, (dict(model), actual)


def test_register_chain_memo_caps_rehashing():
    """ChainMemo resume point: repeated registration of a growing chain
    hashes only the new blocks (ROADMAP PR-3 open item), and the index
    it builds behaves exactly like a memo-free walk's."""
    from repro.serving.paged_cache import ChainMemo
    pool = _pool(n_blocks=20, block_size=4)
    toks = np.arange(40, dtype=np.int32)
    blocks = pool.alloc(4)
    memo = ChainMemo()
    pool.register_chain(toks[:8], blocks[:2], memo=memo)    # 2 full
    assert pool.n_chain_hash_ops == 2 and memo.n_full == 2
    # grow by one full block + a 2-token partial: only they are hashed
    pool.register_chain(toks[:14], blocks, memo=memo)
    assert pool.n_chain_hash_ops == 4 and memo.n_full == 3
    # re-registering the unchanged chain re-walks only the partial tail
    pool.register_chain(toks[:14], blocks, memo=memo)
    assert pool.n_chain_hash_ops == 5
    # a memo-free walk of the same chain re-hashes everything (4 blocks)
    pool.register_chain(toks[:14], blocks)
    assert pool.n_chain_hash_ops == 9
    # the memo-built index serves hits exactly like the rebuilt one
    hit = pool.acquire_prefix(toks[:16])
    assert hit.cached_len == 14 and hit.ids == blocks
    pool.release(hit.ids)
    pool.validate()


def test_memo_lost_race_block_reindexes_after_incumbent_eviction():
    """A block that lost the duplicate race must STALL the memo (not
    advance past it), so a later registration can claim the index once
    the incumbent copy is LRU-evicted -- the memo may never make a
    chain permanently unindexable."""
    from repro.serving.paged_cache import ChainMemo
    pool = _pool(n_blocks=8, block_size=4)
    toks = np.arange(8, dtype=np.int32)
    a = pool.alloc(2)                     # incumbent copy of the chain
    pool.register_chain(toks, a)
    b = pool.alloc(2)                     # duplicate copy: loses the race
    memo = ChainMemo()
    pool.register_chain(toks, b, memo=memo)
    assert memo.n_full == 0               # stalled, stays re-walkable
    pool.release(a)                       # incumbent parks in the LRU...
    pool.alloc(pool.free_blocks)          # ...and is evicted under pressure
    pool.register_chain(toks, b, memo=memo)
    assert memo.n_full == 2               # b now owns the index entries
    hit = pool.acquire_prefix(np.arange(9, dtype=np.int32))
    assert hit.ids == b and hit.cached_len == 8
    pool.release(hit.ids)
    pool.validate()


def test_scheduler_chain_bookkeeping_is_incremental():
    """Finish/preempt-time registration through SequenceState.chain_memo
    hashes only blocks past the admission memo, not the whole chain."""
    pool = _pool(n_blocks=32, block_size=4)
    sch = Scheduler(pool, max_len=64, max_batch=1)
    sch.submit(_Req(np.arange(16, dtype=np.int32), 20))
    sch.admit(_stub_prefill)
    (seq,) = sch.running
    assert pool.n_chain_hash_ops == 4          # 4 full prompt blocks
    for _ in range(12):                        # grow 16 -> 28 tokens
        sch.ensure_append_capacity()
        tok = int((seq.length * 13 + 7) % 97)
        seq.req.out.append(tok)
        seq.last_tok = tok
        seq.length += 1
    sch.finish(seq)
    # chain is 7 blocks; only the 3 past the admission memo are hashed
    assert pool.n_chain_hash_ops == 7
    pool.validate()


def _walk(ops, lengths, max_news):
    """Drive Scheduler+PagedKVPool through a random op sequence."""
    pool = _pool(n_blocks=9, block_size=4)
    sch = Scheduler(pool, max_len=32, max_batch=4)
    # prompts drawn from two base chains so prefixes collide often
    bases = [np.arange(24, dtype=np.int32),
             np.concatenate([np.arange(8), np.arange(50, 66)]).astype(np.int32)]
    for i, op in enumerate(ops):
        ln = 1 + lengths[i % len(lengths)] % 20
        if op == 0:                                    # submit + admit
            base = bases[i % 2]
            sch.submit(_Req(base[:ln].copy(),
                            1 + max_news[i % len(max_news)] % 6))
            sch.admit(_stub_prefill)
        elif op == 1 and sch.running:                  # one decode step
            sch.ensure_append_capacity()
            for s in list(sch.running):
                tok = int((s.length * 13 + 7) % 97)
                s.last_tok = tok
                s.req.out.append(tok)
                s.length += 1
                if len(s.req.out) >= s.req.max_new_tokens \
                        or s.length >= sch.max_len - 1:
                    sch.finish(s)
        elif op == 2 and sch.running:                  # preempt youngest
            sch.preempt(max(sch.running, key=lambda s: s.admitted_at))
            sch.admit(_stub_prefill)
        elif op == 3 and sch.running:                  # finish oldest
            sch.finish(min(sch.running, key=lambda s: s.admitted_at))
        _check(pool, sch)
    for s in list(sch.running):                        # drain
        sch.finish(s)
    _check(pool, sch)
    assert pool.free_blocks == pool.n_usable


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 3), min_size=1, max_size=40),
       st.lists(st.integers(0, 30), min_size=1, max_size=10),
       st.lists(st.integers(0, 10), min_size=1, max_size=10))
def test_pool_invariants_under_random_scheduler_walks(ops, lengths, max_news):
    """Hypothesis sweep (ISSUE 3 satellite): refcounts >= 0 and equal
    to table multiplicity, the null block never allocated, free list
    disjoint from the live set, cached-block hash entries agreeing with
    their recorded contents -- across random
    submit/decode/preempt/finish interleavings."""
    _walk(ops, lengths, max_news)


@pytest.mark.parametrize("seed", range(8))
def test_pool_invariants_seeded_walks(seed):
    """Deterministic twin of the hypothesis sweep so the invariants run
    even where hypothesis isn't installed (tier-1 fallback skips the
    property test, not the coverage)."""
    rng = np.random.default_rng(seed)
    _walk(list(rng.integers(0, 4, 60)),
          list(rng.integers(0, 31, 10)),
          list(rng.integers(0, 11, 10)))
