"""Chaos suite: the serving-hardening contract under deterministic,
seeded fault injection (ISSUE 9).

The claims under test, each locked by a property or a pinned scenario:

* **Determinism of the injector itself**: one seed -> one fault
  schedule, so every failure found here replays exactly; the
  ``NULL_FAULTS`` twin is inert.
* **Memory-fault transparency**: injected alloc/slot exhaustion,
  forced prefix-cache eviction, admission races and preemption storms
  may delay requests but never change their tokens -- every request
  completes bit-identical to the fault-free twin, refcounts stay exact
  (external Counter model) after every walk op, and the drain leaks
  zero blocks and zero state slots.
* **Step-level containment**: a poisoned (non-finite) logits row or a
  raising ``on_token`` callback quarantines exactly the offending
  request (``finish_reason='error'``, cause on ``.error``) while the
  rest of the batch stays bit-identical to a fault-free run.
* **Watchdog recovery**: ``validate_every`` catches corrupted pool
  bookkeeping and corrupted block tables; recovery rebuilds the free
  lists from the surviving tables and quarantines only the chains it
  cannot trust -- then passes the full invariant check it guards.
* **Backpressure**: ``max_queue`` sheds with ``finish_reason=
  'rejected'`` + a ``retry_after`` hint, and ``StreamHandle.resubmit``
  gets the request back in once the queue drains.

Fault-free overhead and recovery latency are gated in
benchmarks/fault_recovery.py (bench-smoke).
"""

import dataclasses
from collections import Counter

import jax
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:    # property tests skip (not error) without hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_config
from repro.models import model as M
from repro.serving import engine as E
from repro.serving.faults import FaultInjector, NULL_FAULTS
from repro.serving.paged_cache import PagedKVPool
from repro.serving.scheduler import Scheduler


def _setup(name="mixtral-8x7b", **red):
    cfg = get_config(name).reduced(**red)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    return cfg, params


def _kv8(cfg):
    return dataclasses.replace(cfg.quant, w_bits=None, kv_bits=8)


class _WalkReq:
    """Minimal stand-in for engine.Request (identity the scheduler needs)."""
    def __init__(self, prompt, max_new_tokens):
        self.prompt = prompt
        self.max_new_tokens = max_new_tokens
        self.temperature = 0.0
        self.out = []
        self.done = False
        self.error = None
        self.finish_reason = None


def _check_pool(pool, sch):
    """Exactness under chaos: pool internals self-consistent, every
    block's refcount equals the number of running tables mapping it,
    every running stateful request holds exactly one slot."""
    pool.validate()
    if pool.needs_blocks:
        model = Counter(int(b) for s in sch.running for b in s.blocks)
        actual = {b: r for b, r in pool._ref.items() if r > 0}
        assert dict(model) == actual, (dict(model), actual)
    if pool.slots is not None:
        assert all(s.slot >= 0 for s in sch.running)
        assert pool.slots.free_slots \
            == pool.slots.n_slots - len(sch.running)


def _chaos_stub_step(sch, chunk):
    """One engine step without the model, with the engine's step-level
    containment: a transient pool fault the scheduler could not absorb
    aborts the step (state intact), exactly like Engine._paged_step."""
    try:
        sch.admit_chunked()
        plan = sch.ensure_step_capacity(sch.plan_step())
    except RuntimeError:
        return
    for seq, n in plan:
        if seq.req.done:
            continue
        if seq.prefilling:
            seq.length += n
            sch.register_progress(seq)
            if seq.length < len(seq.pending):
                continue
            seq.pending = None
            if seq.req.out:                     # warm resume
                seq.last_tok = seq.req.out[-1]
                continue
            tok = int((seq.length * 13 + 7) % 97)
            seq.last_tok = tok
            seq.req.out.append(tok)
        else:
            tok = int((seq.length * 13 + 7) % 97)
            seq.last_tok = tok
            seq.req.out.append(tok)
            seq.length += 1
        if len(seq.req.out) >= seq.req.max_new_tokens \
                or seq.length >= sch.max_len - 1:
            sch.finish(seq)


def _chaos_walk(ops, lengths, max_news, chunk, fseed, *, name="mixtral-8x7b"):
    """Random chunked traffic with memory faults armed: refcounts stay
    exact after every op and the drain leaks nothing."""
    if name == "mamba2-130m":
        faults = FaultInjector(fseed, p_slot_fail=0.3, p_admit_race=0.25,
                               p_preempt_storm=0.1)
        cfg = get_config(name).reduced()
        pool = PagedKVPool(cfg, n_blocks=4, block_size=4,
                           n_state_slots=4, prefix_cache=False,
                           faults=faults)
    else:
        faults = FaultInjector(fseed, p_alloc_fail=0.1, p_forced_evict=0.25,
                               p_admit_race=0.25, p_preempt_storm=0.1)
        cfg = get_config(name).reduced(n_layers=2, window=8)
        pool = PagedKVPool(cfg, n_blocks=9, block_size=4, quant=_kv8(cfg),
                           faults=faults)
    sch = Scheduler(pool, max_len=32, max_batch=4, chunk_tokens=chunk)
    assert sch.faults is faults, "scheduler must inherit the pool's injector"
    bases = [np.arange(24, dtype=np.int32),
             np.concatenate([np.arange(8),
                             np.arange(50, 66)]).astype(np.int32)]
    for i, op in enumerate(ops):
        ln = 1 + lengths[i % len(lengths)] % 20
        if op == 0:                                    # submit
            sch.submit(_WalkReq(bases[i % 2][:ln].copy(),
                                1 + max_news[i % len(max_news)] % 16))
        elif op in (1, 2):                             # one engine step
            _chaos_stub_step(sch, chunk)
        elif op == 3:                                  # cancel anywhere
            reqs = [s.req for s in sch.running] + list(sch.waiting)
            if reqs:
                assert sch.cancel(reqs[i % len(reqs)])
        elif op == 4 and sch.running:                  # preempt youngest
            sch.preempt(max(sch.running, key=lambda s: s.admitted_at))
        _check_pool(pool, sch)
    steps = 0
    while sch.has_work:                                # drain
        _chaos_stub_step(sch, chunk)
        _check_pool(pool, sch)
        steps += 1
        assert steps < 8000, "drain did not terminate under faults"
    assert pool.free_blocks == pool.n_usable, \
        "chaos walk leaked blocks"
    if pool.slots is not None:
        assert pool.slots.free_slots == pool.slots.n_slots, \
            "chaos walk leaked state slots"
    # a quarantine-free walk must finish (not error) every uncancelled
    # request: memory faults are transparent to the outcome
    return faults


# ---------------------------------------------------------------------------
# The injector itself
# ---------------------------------------------------------------------------

def test_injector_is_deterministic_and_null_is_inert():
    mk = lambda: FaultInjector(7, p_alloc_fail=0.4, p_admit_race=0.5,
                               p_nan_logits=0.3)
    a, b = mk(), mk()
    sched_a = [(a.alloc_fail(1), a.admit_race(), a.nan_logits(None))
               for _ in range(300)]
    sched_b = [(b.alloc_fail(1), b.admit_race(), b.nan_logits(None))
               for _ in range(300)]
    assert sched_a == sched_b, "same seed must replay the same schedule"
    assert a.fired == b.fired
    assert a.fired["alloc_fail"] > 0 and a.fired["admit_race"] > 0
    # a different seed gives a different schedule (vanishingly unlikely
    # to collide over 900 draws)
    c = FaultInjector(8, p_alloc_fail=0.4, p_admit_race=0.5,
                      p_nan_logits=0.3)
    sched_c = [(c.alloc_fail(1), c.admit_race(), c.nan_logits(None))
               for _ in range(300)]
    assert sched_c != sched_a
    # the disabled twin: constant False everywhere, nothing retained
    assert NULL_FAULTS.enabled is False
    assert not any([NULL_FAULTS.alloc_fail(5), NULL_FAULTS.slot_fail(),
                    NULL_FAULTS.forced_evict(), NULL_FAULTS.admit_race(),
                    NULL_FAULTS.preempt_storm(), NULL_FAULTS.nan_logits(0),
                    NULL_FAULTS.callback_error(0)])
    assert NULL_FAULTS.fired == Counter()
    clk = lambda: 3.5
    assert NULL_FAULTS.wrap_clock(clk) is clk


def test_wrapped_clock_jumps_forward_monotonically():
    t = [0.0]
    faults = FaultInjector(3, p_clock_jump=1.0, clock_jump=10.0)
    wrapped = faults.wrap_clock(lambda: t[0])
    reads = []
    for i in range(5):
        t[0] = float(i)
        reads.append(wrapped())
    assert reads == sorted(reads), "wrapped clock ran backward"
    assert reads[-1] >= 4.0 + 5 * 10.0 - 10.0   # jumps accumulated
    assert faults.fired["clock_jump"] == 5
    # p=0 returns the base clock untouched
    assert FaultInjector(0).wrap_clock(None)() > 0


# ---------------------------------------------------------------------------
# Property walks: the scheduler + pool under memory faults
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(ops=st.lists(st.integers(0, 4), min_size=4, max_size=40),
       lengths=st.lists(st.integers(0, 1000), min_size=1, max_size=8),
       max_news=st.lists(st.integers(0, 1000), min_size=1, max_size=8),
       chunk=st.integers(1, 6),
       fseed=st.integers(0, 1000))
def test_property_chaos_walk_windowed(ops, lengths, max_news, chunk, fseed):
    """Injected alloc failures, forced evictions, admission races and
    preemption storms: refcounts exact after every op, zero leaks."""
    _chaos_walk(ops, lengths, max_news, chunk, fseed)


@settings(max_examples=15, deadline=None)
@given(ops=st.lists(st.integers(0, 4), min_size=4, max_size=30),
       lengths=st.lists(st.integers(0, 1000), min_size=1, max_size=8),
       max_news=st.lists(st.integers(0, 1000), min_size=1, max_size=8),
       chunk=st.integers(1, 6),
       fseed=st.integers(0, 1000))
def test_property_chaos_walk_slots_only(ops, lengths, max_news, chunk,
                                        fseed):
    """Pure-SSM walk with slot-exhaustion faults: every state slot comes
    back despite injected alloc_slot failures mid-admission."""
    _chaos_walk(ops, lengths, max_news, chunk, fseed, name="mamba2-130m")


def test_chaos_walk_pinned_runs_without_hypothesis():
    """Fixed replays of the property walks so the chaos machinery is
    exercised in tier-1 even when hypothesis is not installed."""
    ops = [0, 0, 1, 0, 2, 1, 3, 1, 0, 4, 1, 2, 0, 1, 1, 3, 2, 0, 1, 4,
           1, 2, 1, 0, 1, 1]
    for fseed in (0, 7, 42, 101):
        fired = _chaos_walk(ops, [5, 17, 3], [4, 9], 3, fseed).fired
        assert sum(fired.values()) > 0, (fseed, fired)
    for fseed in (1, 13):
        _chaos_walk(ops, [8, 2], [3, 12], 2, fseed, name="mamba2-130m")


def test_admission_rollback_pinned():
    """Pinned (no hypothesis): a slot fault inside chunked admission
    rolls the acquired prefix back through the refcount path and
    re-queues the request; the next step admits it cleanly."""
    faults = FaultInjector(0, p_slot_fail=1.0)
    cfg = get_config("mamba2-130m").reduced()
    pool = PagedKVPool(cfg, n_blocks=4, block_size=4, n_state_slots=4,
                       prefix_cache=False, faults=faults)
    sch = Scheduler(pool, max_len=32, max_batch=4, chunk_tokens=3)
    sch.submit(_WalkReq(np.arange(6, dtype=np.int32), 2))
    _chaos_stub_step(sch, 3)
    assert not sch.running and len(sch.waiting) == 1, \
        "slot fault must bounce the admission back to the queue"
    assert sch._c_admit_rollbacks.value == 1
    assert pool.slots.free_slots == pool.slots.n_slots
    faults.p_slot_fail = 0.0           # fault clears; admission succeeds
    _chaos_stub_step(sch, 3)
    assert len(sch.running) == 1
    while sch.has_work:
        _chaos_stub_step(sch, 3)
    assert pool.slots.free_slots == pool.slots.n_slots


# ---------------------------------------------------------------------------
# Engine-level: token identity of survivors vs the fault-free twin
# ---------------------------------------------------------------------------

def _run_engine(params, cfg, prompts, *, quant, max_new=4, **kw):
    eng = E.Engine(params, cfg, n_slots=2, max_len=32, quant=quant,
                   paged=True, block_size=4, chunk_tokens=3, **kw)
    reqs = [E.Request(prompt=p.copy(), max_new_tokens=max_new)
            for p in prompts]
    handles = [eng.submit(r) for r in reqs]
    eng.run()
    return reqs, handles, eng


def test_memory_faults_never_change_tokens():
    """Alloc failures, forced evictions, admission races and preemption
    storms against the real engine: every request still completes, with
    tokens bit-identical to the fault-free twin, and the pool drains to
    zero leaks."""
    cfg, params = _setup("mixtral-8x7b", n_layers=2, window=8)
    kv8 = _kv8(cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, (n,), dtype=np.int32)
               for n in (5, 9, 14)]
    base, _, _ = _run_engine(params, cfg, prompts, quant=kv8)
    faults = FaultInjector(11, p_alloc_fail=0.05, p_forced_evict=0.3,
                           p_admit_race=0.3, p_preempt_storm=0.1)
    reqs, _, eng = _run_engine(params, cfg, prompts, quant=kv8,
                               faults=faults)
    assert sum(faults.fired.values()) > 0, "the schedule must have fired"
    for r, b in zip(reqs, base):
        assert r.done and r.error is None, (r.finish_reason, r.error)
        assert r.finish_reason == "length"
        assert r.out == b.out, "memory faults changed the tokens"
    eng.pool.validate()
    assert eng.pool.free_blocks == eng.pool.n_usable
    # the injection schedule is visible in the shared registry
    reg = eng.pool.metrics
    assert reg.value("repro_faults_injected",
                     site="admit_race") == faults.fired["admit_race"] > 0


def test_nan_quarantine_contains_to_one_request():
    """A poisoned logits row quarantines exactly the offending request:
    ``finish_reason='error'``, the cause surfaced on the handle, blocks
    released with zero leaks -- and every surviving request's tokens are
    bit-identical to the fault-free twin."""
    cfg, params = _setup("mixtral-8x7b", n_layers=2, window=8)
    kv8 = _kv8(cfg)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, (n,), dtype=np.int32)
               for n in (5, 9, 14)]
    base, _, _ = _run_engine(params, cfg, prompts, quant=kv8, max_new=6)
    faults = FaultInjector(2, p_nan_logits=0.06)
    reqs, handles, eng = _run_engine(params, cfg, prompts, quant=kv8,
                                     max_new=6, faults=faults)
    assert faults.fired["nan_logits"] >= 1, \
        "pick a seed whose schedule actually poisons a row"
    errored = [r for r in reqs if r.finish_reason == "error"]
    survived = [(r, b) for r, b in zip(reqs, base)
                if r.finish_reason != "error"]
    assert errored and survived, (len(errored), len(survived))
    for r in errored:
        assert r.done and "non-finite" in r.error
    for h in handles:
        if h.finish_reason == "error":
            assert h.result().error == h.error   # surfaced on the handle
    for r, b in survived:
        assert r.out == b.out, "a peer's quarantine changed these tokens"
    reg = eng.pool.metrics
    assert reg.value("repro_engine_fault_requests",
                     kind="nan_logits") == len(errored)
    eng.pool.validate()
    assert eng.pool.free_blocks == eng.pool.n_usable


def test_callback_exception_isolated_per_request():
    """A raising ``on_token`` callback (real user code, no injector)
    quarantines its own request and never wedges the step loop; the
    peer's tokens are untouched."""
    cfg, params = _setup("mamba2-130m")
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, cfg.vocab, (5,), dtype=np.int32),
               rng.integers(0, cfg.vocab, (7,), dtype=np.int32)]
    base, _, _ = _run_engine(params, cfg, prompts, quant=None, max_new=6)

    eng = E.Engine(params, cfg, n_slots=2, max_len=32, paged=True,
                   block_size=4, chunk_tokens=3)
    a = E.Request(prompt=prompts[0].copy(), max_new_tokens=6)
    b = E.Request(prompt=prompts[1].copy(), max_new_tokens=6)

    def bad_cb(tok):
        if len(b.out) == 2:
            raise ValueError("downstream sink exploded")
    b.on_token = bad_cb
    ha, hb = eng.submit(a), eng.submit(b)
    eng.run()
    assert b.done and b.finish_reason == "error"
    assert "on_token callback raised" in b.error
    assert len(b.out) == 2             # emitted tokens stay delivered
    assert a.done and a.finish_reason == "length"
    assert a.out == base[0].out, "quarantining b changed a's tokens"
    assert hb.error == b.error and ha.error is None
    reg = eng.pool.metrics
    assert reg.value("repro_engine_fault_requests", kind="callback") == 1
    assert eng.pool.slots.free_slots == eng.pool.slots.n_slots


def test_faults_disabled_is_token_identical_to_default():
    """An armed-but-all-zero injector must be invisible: same tokens as
    the NULL_FAULTS default, nothing fired."""
    cfg, params = _setup("mamba2-130m")
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab, (n,), dtype=np.int32)
               for n in (5, 9)]
    base, _, eng0 = _run_engine(params, cfg, prompts, quant=None)
    assert eng0.faults is NULL_FAULTS
    armed = FaultInjector(0)           # every probability 0.0
    reqs, _, _ = _run_engine(params, cfg, prompts, quant=None,
                             faults=armed)
    assert [r.out for r in reqs] == [b.out for b in base]
    assert armed.fired == Counter()


def test_clock_jump_expires_deadlines_cleanly():
    """Injected clock jumps race every deadline: requests finish with
    ``finish_reason='timeout'`` (never a crash, never a leak)."""
    cfg, params = _setup("mamba2-130m")
    faults = FaultInjector(1, p_clock_jump=1.0, clock_jump=3600.0)
    eng = E.Engine(params, cfg, n_slots=2, max_len=32, paged=True,
                   block_size=4, chunk_tokens=3, faults=faults)
    rng = np.random.default_rng(9)
    reqs = [E.Request(prompt=rng.integers(0, cfg.vocab, (5,),
                                          dtype=np.int32),
                      max_new_tokens=4, timeout=5.0) for _ in range(2)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        assert r.done and r.finish_reason == "timeout", r.finish_reason
    assert faults.fired["clock_jump"] >= 1
    assert eng.pool.slots.free_slots == eng.pool.slots.n_slots


# ---------------------------------------------------------------------------
# Watchdog: pool integrity violations recover instead of raising
# ---------------------------------------------------------------------------

def test_watchdog_repairs_bookkeeping_corruption():
    """A live block id smuggled onto the free list breaks the pool
    invariants; the ``validate_every`` watchdog rebuilds the free list
    from the (intact) block tables and every request still finishes
    with fault-free tokens."""
    cfg, params = _setup("mixtral-8x7b", n_layers=2, window=8)
    kv8 = _kv8(cfg)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, (n,), dtype=np.int32)
               for n in (5, 9)]
    base, _, _ = _run_engine(params, cfg, prompts, quant=kv8, max_new=6)

    eng = E.Engine(params, cfg, n_slots=2, max_len=32, quant=kv8,
                   paged=True, block_size=4, chunk_tokens=3,
                   validate_every=1)
    reqs = [E.Request(prompt=p.copy(), max_new_tokens=6) for p in prompts]
    for r in reqs:
        eng.submit(r)
    for _ in range(4):                 # get both requests decoding
        assert eng.step()
    live = next(int(b) for s in eng.scheduler.running for b in s.blocks)
    eng.pool._free.append(live)        # corrupt: live id on the free list
    eng.run()
    reg = eng.pool.metrics
    assert reg.value("repro_engine_fault_watchdog_violations") == 1
    for r, b in zip(reqs, base):
        assert r.done and r.finish_reason == "length" and r.error is None
        assert r.out == b.out, "watchdog recovery changed the tokens"
    eng.pool.validate()
    assert eng.pool.free_blocks == eng.pool.n_usable


def test_watchdog_quarantines_corrupt_chain():
    """A block table that references an impossible block id cannot be
    trusted against the refcount map: the watchdog quarantines that
    chain (``finish_reason='error'``) and rebuilds; the other request
    finishes with fault-free tokens and nothing leaks."""
    cfg, params = _setup("mixtral-8x7b", n_layers=2, window=8)
    kv8 = _kv8(cfg)
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab, (5,), dtype=np.int32),
               rng.integers(0, cfg.vocab, (9,), dtype=np.int32)]
    base, _, _ = _run_engine(params, cfg, prompts, quant=kv8, max_new=6)

    eng = E.Engine(params, cfg, n_slots=2, max_len=32, quant=kv8,
                   paged=True, block_size=4, chunk_tokens=3,
                   validate_every=1)
    a = E.Request(prompt=prompts[0].copy(), max_new_tokens=6)
    b = E.Request(prompt=prompts[1].copy(), max_new_tokens=6)
    for r in (a, b):
        eng.submit(r)
    for _ in range(4):
        assert eng.step()
    seq_b = next(s for s in eng.scheduler.running if s.req is b)
    seq_b.blocks[0] = 9999             # corrupt b's table, then un-balance
    eng.pool._free.append(1)           # the pool so validate() trips
    eng.run()
    assert b.done and b.finish_reason == "error"
    assert "integrity" in b.error
    assert a.done and a.finish_reason == "length" and a.error is None
    assert a.out == base[0].out, "quarantining b changed a's tokens"
    reg = eng.pool.metrics
    assert reg.value("repro_engine_fault_watchdog_violations") == 1
    assert reg.value("repro_engine_fault_requests", kind="watchdog") == 1
    eng.pool.validate()
    assert eng.pool.free_blocks == eng.pool.n_usable


# ---------------------------------------------------------------------------
# Backpressure: bounded queue, shed, resubmit
# ---------------------------------------------------------------------------

def test_max_queue_sheds_with_retry_after_and_resubmit_recovers():
    cfg, params = _setup("mamba2-130m")
    rng = np.random.default_rng(6)
    p_a = rng.integers(0, cfg.vocab, (5,), dtype=np.int32)
    p_b = rng.integers(0, cfg.vocab, (7,), dtype=np.int32)
    base, _, _ = _run_engine(params, cfg, [p_b], quant=None, max_new=4)

    eng = E.Engine(params, cfg, n_slots=2, max_len=32, paged=True,
                   block_size=4, chunk_tokens=3, max_queue=1)
    a = E.Request(prompt=p_a.copy(), max_new_tokens=4)
    b = E.Request(prompt=p_b.copy(), max_new_tokens=4)
    ha = eng.submit(a)                 # fills the one queue seat
    hb = eng.submit(b)                 # shed: queue is at max_queue
    assert b.done and b.finish_reason == "rejected"
    assert "queue full" in b.error and hb.error == b.error
    assert hb.retry_after is not None and hb.retry_after > 0
    reg = eng.pool.metrics
    assert reg.value("repro_sched_shed_requests") == 1
    assert reg.value("repro_sched_shed_retry_after") == b.retry_after
    assert b.out == []                 # shed before any admission

    ha.result()                        # drain the queue
    assert a.done and a.finish_reason == "length"
    hint = b.retry_after               # resubmit clears the hint
    delays = []
    hb.resubmit(sleep=delays.append)   # injectable backoff clock
    assert delays and delays[0] >= min(2.0, max(hint, 0.05)) - 1e-9
    assert not b.done, "resubmit must have re-queued the request"
    out = hb.result()
    assert out.finish_reason == "length" and out.error is None
    assert b.out == base[0].out, "a shed/resubmit cycle changed tokens"
    assert eng.pool.slots.free_slots == eng.pool.slots.n_slots


def test_shed_rate_bounded_under_overload():
    """2x overload against a bounded queue: some requests shed, some
    serve, nobody hangs, and every shed carries the hint."""
    cfg, params = _setup("mamba2-130m")
    rng = np.random.default_rng(10)
    eng = E.Engine(params, cfg, n_slots=2, max_len=32, paged=True,
                   block_size=4, chunk_tokens=3, max_queue=2)
    reqs = [E.Request(prompt=rng.integers(0, cfg.vocab, (5,),
                                          dtype=np.int32),
                      max_new_tokens=2) for _ in range(8)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    shed = [r for r in reqs if r.finish_reason == "rejected"]
    served = [r for r in reqs if r.finish_reason == "length"]
    assert len(shed) + len(served) == len(reqs)
    assert shed and served, (len(shed), len(served))
    for r in shed:
        assert r.retry_after is not None and r.retry_after > 0
        assert r.out == []
    assert eng.pool.slots.free_slots == eng.pool.slots.n_slots


# ---------------------------------------------------------------------------
# Satellites: StreamHandle idempotency, mid-chunk timeout regression
# ---------------------------------------------------------------------------

def test_double_submit_is_idempotent():
    """Submitting the same request twice while it is in flight must not
    enqueue it twice (a duplicate would double-release through free()'s
    strict path at finish)."""
    cfg, params = _setup("mamba2-130m")
    rng = np.random.default_rng(14)
    eng = E.Engine(params, cfg, n_slots=2, max_len=32, paged=True,
                   block_size=4, chunk_tokens=3)
    r = E.Request(prompt=rng.integers(0, cfg.vocab, (5,), dtype=np.int32),
                  max_new_tokens=4)
    h1 = eng.submit(r)
    h2 = eng.submit(r)                 # same engine, in flight: no-op
    assert h2.req is r
    assert list(eng.scheduler.waiting).count(r) == 1
    eng.step()                         # r admitted
    eng.submit(r)                      # still in flight: no-op again
    assert r not in eng.scheduler.waiting
    h1.result()
    assert r.done and r.finish_reason == "length" and len(r.out) == 4
    assert eng.pool.slots.free_slots == eng.pool.slots.n_slots
    # contiguous engine: same guard on the plain queue
    eng2 = E.Engine(params, cfg, n_slots=2, max_len=32)
    q = E.Request(prompt=rng.integers(0, cfg.vocab, (4,), dtype=np.int32),
                  max_new_tokens=2)
    eng2.submit(q), eng2.submit(q)
    assert eng2.queue.count(q) == 1
    eng2.run()
    assert q.done and len(q.out) == 2


def test_cancel_after_finish_is_a_clean_no():
    cfg, params = _setup("mamba2-130m")
    rng = np.random.default_rng(15)
    eng = E.Engine(params, cfg, n_slots=2, max_len=32, paged=True,
                   block_size=4, chunk_tokens=3)
    r = E.Request(prompt=rng.integers(0, cfg.vocab, (5,), dtype=np.int32),
                  max_new_tokens=3)
    h = eng.submit(r)
    h.result()
    assert r.done and r.finish_reason == "length"
    n = len(r.out)
    assert h.cancel() is False         # already finished
    assert h.cancel() is False         # and again: still a clean no
    assert r.finish_reason == "length" and len(r.out) == n
    eng.pool.validate()                # no double-release happened
    assert eng.pool.slots.free_slots == eng.pool.slots.n_slots


def test_timeout_mid_chunk_releases_partial_chain():
    """Regression (ISSUE 9 satellite): a deadline expiring while the
    prompt is mid-stream through chunked prefill must release the
    partially-written chain through the refcount path -- zero leaked
    blocks, zero leaked slots, and the surviving request untouched."""
    cfg, params = _setup("mixtral-8x7b", n_layers=2, window=8)
    kv8 = _kv8(cfg)
    t = [0.0]
    eng = E.Engine(params, cfg, n_slots=2, max_len=32, quant=kv8,
                   paged=True, block_size=4, chunk_tokens=3,
                   clock=lambda: t[0])
    rng = np.random.default_rng(16)
    a = E.Request(prompt=rng.integers(0, cfg.vocab, (4,), dtype=np.int32),
                  max_new_tokens=6)
    b = E.Request(prompt=rng.integers(0, cfg.vocab, (24,), dtype=np.int32),
                  max_new_tokens=2, timeout=5.0)
    eng.submit(a), eng.submit(b)
    for _ in range(3):
        assert eng.step()
    seq_b = next(s for s in eng.scheduler.running if s.req is b)
    assert seq_b.prefilling and 0 < seq_b.length < 24, \
        "the deadline must expire with the chain partially written"
    held = len(seq_b.blocks)
    assert held > 0
    t[0] = 10.0                        # expire mid-chunk
    assert eng.step()
    assert b.done and b.finish_reason == "timeout" and b.out == []
    # b's partial chain went back through the refcount path: the only
    # live references left are a's
    model = Counter(int(blk) for s in eng.scheduler.running
                    for blk in s.blocks)
    assert dict(model) == {blk: n for blk, n in eng.pool._ref.items()
                           if n > 0}, "mid-chunk expiry leaked references"
    eng.run()
    assert a.done and a.finish_reason == "length" and len(a.out) == 6
    eng.pool.validate()
    assert eng.pool.free_blocks == eng.pool.n_usable
