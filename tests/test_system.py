"""End-to-end system behaviour: train -> checkpoint -> quantize (paper
technique) -> serve, as one pipeline."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import DataSpec
from repro.models import model as M
from repro.models.config import QuantConfig
from repro.serving import engine as E
from repro.train.trainer import TrainConfig, Trainer


def test_train_quantize_serve_pipeline(tmp_path):
    """The full lifecycle the framework exists for: train a model with
    the fault-tolerant trainer, quantize its weights to packed bipolar
    planes (W4A8), and serve greedy completions that match the bf16
    model's on a learnable stream."""
    cfg = get_config("llama3-8b").reduced(n_layers=2, vocab=256)
    spec = DataSpec(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=5)
    tcfg = TrainConfig(num_steps=40, peak_lr=1e-3, warmup_steps=5,
                       ckpt_dir=str(tmp_path), ckpt_every=20)
    state, hist = Trainer(cfg, tcfg, spec, async_ckpt=False).run(resume=False)
    assert hist[-1] < hist[0]                       # learned something

    params = state["params"]
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, (8,), dtype=np.int32)

    def greedy(p, quant):
        eng = E.Engine(p, cfg, n_slots=1, max_len=32, quant=quant)
        req = E.Request(prompt=prompt, max_new_tokens=6)
        eng.submit(req)
        eng.run()
        return req.out

    out_bf = greedy(params, None)

    # W8A8 is near-lossless: the whole greedy chain must match bf16
    # (autoregressive chains compound any flip, so this is a strict check)
    q8 = QuantConfig(w_bits=8, a_bits=8)
    out_q8 = greedy(M.quantize_params(params, q8), q8)
    assert out_q8 == out_bf, (out_q8, out_bf)

    # W4A8 (aggressive): must complete and agree on the first
    # (non-compounding) greedy token
    q4 = QuantConfig(w_bits=4, a_bits=8)
    out_q4 = greedy(M.quantize_params(params, q4), q4)
    assert len(out_q4) == 6
    assert out_q4[0] == out_bf[0], (out_q4, out_bf)
