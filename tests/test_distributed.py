"""Distribution tests on multi-device host meshes.

Each test runs in a subprocess so it can set
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before jax init
(the main test process must keep seeing 1 device -- task spec)."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.distributed import sharding as S
from repro.distributed.compress import compressed_psum, dp_train_step
from repro.models import model as M
from repro.data.pipeline import DataSpec, batch_at
cfg = get_config("llama3-8b").reduced(n_layers=2)
params = M.init_params(cfg, jax.random.PRNGKey(0))
spec = DataSpec(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=0)
batch = {k: jnp.asarray(v) for k, v in batch_at(spec, 0).items()}
"""


def _run(body: str):
    r = subprocess.run(
        [sys.executable, "-c", PRELUDE + body],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")})
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "OK" in r.stdout


def test_pjit_sharded_train_step_matches_single_device():
    _run("""
loss0 = float(M.loss_fn(params, batch, cfg))
mesh = make_host_mesh(4, 2)
S.set_activation_context(mesh)
ps = S.shardings_for_params(mesh, params)
bs = S.shardings_for_batch(mesh, batch)
params_sh = jax.device_put(params, ps)
batch_sh = jax.device_put(batch, bs)
fn = jax.jit(lambda p, b: M.loss_fn(p, b, cfg))
loss1 = float(fn(params_sh, batch_sh))
assert abs(loss1 - loss0) < 0.05, (loss0, loss1)
grads = jax.jit(jax.grad(lambda p, b: M.loss_fn(p, b, cfg)))(params_sh, batch_sh)
for g in jax.tree.leaves(grads):
    assert np.all(np.isfinite(np.asarray(g, dtype=np.float32)))
print("OK")
""")


def test_param_shardings_actually_shard():
    _run("""
mesh = make_host_mesh(4, 2)
ps = S.shardings_for_params(mesh, params)
params_sh = jax.device_put(params, ps)
import numpy as np
sharded = sum(
    1 for p in jax.tree.leaves(params_sh)
    if p.sharding.num_devices > 1 and not p.sharding.is_fully_replicated)
total = len(jax.tree.leaves(params_sh))
assert sharded >= total // 3, (sharded, total)
# per-device bytes must be well under replicated bytes
full = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
local = sum(x.addressable_shards[0].data.size * x.dtype.itemsize
            for x in jax.tree.leaves(params_sh))
assert local < full * 0.55, (local, full)
print("OK")
""")


def test_compressed_dp_allreduce_close_to_exact():
    _run("""
mesh1d = jax.make_mesh((8,), ("data",))
loss_fn = lambda p, b: M.loss_fn(p, b, cfg)
step_c = jax.jit(dp_train_step(loss_fn, mesh1d, compress=True))
step_e = jax.jit(dp_train_step(loss_fn, mesh1d, compress=False))
lc, gc = step_c(params, batch)
le, ge = step_e(params, batch)
assert abs(float(lc) - float(le)) < 1e-3
num = 0.0; den = 0.0
for a, b in zip(jax.tree.leaves(gc), jax.tree.leaves(ge)):
    a = np.asarray(a, dtype=np.float32); b = np.asarray(b, dtype=np.float32)
    num += float(np.sum((a - b) ** 2)); den += float(np.sum(b ** 2))
rel = (num / max(den, 1e-30)) ** 0.5
assert rel < 0.05, rel            # int8 wire error is small
# wire volume: int8 codes are 4x smaller than f32 (documented claim)
print("OK")
""")


def test_elastic_restore_onto_different_mesh(tmp_path):
    _run(f"""
from repro.checkpoint import manager as CM
import os
d = {str(tmp_path)!r}
CM.save_tree(params, d, 1)
mesh = make_host_mesh(2, 4)       # DIFFERENT topology than training
ps = S.shardings_for_params(mesh, params)
restored, meta = CM.restore_tree(params, d, shardings=ps)
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert b.sharding.mesh.shape == {{"data": 2, "model": 4}}
print("OK")
""")


def test_gpipe_pipeline_matches_sequential():
    _run("""
from repro.distributed.pipeline import pipeline_apply
import functools
n_stages, n_micro, mb, d = 4, 8, 2, 16
mesh = jax.make_mesh((4,), ("pipe",))
keys = jax.random.split(jax.random.PRNGKey(0), n_stages)
ws = jnp.stack([jax.random.normal(k, (d, d)) / np.sqrt(d) for k in keys])
def stage_fn(w, x):
    return jnp.tanh(x @ w)
x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))
# sequential reference
ref = x
for s in range(n_stages):
    ref = jnp.tanh(ref @ ws[s])
run = pipeline_apply(stage_fn, n_stages, n_micro, axis="pipe")
out = run(mesh, ws, x)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)
print("OK")
""")
