"""Minimal stand-in for ``hypothesis`` so property tests *skip* cleanly.

Without this, an unconditional ``from hypothesis import ...`` kills the
whole tier-1 run at collection on machines without the dev extra.  The
fallback mimics just enough of the API surface the test files touch:
``@given(...)`` replaces the test with a skip, ``@settings(...)`` is a
no-op, and ``st.<strategy>(...)`` returns placeholders that are never
drawn from.  Install the real thing via ``requirements-dev.txt`` to run
the property sweeps.
"""

import pytest


def given(*_args, **_kwargs):
    def deco(_fn):
        @pytest.mark.skip(reason="hypothesis not installed "
                                 "(pip install -r requirements-dev.txt)")
        def skipped():
            pass
        skipped.__name__ = _fn.__name__
        skipped.__doc__ = _fn.__doc__
        return skipped
    return deco


def settings(*_args, **_kwargs):
    return lambda fn: fn


class _Strategies:
    def __getattr__(self, _name):
        return lambda *a, **k: None


st = _Strategies()
