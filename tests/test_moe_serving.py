"""MoE serving: the grouped expert-kernel rewire + capacity telemetry.

The rewire acceptance bar (ISSUE 8): with ``layers.GROUPED_MOE`` on
(one ``ap_moe_expert_linear`` launch pair per MoE layer) the paged
engine's greedy decode must be TOKEN-IDENTICAL to the pre-rewire
batched-over-E expert path on the MoE smoke configs -- equality, not
tolerance, because the grouped kernel's live rows are bit-identical to
``layers._expert_matmul`` and the combine gather never reads a dead
capacity row.  Rides along: the decode capacity clamp (satellite 1)
cannot change routing, and the ``metrics=True`` engine surfaces the
``repro_moe_*`` capacity-pressure series.
"""

import contextlib
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import layers as L
from repro.models import model as M
from repro.serving import engine as E

MOE_SMOKE = ["mixtral-8x7b", "deepseek-moe-16b"]


@contextlib.contextmanager
def _grouped_moe(flag):
    """Flip the module-level grouped/legacy expert-path switch.

    The engine's steps are jitted with static (cfg, quant) only -- the
    flag is read at trace time, so both flips MUST drop the jit cache
    or the step would silently keep running the previously-traced
    path."""
    old = L.GROUPED_MOE
    L.GROUPED_MOE = flag
    jax.clear_caches()
    try:
        yield
    finally:
        L.GROUPED_MOE = old
        jax.clear_caches()


def _setup(name):
    cfg = get_config(name).reduced(n_layers=2)
    qcfg = dataclasses.replace(cfg.quant, kv_bits=8)
    assert qcfg.w_bits is not None, "MoE smoke configs ship quantized"
    params = M.quantize_params(M.init_params(cfg, jax.random.PRNGKey(1)),
                               qcfg)
    return cfg, qcfg, params


def _decode(params, cfg, qcfg, prompts, **kw):
    eng = E.Engine(params, cfg, n_slots=2, max_len=32, quant=qcfg,
                   paged=True, block_size=8, **kw)
    reqs = [E.Request(prompt=p.copy(), max_new_tokens=5) for p in prompts]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done and r.error is None for r in reqs)
    return [list(r.out) for r in reqs], eng


def _prompts(cfg, seed=11, n=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (5 + i,), dtype=np.int32)
            for i in range(n)]


def test_grouped_rewire_token_identical():
    """Paged greedy decode pre/post rewire, both MoE smoke archs
    (mixtral: all-MoE layers; deepseek-moe: first_dense prelude layer,
    so the dense and MoE block paths coexist in one forward)."""
    for name in MOE_SMOKE:
        cfg, qcfg, params = _setup(name)
        prompts = _prompts(cfg)
        with _grouped_moe(True):
            out_grouped, _ = _decode(params, cfg, qcfg, prompts)
        with _grouped_moe(False):
            out_legacy, _ = _decode(params, cfg, qcfg, prompts)
        assert out_grouped == out_legacy, (name, out_grouped, out_legacy)


def test_decode_capacity_clamped_without_changing_outputs():
    """Satellite 1: with t live tokens the dispatch can never hold more
    than t*k assignments per expert, so capacity rows above that bound
    are pure waste -- the clamp must remove them (smaller kernel grid)
    while keeping routing, outputs, and drop counts identical."""
    cfg, qcfg, _ = _setup("mixtral-8x7b")
    e, k = cfg.n_experts, cfg.top_k
    p = M.quantize_params(L.moe_init(jax.random.PRNGKey(3), cfg), qcfg)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (1, 1, cfg.d_model)), jnp.bfloat16)          # decode shape: t = 1
    big = dataclasses.replace(cfg, capacity_factor=16.0)  # ceil formula: 8
    y_big, _, st_big = L.moe_apply(p, x, big, quant=qcfg)
    assert int(st_big["capacity"]) == e * k, \
        "capacity must clamp to t*k live-token rows, not the ceil formula"
    assert int(st_big["dropped"]) == 0, \
        "the clamp only removes rows no token could ever occupy"
    # a factor whose ceil formula lands exactly on the clamp bound must
    # produce the same dispatch -- and therefore the same output bits
    y_ref, _, st_ref = L.moe_apply(p, x, cfg, quant=qcfg)
    np.testing.assert_array_equal(np.asarray(y_big), np.asarray(y_ref))
    assert int(st_big["load"].sum()) == int(st_ref["load"].sum()) == k


def test_moe_telemetry_surfaces_expert_load():
    """metrics=True engine on a MoE arch must emit the repro_moe_*
    series: one expert-load histogram sample per (layer, expert) per
    forward, and a capacity-utilization gauge in (0, 1]."""
    cfg, qcfg, params = _setup("mixtral-8x7b")
    _, eng = _decode(params, cfg, qcfg, _prompts(cfg), metrics=True)
    snap = eng.obs.registry.snapshot()
    n_load = snap.get("repro_moe_expert_load_count", 0.0)
    assert n_load > 0, "no expert-load samples reached the registry"
    assert n_load % cfg.n_layers == 0, \
        "each forward must report every MoE layer's expert-load row"
    util = snap.get("repro_moe_capacity_utilization", 0.0)
    assert 0.0 < util <= 1.0, snap
    # greedy decode at top_k=2, capacity clamped to t*k: nothing dropped
    assert snap.get("repro_moe_dropped_tokens_total", 0.0) == 0.0


def test_legacy_fallback_unquantized_params():
    """Float (unquantized) expert weights must keep taking the dense
    einsum fallback -- the grouped kernel only claims BipolarTensor
    experts -- and still serve end to end."""
    cfg = get_config("mixtral-8x7b").reduced(n_layers=2)
    qcfg = dataclasses.replace(cfg.quant, w_bits=None, kv_bits=8)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    out, _ = _decode(params, cfg, qcfg, _prompts(cfg))
    assert all(len(o) == 5 for o in out)
