"""Grouped MoE expert kernel vs the legacy batched-over-E path.

The contract under test (ops.ap_moe_expert_linear): per dispatch-group
segment, rows below the keep count are BIT-identical to the pre-rewire
oracle ``layers._expert_matmul`` (same f32 quantization chain, same
epilogue cast point), rows at-or-above it are exact zeros, and the
interpret impl's kernel-reported live-tile map equals the reference
impl's analytic one -- the proof that ``pl.when`` actually skipped the
empty capacity tiles rather than computing zeros the hard way.
"""

import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.models import layers as L
from repro.models.config import QuantConfig
from repro.models.model import _quantize_leaf

RNG = np.random.default_rng(42)

# deliberately odd: SEG not a multiple of 8, K not a multiple of 32,
# N not a multiple of 128 -- every pad path in the op is exercised
E, G, SEG, K, N = 3, 2, 5, 37, 19
C = G * SEG

# activation/weight bit pairs spanning the full 1..8 arbitrary range
BIT_PAIRS = [(1, 1), (2, 2), (3, 4), (4, 4), (5, 6), (7, 7), (8, 3), (8, 8)]
# interpret runs the real kernel body in python -- keep its matrix small
INTERP_BITS = [(1, 1), (3, 4), (8, 8)]


def _weights(nb, *, seed, n=N, k=K):
    w = np.asarray(
        np.random.default_rng(seed).standard_normal((E, n, k)) / np.sqrt(k),
        np.float32)
    return _quantize_leaf(jnp.asarray(w), QuantConfig(w_bits=nb),
                          stacked=False)


def _acts(dtype, *, k=K):
    x = RNG.standard_normal((E, C, k)).astype(np.float32)
    return jnp.asarray(x, dtype)


def _counts(fills):
    """counts (E, G) from an explicit per-(e, g) fill list."""
    c = np.asarray(fills, np.int32).reshape(E, G)
    assert c.max() <= SEG
    return jnp.asarray(c)


DEFAULT_COUNTS = _counts([[5, 2], [3, 0], [1, 4]])  # mixed partial fills


def _live_rows(counts):
    """(E, C) bool: which capacity rows hold a kept token."""
    off = np.arange(C) % SEG
    grp = np.arange(C) // SEG
    return np.asarray(counts)[:, grp] > off[None, :]


def _legacy_single(w, x, a_bits):
    return L._expert_matmul(w, x, types.SimpleNamespace(a_bits=a_bits))


def _legacy_dual(wg, wu, x, a_bits):
    """The legacy gate/up composition from moe_apply: one shared
    activation quantization, silu(gate) * up composed in f32 (no
    intermediate narrowing cast), one cast back at the end."""
    q = types.SimpleNamespace(a_bits=a_bits)
    pre = L._expert_quantize(x, a_bits)
    gate = L._expert_matmul(wg, x, q, pre, out_dtype=jnp.float32)
    up = L._expert_matmul(wu, x, q, pre, out_dtype=jnp.float32)
    return (jax.nn.silu(gate) * up).astype(x.dtype)


def _assert_rows(y, oracle, counts):
    """Live rows bit-identical to the oracle; dead rows exact zeros."""
    y, oracle = np.asarray(y), np.asarray(oracle)
    live = _live_rows(counts)
    np.testing.assert_array_equal(y[live], oracle[live])
    assert not y[~live].any(), "dead capacity rows must be exact zeros"


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("bits", BIT_PAIRS,
                         ids=[f"a{a}w{b}" for a, b in BIT_PAIRS])
def test_reference_matches_legacy(bits, dtype):
    a_bits, w_bits = bits
    w = _weights(w_bits, seed=w_bits)
    x = _acts(dtype)
    y = ops.ap_moe_expert_linear(x, w, counts=DEFAULT_COUNTS, a_bits=a_bits,
                                 impl="reference")
    _assert_rows(y, _legacy_single(w, x, a_bits), DEFAULT_COUNTS)


@pytest.mark.parametrize("variant", ["fused", "bitserial"])
@pytest.mark.parametrize("bits", INTERP_BITS,
                         ids=[f"a{a}w{b}" for a, b in INTERP_BITS])
def test_interpret_matches_legacy_and_skips_dead_tiles(bits, variant):
    a_bits, w_bits = bits
    w = _weights(w_bits, seed=10 + w_bits)
    x = _acts(jnp.bfloat16)
    y, live = ops.ap_moe_expert_linear(
        x, w, counts=DEFAULT_COUNTS, a_bits=a_bits, variant=variant,
        impl="interpret", with_stats=True)
    _assert_rows(y, _legacy_single(w, x, a_bits), DEFAULT_COUNTS)
    # skip-path proof: the kernel-reported live-tile map must equal the
    # reference impl's analytic map -- tiles the analytic map calls dead
    # were dead in-kernel too (pl.when really skipped them)
    _, live_ref = ops.ap_moe_expert_linear(
        x, w, counts=DEFAULT_COUNTS, a_bits=a_bits, variant=variant,
        impl="reference", with_stats=True)
    np.testing.assert_array_equal(np.asarray(live), np.asarray(live_ref))
    n_skipped = int(np.asarray(live).size - np.asarray(live).sum())
    assert n_skipped == int((np.asarray(DEFAULT_COUNTS) == 0).sum()), \
        "one whole capacity tile per empty (expert, group) must be skipped"


@pytest.mark.parametrize("impl", ["reference", "interpret"])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_dual_gate_up_matches_legacy_composition(impl, dtype):
    a_bits, w_bits = 8, 2
    wg = _weights(w_bits, seed=1)
    wu = _weights(w_bits, seed=2)
    x = _acts(dtype)
    y = ops.ap_moe_expert_linear(x, wg, w2=wu, counts=DEFAULT_COUNTS,
                                 a_bits=a_bits, act="silu", impl=impl)
    _assert_rows(y, _legacy_dual(wg, wu, x, a_bits), DEFAULT_COUNTS)


@pytest.mark.parametrize("impl", ["reference", "interpret"])
def test_empty_expert_and_all_dropped_group(impl):
    a_bits, w_bits = 6, 3
    w = _weights(w_bits, seed=3)
    x = _acts(jnp.bfloat16)
    # expert 1 receives nothing anywhere; group 1 dropped every token
    counts = _counts([[4, 0], [0, 0], [2, 0]])
    y, live = ops.ap_moe_expert_linear(x, w, counts=counts, a_bits=a_bits,
                                       impl=impl, with_stats=True)
    _assert_rows(y, _legacy_single(w, x, a_bits), counts)
    live = np.asarray(live).reshape(E, G, -1)
    assert not live[1].any(), "empty expert must report zero live tiles"
    assert not live[:, 1].any(), "all-dropped group must report zero tiles"


@pytest.mark.parametrize("impl", ["reference", "interpret"])
def test_full_capacity_no_dead_rows(impl):
    a_bits, w_bits = 4, 4
    w = _weights(w_bits, seed=4)
    x = _acts(jnp.bfloat16)
    counts = _counts([[SEG] * G] * E)
    y = ops.ap_moe_expert_linear(x, w, counts=counts, a_bits=a_bits,
                                 impl=impl)
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(_legacy_single(w, x, a_bits)))


@pytest.mark.parametrize("impl", ["reference", "interpret"])
def test_two_stage_chain_bit_stable_under_jit(impl):
    """The engine runs both expert GEMM stages inside one jit graph.
    The grouped chain (dual gate/up -> down) and the barrier-pinned
    legacy composition must agree bitwise, compiled or eager -- the
    regression test for XLA's excess-precision convert elision, which
    rounds the f32->bf16->f32 boundary between fused stages differently
    than the materialized HBM round-trip the kernel performs."""
    a_bits = 8
    q = types.SimpleNamespace(a_bits=a_bits)
    wg, wu = _weights(2, seed=6), _weights(2, seed=7)
    wd = _weights(2, seed=8, n=K, k=N)
    x = _acts(jnp.bfloat16)

    def grouped(xx):
        h = ops.ap_moe_expert_linear(xx, wg, w2=wu, counts=DEFAULT_COUNTS,
                                     a_bits=a_bits, act="silu", impl=impl)
        return ops.ap_moe_expert_linear(h, wd, counts=DEFAULT_COUNTS,
                                        a_bits=a_bits, impl=impl)

    def legacy(xx):
        # the quantized fallback branch of moe_apply, barriers included
        xx = jax.lax.optimization_barrier(xx)
        pre = L._expert_quantize(xx, a_bits)
        gate = L._expert_matmul(wg, xx, q, pre, out_dtype=jnp.float32)
        up = L._expert_matmul(wu, xx, q, pre, out_dtype=jnp.float32)
        h = jax.lax.optimization_barrier(
            (jax.nn.silu(gate) * up).astype(xx.dtype))
        return jax.lax.optimization_barrier(L._expert_matmul(wd, h, q))

    yg_e, yg_j = np.asarray(grouped(x)), np.asarray(jax.jit(grouped)(x))
    yl_e, yl_j = np.asarray(legacy(x)), np.asarray(jax.jit(legacy)(x))
    np.testing.assert_array_equal(yg_e, yg_j)
    np.testing.assert_array_equal(yl_e, yl_j)
    live = _live_rows(DEFAULT_COUNTS)
    np.testing.assert_array_equal(yg_j[live], yl_j[live])


def test_single_group_matches_multi_group_live_rows():
    # G=1 (the decode-shape dispatch) against the same tokens split G=2:
    # live rows only, since the dead-row placement differs by grouping
    a_bits, w_bits = 8, 2
    w = _weights(w_bits, seed=5)
    x = _acts(jnp.bfloat16)
    counts1 = jnp.asarray(np.asarray(DEFAULT_COUNTS).sum(1, keepdims=True))
    # rebuild x so each expert's kept tokens form one prefix
    live = _live_rows(DEFAULT_COUNTS)
    xc = np.zeros_like(np.asarray(x, np.float32))
    for e in range(E):
        rows = np.asarray(x, np.float32)[e][live[e]]
        xc[e, :len(rows)] = rows
    xc = jnp.asarray(xc, x.dtype)
    y1 = ops.ap_moe_expert_linear(xc, w, counts=counts1, a_bits=a_bits,
                                  impl="reference")
    y2 = ops.ap_moe_expert_linear(x, w, counts=DEFAULT_COUNTS,
                                  a_bits=a_bits, impl="reference")
    y1, y2 = np.asarray(y1), np.asarray(y2)
    for e in range(E):
        n_live = int(live[e].sum())
        np.testing.assert_array_equal(y1[e, :n_live], y2[e][live[e]])
