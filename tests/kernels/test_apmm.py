"""APMM Pallas kernel vs pure-jnp oracle: shape/dtype/bit-width sweeps.

All Pallas kernels execute under ``interpret=True`` (kernel body run in
Python on CPU); the oracle is :mod:`repro.kernels.ref`, itself validated
bit-exactly against plain integer matmul in test_bipolar.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:    # property tests skip (not error) without hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.core import bipolar
from repro.kernels import ops, pack, ref

RNG = np.random.default_rng(42)


def _rand(m, k, dtype=np.float32, scale=2.0):
    return (RNG.standard_normal((m, k)) * scale).astype(dtype)


def _quant_pair(m, n, k, n_a, n_b):
    a = jnp.array(_rand(m, k))
    b = jnp.array(_rand(n, k))
    at = ops.quantize_rows(a, n_a, pad_bit=0, impl="reference")
    bt = ops.quantize_rows(b, n_b, pad_bit=1, impl="reference")
    return at, bt


# --- full sweep: shapes x bits x variants, bit-exact int32 ----------------

SHAPES = [
    (8, 16, 32),       # single tile, word-aligned
    (8, 16, 70),       # K not a multiple of 32 -> pad correction
    (130, 257, 100),   # nothing aligned
    (256, 256, 512),   # exactly the default tile
    (300, 130, 1100),  # multi-tile in every dim with remainders
]
BIT_PAIRS = [(1, 1), (1, 2), (2, 2), (3, 4), (4, 4), (7, 7), (8, 3), (8, 8)]


@pytest.mark.parametrize("shape", SHAPES, ids=[str(s) for s in SHAPES])
@pytest.mark.parametrize("bits", BIT_PAIRS, ids=[f"W{b}A{a}" for a, b in BIT_PAIRS])
@pytest.mark.parametrize("variant", ["fused", "bitserial"])
def test_kernel_matches_oracle_int(shape, bits, variant):
    m, n, k = shape
    n_a, n_b = bits
    at, bt = _quant_pair(m, n, k, n_a, n_b)
    y_ref = np.asarray(ops.ap_matmul(at, bt, raw=True, impl="reference"))
    y_ker = np.asarray(ops.ap_matmul(at, bt, raw=True, impl="interpret",
                                     variant=variant))
    np.testing.assert_array_equal(y_ker, y_ref)


@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dequant_matches_oracle(out_dtype):
    at, bt = _quant_pair(64, 48, 130, 2, 3)
    y_ref = np.asarray(ops.ap_matmul(at, bt, impl="reference",
                                     out_dtype=out_dtype)).astype(np.float32)
    y_ker = np.asarray(ops.ap_matmul(at, bt, impl="interpret",
                                     out_dtype=out_dtype)).astype(np.float32)
    np.testing.assert_allclose(y_ker, y_ref, rtol=1e-2, atol=1e-2)


@given(m=st.integers(1, 70), n=st.integers(1, 70), k=st.integers(1, 200),
       n_a=st.integers(1, 4), n_b=st.integers(1, 4))
@settings(max_examples=10, deadline=None)
def test_kernel_property_sweep(m, n, k, n_a, n_b):
    at, bt = _quant_pair(m, n, k, n_a, n_b)
    y_ref = np.asarray(ops.ap_matmul(at, bt, raw=True, impl="reference"))
    y_ker = np.asarray(ops.ap_matmul(at, bt, raw=True, impl="interpret"))
    np.testing.assert_array_equal(y_ker, y_ref)


# --- pack kernel ----------------------------------------------------------

@pytest.mark.parametrize("n_bits", [1, 2, 3, 4, 7])
@pytest.mark.parametrize("rk", [(8, 32), (100, 70), (256, 1024), (33, 96)])
def test_pack_kernel_matches_reference(n_bits, rk):
    r, k = rk
    x = jnp.array(_rand(r, k))
    t_ref = ops.quantize_rows(x, n_bits, pad_bit=0, impl="reference")
    t_ker = ops.quantize_rows(x, n_bits, pad_bit=0, impl="interpret")
    np.testing.assert_array_equal(np.asarray(t_ker.packed),
                                  np.asarray(t_ref.packed))
    np.testing.assert_allclose(np.asarray(t_ker.scale),
                               np.asarray(t_ref.scale))


def test_pack_kernel_weight_pad_bit():
    """Weight padding must be all-one bits (pad value +scale*maxv)."""
    x = jnp.array(_rand(4, 40))  # 40 -> padded to 64: 24 pad bits
    t_ref = ops.quantize_rows(x, 3, pad_bit=1, impl="reference")
    t_ker = ops.quantize_rows(x, 3, pad_bit=1, impl="interpret")
    np.testing.assert_array_equal(np.asarray(t_ker.packed),
                                  np.asarray(t_ref.packed))


# --- end-to-end linear ----------------------------------------------------

@pytest.mark.parametrize("impl", ["reference", "interpret"])
@pytest.mark.parametrize("w_bits,tol", [(4, 0.20), (8, 0.02)])
def test_ap_linear_close_to_float(impl, w_bits, tol):
    """Quantized linear tracks the float matmul within the bit-width's
    quantization error (absmax W4 step is ~13% of range; W8 ~0.8%)."""
    x = jnp.array(_rand(5, 7 * 64).reshape(5, 7, 64) / 4)
    w = jnp.array(_rand(32, 64) / 8)
    wt = ops.pack_weight(w, w_bits, impl="reference")
    y_q = np.asarray(ops.ap_linear(x, wt, a_bits=8, impl=impl))
    y_f = np.asarray(x) @ np.asarray(w).T
    rel = np.abs(y_q - y_f).mean() / (np.abs(y_f).mean() + 1e-9)
    assert rel < tol, rel
    assert y_q.shape == (5, 7, 32)


def test_ap_linear_batched_shapes():
    x = jnp.array(_rand(2, 3 * 96).reshape(2, 3, 96))
    wt = ops.pack_weight(jnp.array(_rand(17, 96)), 2, impl="reference")
    y = ops.ap_linear(x, wt, a_bits=4, impl="reference")
    assert y.shape == (2, 3, 17)
    assert not np.any(np.isnan(np.asarray(y)))
