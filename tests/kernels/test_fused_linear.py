"""One-kernel fused quantized linear (``ops.ap_linear_fused``).

Contract under test (tests run under reference AND interpret via the CI
``kernels-impl`` matrix, plus explicit cross-impl checks here):

* the fused path is *bit-identical* to the unfused composition
  (``quantize_rows`` launch -> ``ap_matmul`` launch -> jnp epilogue) --
  the property that makes greedy decode token-identical by construction;
* reference and interpret agree bit-exactly on the integer core and
  bitwise on the epilogue (same cast points);
* the epilogue flags (bias, act, residual, dual-GEMM gate/up) compose;
* the ``bitserial`` variant holds at ``n_bits >= 8`` (where single-group
  operand recovery would overflow int8) and on non-multiple-of-tile
  M/N/K shapes;
* ``ap_matmul`` accepts operands packed to different K word-widths
  (satellite regression: pad to common width instead of asserting).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops

RNG = np.random.default_rng(21)

# (M, N, K): single aligned tile; odd-K pad correction; nothing aligned
SHAPES = [(8, 128, 64), (5, 33, 70), (130, 257, 100)]
BITS = [2, 4, 8]


def _inputs(m, n, k, w_bits, seed=0):
    rng = np.random.default_rng((m, n, k, w_bits, seed))
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = ops.pack_weight(
        jnp.asarray(rng.standard_normal((n, k)), jnp.float32), w_bits,
        impl="reference")
    w2 = ops.pack_weight(
        jnp.asarray(rng.standard_normal((n, k)), jnp.float32), w_bits,
        impl="reference")
    bias = jnp.asarray(rng.standard_normal((n,)), jnp.float32)
    res = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    return x, w, w2, bias, res


def _unfused(x, w, *, a_bits, variant, act="none", w2=None, bias=None,
             residual=None, out_dtype=jnp.bfloat16, impl="reference"):
    """The composed two-launch pipeline the fused kernel must match
    bitwise: ap_linear (quantize-pack launch + GEMM launch) + jnp
    epilogue with the documented cast points."""
    y = ops.ap_linear(x, w, a_bits=a_bits, variant=variant, impl=impl,
                      out_dtype=out_dtype)
    yf = y.astype(jnp.float32)
    if bias is not None:
        # bias adds in f32 before the out-dtype cast, so re-derive the
        # pre-cast f32 product for the biased oracle
        wt = ops.ap_matmul(
            ops.quantize_rows(x.reshape(-1, x.shape[-1]), a_bits,
                              pad_bit=0, impl=impl),
            w, variant=variant, impl=impl, out_dtype=jnp.float32)
        yf = wt.reshape(y.shape) + bias
        y = yf.astype(out_dtype)
    if w2 is not None:
        y2 = ops.ap_linear(x, w2, a_bits=a_bits, variant=variant,
                           impl=impl, out_dtype=out_dtype)
        f = jax.nn.silu if act == "silu" else jax.nn.gelu
        y = (f(y.astype(jnp.float32))
             * y2.astype(jnp.float32)).astype(out_dtype)
    elif act != "none":
        f = jax.nn.silu if act == "silu" else jax.nn.gelu
        y = f(y.astype(jnp.float32)).astype(out_dtype)
    if residual is not None:
        y = y + residual.astype(out_dtype)
    return y


@pytest.mark.parametrize("shape", SHAPES, ids=[str(s) for s in SHAPES])
@pytest.mark.parametrize("w_bits", BITS)
@pytest.mark.parametrize("variant", ["fused", "bitserial"])
def test_fused_linear_bit_identical_to_unfused(shape, w_bits, variant):
    """Plain fused linear == quantize_rows + ap_matmul, bitwise, under
    both impls -- incl. bitserial at n_bits == 8 and odd M/N/K."""
    m, n, k = shape
    x, w, _, _, _ = _inputs(m, n, k, w_bits)
    for impl in ("reference", "interpret"):
        y_f = np.asarray(ops.ap_linear_fused(
            x, w, a_bits=8, variant=variant, impl=impl,
            out_dtype=jnp.bfloat16), np.float32)
        y_u = np.asarray(_unfused(x, w, a_bits=8, variant=variant,
                                  impl=impl), np.float32)
        np.testing.assert_array_equal(y_f, y_u, err_msg=impl)


@pytest.mark.parametrize("shape", SHAPES, ids=[str(s) for s in SHAPES])
@pytest.mark.parametrize("act", ["silu", "gelu"])
def test_fused_epilogue_act_residual(shape, act):
    m, n, k = shape
    x, w, _, _, res = _inputs(m, n, k, 4, seed=1)
    for impl in ("reference", "interpret"):
        y_f = np.asarray(ops.ap_linear_fused(
            x, w, a_bits=8, act=act, residual=res, impl=impl,
            out_dtype=jnp.bfloat16), np.float32)
        y_u = np.asarray(_unfused(x, w, a_bits=8, variant="fused",
                                  act=act, residual=res, impl=impl),
                         np.float32)
        np.testing.assert_array_equal(y_f, y_u, err_msg=impl)


@pytest.mark.parametrize("shape", SHAPES, ids=[str(s) for s in SHAPES])
@pytest.mark.parametrize("variant", ["fused", "bitserial"])
def test_fused_dual_gemm_swiglu(shape, variant):
    """Dual-GEMM gate/up mode: one A-tile stream, silu(gate)*up fused."""
    m, n, k = shape
    x, w, w2, _, res = _inputs(m, n, k, 3, seed=2)
    for impl in ("reference", "interpret"):
        y_f = np.asarray(ops.ap_linear_fused(
            x, w, w2=w2, a_bits=8, act="silu", variant=variant,
            residual=res, impl=impl, out_dtype=jnp.bfloat16), np.float32)
        y_u = np.asarray(_unfused(x, w, a_bits=8, variant=variant,
                                  act="silu", w2=w2, residual=res,
                                  impl=impl), np.float32)
        np.testing.assert_array_equal(y_f, y_u, err_msg=impl)


def test_fused_bias():
    x, w, _, bias, _ = _inputs(24, 40, 67, 4, seed=3)
    for impl in ("reference", "interpret"):
        y_f = np.asarray(ops.ap_linear_fused(
            x, w, a_bits=8, bias=bias, impl=impl,
            out_dtype=jnp.float32), np.float32)
        y_u = np.asarray(_unfused(x, w, a_bits=8, variant="fused",
                                  bias=bias, out_dtype=jnp.float32,
                                  impl=impl), np.float32)
        np.testing.assert_allclose(y_f, y_u, rtol=1e-6, atol=1e-6,
                                   err_msg=impl)


def test_fused_linear_batched_lead_dims():
    x = jnp.asarray(RNG.standard_normal((2, 3, 96)), jnp.float32)
    w = ops.pack_weight(jnp.asarray(RNG.standard_normal((17, 96)),
                                    jnp.float32), 2, impl="reference")
    for impl in ("reference", "interpret"):
        y = ops.ap_linear_fused(x, w, a_bits=4, impl=impl)
        assert y.shape == (2, 3, 17)
        assert not np.any(np.isnan(np.asarray(y)))


def test_fused_linear_close_to_float():
    """W8A8 fused linear with silu tracks the float reference within
    quantization error (sanity that the epilogue math is the function
    we think it is, not just self-consistent)."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((6, 64)) / 4, jnp.float32)
    wg = jnp.asarray(rng.standard_normal((32, 64)) / 8, jnp.float32)
    wu = jnp.asarray(rng.standard_normal((32, 64)) / 8, jnp.float32)
    wgt = ops.pack_weight(wg, 8, impl="reference")
    wut = ops.pack_weight(wu, 8, impl="reference")
    y = np.asarray(ops.ap_linear_fused(
        x, wgt, w2=wut, a_bits=8, act="silu", impl="reference",
        out_dtype=jnp.float32))
    xf = np.asarray(x)
    ref_f = jax.nn.silu(xf @ np.asarray(wg).T) * (xf @ np.asarray(wu).T)
    rel = np.abs(y - np.asarray(ref_f)).mean() / \
        (np.abs(np.asarray(ref_f)).mean() + 1e-9)
    assert rel < 0.05, rel


# --- satellite: mixed K word-widths in ap_matmul --------------------------

@pytest.mark.parametrize("impl", ["reference", "interpret"])
def test_ap_matmul_mixed_k_word_width(impl):
    """Operands packed to different K word-widths (offline weight
    alignment padding) must pad to the common width -- A with zero
    bits, B with one bits -- and produce the identical product."""
    rng = np.random.default_rng(11)
    a = jnp.asarray(rng.standard_normal((10, 70)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((9, 70)), jnp.float32)
    at = ops.quantize_rows(a, 8, pad_bit=0, impl="reference")
    bt = ops.quantize_rows(b, 3, pad_bit=1, impl="reference")
    y0 = np.asarray(ops.ap_matmul(at, bt, raw=True, impl=impl))
    # widen B by one word of all-one pad bits
    bw = dataclasses.replace(bt, packed=jnp.pad(
        bt.packed, ((0, 0), (0, 0), (0, 1)),
        constant_values=np.uint32(0xFFFFFFFF)))
    np.testing.assert_array_equal(
        np.asarray(ops.ap_matmul(at, bw, raw=True, impl=impl)), y0)
    # widen A by two words of all-zero pad bits
    aw = dataclasses.replace(at, packed=jnp.pad(
        at.packed, ((0, 0), (0, 0), (0, 2)), constant_values=np.uint32(0)))
    np.testing.assert_array_equal(
        np.asarray(ops.ap_matmul(aw, bt, raw=True, impl=impl)), y0)
    # both widened at once, to different widths
    np.testing.assert_array_equal(
        np.asarray(ops.ap_matmul(aw, bw, raw=True, impl=impl)), y0)
    # dequantizing path survives the width fix too
    yd0 = np.asarray(ops.ap_matmul(at, bt, impl=impl))
    yd1 = np.asarray(ops.ap_matmul(aw, bw, impl=impl))
    np.testing.assert_allclose(yd1, yd0, rtol=1e-6, atol=1e-6)
