"""Three-impl parity for the paged dequantizing flash kernel.

The paged op reads packed bipolar K/V through a per-request block table
(serving block pool).  Contract (same as every op in repro.kernels.ops):
``reference`` (jnp gather + contiguous reference path) and ``interpret``
(the scalar-prefetch Pallas kernel body in Python) agree to float
tolerance on the same packed buffers; the ``pallas`` path runs the
identical kernel body on TPU.  Additionally the paged reference must be
*exactly* the contiguous :func:`ops.kv_cache_attention` on the gathered
layout -- paging is memory management, not math.

Since ISSUE 3 the kernel also serves block-table *suffix prefill*:
``Sq > 1`` causal query tokens folded into the query axis (grid tiled
by ``q_block`` rows), each masked by its own absolute position.  The
same parity matrix covers that path.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import bipolar
from repro.kernels import ops

RNG = np.random.default_rng(11)

BITS = [2, 4, 8]


def _paged_inputs(bits, *, B=2, H=3, G=2, sq=1, d=16, bs=8, n_blocks=12,
                  NB=4, lens=(19, 7)):
    """Random per-request K/V quantized and scattered into pool blocks,
    plus the equivalent contiguous (gathered) layout as an oracle.

    ``sq`` > 1 emulates suffix prefill: the query axis carries ``G*sq``
    rows -- ``sq`` causal tokens per GQA group, positioned at the last
    ``sq`` positions of each request."""
    dw = bipolar.packed_words(d)
    k_pool = np.zeros((n_blocks, bs, H, bits, dw), np.uint32)
    v_pool = np.zeros_like(k_pool)
    k_sc = np.zeros((n_blocks, bs, H, 1), np.float32)
    v_sc = np.zeros_like(k_sc)
    pool_pos = np.full((n_blocks, bs), -1, np.int32)
    tables = np.zeros((B, NB), np.int32)    # pad entries -> null block 0
    free = list(range(1, n_blocks))

    T = NB * bs
    k_cat = np.zeros((B, T, H, bits, dw), np.uint32)
    v_cat = np.zeros_like(k_cat)
    ksc_cat = np.ones((B, T, H, 1), np.float32)
    vsc_cat = np.ones_like(ksc_cat)
    pos_cat = np.full((B, T), -1, np.int32)

    for b, ln in enumerate(lens):
        k = jnp.asarray(RNG.standard_normal((1, ln, H, d)), jnp.float32)
        v = jnp.asarray(RNG.standard_normal((1, ln, H, d)), jnp.float32)
        kq, ks = ops.quantize_kv(k, bits)
        vq, vs = ops.quantize_kv(v, bits)
        nb = -(-ln // bs)
        ids = [free.pop() for _ in range(nb)]
        tables[b, :nb] = ids
        for j, bid in enumerate(ids):
            lo, hi = j * bs, min((j + 1) * bs, ln)
            k_pool[bid, :hi - lo] = np.asarray(kq[0, lo:hi])
            v_pool[bid, :hi - lo] = np.asarray(vq[0, lo:hi])
            k_sc[bid, :hi - lo] = np.asarray(ks[0, lo:hi])
            v_sc[bid, :hi - lo] = np.asarray(vs[0, lo:hi])
            pool_pos[bid, :hi - lo] = np.arange(lo, hi)
        k_cat[b, :ln] = np.asarray(kq[0])
        v_cat[b, :ln] = np.asarray(vq[0])
        ksc_cat[b, :ln] = np.asarray(ks[0])
        vsc_cat[b, :ln] = np.asarray(vs[0])
        pos_cat[b, :ln] = np.arange(ln)

    q = jnp.asarray(RNG.standard_normal((B, H, G * sq, d)), jnp.float32)
    # row gi*sq + si is group gi's query for the si-th of the last sq
    # positions (the layers.attention_apply fold order)
    q_pos = jnp.asarray([[ln - sq + (r % sq) for r in range(G * sq)]
                         for ln in lens], jnp.int32)
    paged = (q, jnp.asarray(k_pool), jnp.asarray(k_sc), jnp.asarray(v_pool),
             jnp.asarray(v_sc), jnp.asarray(pool_pos), jnp.asarray(tables),
             q_pos)
    contiguous = (k_cat, ksc_cat, v_cat, vsc_cat, pos_cat)
    return paged, contiguous


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("window", [None, 8])
def test_paged_attention_reference_interpret_parity(bits, window):
    paged, _ = _paged_inputs(bits)
    d = paged[0].shape[-1]
    y_ref = np.asarray(ops.paged_kv_cache_attention(
        *paged, d=d, window=window, impl="reference"))
    y_int = np.asarray(ops.paged_kv_cache_attention(
        *paged, d=d, window=window, impl="interpret"))
    np.testing.assert_allclose(y_int, y_ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("bits", BITS)
def test_paged_matches_contiguous_on_gathered_layout(bits):
    """Paging must not change the math: the paged read equals the
    contiguous quantized-KV attention over the same packed planes laid
    out contiguously (exactly, under the shared reference dataflow)."""
    paged, (k_cat, ksc_cat, v_cat, vsc_cat, pos_cat) = _paged_inputs(bits)
    q = paged[0]
    B, H, G, d = q.shape
    T = k_cat.shape[1]
    y_p = np.asarray(ops.paged_kv_cache_attention(
        *paged, d=d, impl="reference"))

    fold = lambda a: a.transpose((0, 2, 1) + tuple(
        range(3, a.ndim))).reshape((B * H, T) + a.shape[3:])
    y_c = np.asarray(ops.kv_cache_attention(
        q.reshape(B * H, G, d),
        fold(jnp.asarray(k_cat)), fold(jnp.asarray(ksc_cat)),
        fold(jnp.asarray(v_cat)), fold(jnp.asarray(vsc_cat)),
        jnp.repeat(paged[-1], H, 0),
        jnp.repeat(jnp.asarray(pos_cat), H, 0),
        d=d, impl="reference")).reshape(B, H, G, d)
    np.testing.assert_array_equal(y_p, y_c)


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("window", [None, 8])
def test_paged_sq_gt1_reference_interpret_parity(bits, window):
    """Suffix-prefill shape: 6 causal query tokens per GQA group, with
    a q_block of 8 so the 12 padded query rows span two kernel tiles
    (exercising the scratch re-init at each new query tile)."""
    paged, _ = _paged_inputs(bits, sq=6, lens=(19, 9))
    d = paged[0].shape[-1]
    y_ref = np.asarray(ops.paged_kv_cache_attention(
        *paged, d=d, window=window, impl="reference"))
    y_int = np.asarray(ops.paged_kv_cache_attention(
        *paged, d=d, window=window, q_block=8, impl="interpret"))
    np.testing.assert_allclose(y_int, y_ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("bits", [4, 8])
def test_paged_sq_gt1_matches_contiguous_on_gathered_layout(bits):
    """Multi-token causal queries through the block table equal the
    contiguous quantized-KV attention over the gathered planes exactly
    (shared reference dataflow): the Sq>1 path changes how queries are
    batched, not what they compute."""
    paged, (k_cat, ksc_cat, v_cat, vsc_cat, pos_cat) = _paged_inputs(
        bits, sq=5, lens=(19, 11))
    q = paged[0]
    B, H, GS, d = q.shape
    T = k_cat.shape[1]
    y_p = np.asarray(ops.paged_kv_cache_attention(
        *paged, d=d, impl="reference"))

    fold = lambda a: a.transpose((0, 2, 1) + tuple(
        range(3, a.ndim))).reshape((B * H, T) + a.shape[3:])
    y_c = np.asarray(ops.kv_cache_attention(
        q.reshape(B * H, GS, d),
        fold(jnp.asarray(k_cat)), fold(jnp.asarray(ksc_cat)),
        fold(jnp.asarray(v_cat)), fold(jnp.asarray(vsc_cat)),
        jnp.repeat(paged[-1], H, 0),
        jnp.repeat(jnp.asarray(pos_cat), H, 0),
        d=d, impl="reference")).reshape(B, H, GS, d)
    np.testing.assert_array_equal(y_p, y_c)


def test_paged_sq_causality_within_suffix():
    """Each suffix query must see exactly the prefix plus the suffix
    tokens at positions <= its own: computing the same rows one
    query-position at a time (decode-style Sq=1 calls) must agree."""
    paged, _ = _paged_inputs(8, sq=4, G=2, lens=(17,), B=1)
    q, kp, ks, vp, vs, pos, tables, q_pos = paged
    d = q.shape[-1]
    y_all = np.asarray(ops.paged_kv_cache_attention(
        q, kp, ks, vp, vs, pos, tables, q_pos, d=d, impl="interpret"))
    for si in range(4):
        rows = [g * 4 + si for g in range(2)]
        y_one = np.asarray(ops.paged_kv_cache_attention(
            q[:, :, rows], kp, ks, vp, vs, pos, tables, q_pos[:, rows],
            d=d, impl="interpret"))
        np.testing.assert_allclose(y_one, y_all[:, :, rows],
                                   rtol=2e-6, atol=2e-6)


@pytest.mark.parametrize("window", [None, 8])
def test_paged_mixed_sq_lanes_match_single_lane_calls(window):
    """The fused continuous-batching step (ISSUE 6) batches lanes with
    *different* real query counts into one dispatch: chunk-prefill lanes
    carry Sq real rows, decode lanes 1 real row, the rest padded at
    q_pos=-1.  Every real row must equal the same query issued in a
    lane-shaped call of its own -- per-row position masking, not lane
    shape, decides what a query sees."""
    paged, _ = _paged_inputs(8, sq=4, lens=(19, 7))
    q, kp, ks, vp, vs, pos, tables, q_pos = paged
    d = q.shape[-1]
    qp = np.asarray(q_pos).copy()
    qp[1] = -1
    for g in range(2):
        qp[1, g * 4] = 6       # lane 1: one decode row per group (ln-1)
    qp = jnp.asarray(qp)
    rows = np.array([0, 4])    # lane 1's real rows
    for impl in ("reference", "interpret"):
        y = np.asarray(ops.paged_kv_cache_attention(
            q, kp, ks, vp, vs, pos, tables, qp, d=d, window=window,
            impl=impl))
        y0 = np.asarray(ops.paged_kv_cache_attention(
            q[0:1], kp, ks, vp, vs, pos, tables[0:1], qp[0:1], d=d,
            window=window, impl=impl))
        np.testing.assert_allclose(y[0], y0[0], rtol=2e-6, atol=2e-6)
        y1 = np.asarray(ops.paged_kv_cache_attention(
            q[1:2, :, rows], kp, ks, vp, vs, pos, tables[1:2],
            qp[1:2, rows], d=d, window=window, impl=impl))
        np.testing.assert_allclose(y[1][:, rows], y1[0],
                                   rtol=2e-6, atol=2e-6)


# ---------------------------------------------------------------------------
# Sliding-window boundaries (ISSUE 5): the kernel's window mask + the
# grid's dead-block skip across all impls
# ---------------------------------------------------------------------------

# bs=8 throughout: 8 = window == block_size, 6/13 = window % block_size
# != 0 (smaller and larger than a block), 4 = window < block_size
WINDOWS = [4, 6, 8, 13]


@pytest.mark.parametrize("window", WINDOWS)
@pytest.mark.parametrize("sq", [1, 5])
def test_paged_windowed_parity_all_impls(window, sq):
    """Windowed paged attention (Sq=1 decode and Sq>1 suffix prefill)
    agrees across reference | interpret (the pallas path runs the same
    kernel body on TPU) at every window/block alignment."""
    paged, _ = _paged_inputs(8, sq=sq, lens=(19, 9) if sq > 1 else (19, 7))
    d = paged[0].shape[-1]
    y_ref = np.asarray(ops.paged_kv_cache_attention(
        *paged, d=d, window=window, impl="reference"))
    y_int = np.asarray(ops.paged_kv_cache_attention(
        *paged, d=d, window=window, q_block=8 if sq > 1 else None,
        impl="interpret"))
    np.testing.assert_allclose(y_int, y_ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("impl", ["reference", "interpret"])
@pytest.mark.parametrize("window", [4, 8])
def test_paged_window_edge_is_exclusive(impl, window):
    """q at position p attends exactly kv positions in (p - w, p]: the
    windowed result over the full pool equals the UNwindowed result
    over a pool whose out-of-window slots are invalidated by hand --
    if the kernel's edge were off by one either way, the two would
    differ at the boundary token."""
    paged, _ = _paged_inputs(8, lens=(19,), B=1)
    q, kp, ks, vp, vs, pos, tables, q_pos = paged
    d = q.shape[-1]
    y_w = np.asarray(ops.paged_kv_cache_attention(
        q, kp, ks, vp, vs, pos, tables, q_pos, d=d, window=window,
        impl=impl))
    edge = int(q_pos[0, 0]) - window          # last EXCLUDED position
    pos_masked = jnp.where(pos <= edge, -1, pos)
    y_m = np.asarray(ops.paged_kv_cache_attention(
        q, kp, ks, vp, vs, pos_masked, tables, q_pos, d=d, window=None,
        impl=impl))
    np.testing.assert_allclose(y_w, y_m, rtol=2e-6, atol=2e-6)
    # the boundary matters: including position `edge` changes the result
    pos_off = jnp.where(pos <= edge - 1, -1, pos)
    y_off = np.asarray(ops.paged_kv_cache_attention(
        q, kp, ks, vp, vs, pos_off, tables, q_pos, d=d, window=None,
        impl=impl))
    assert np.abs(y_off - y_w).max() > 1e-6, \
        "edge token contributed nothing -- boundary test is vacuous"


@pytest.mark.parametrize("sq", [1, 4])
def test_paged_q_pos_exactly_at_window_edges(sq):
    """Query positions sitting exactly at window-multiple boundaries
    (q_pos = w, and block-crossing suffixes): reference/interpret agree
    and rows whose window precisely covers one block see it."""
    w = 8
    paged, _ = _paged_inputs(8, sq=sq, lens=(w + sq,), B=1)
    q, kp, ks, vp, vs, pos, tables, q_pos = paged
    d = q.shape[-1]
    assert int(np.asarray(q_pos).min()) == w, np.asarray(q_pos)
    y_ref = np.asarray(ops.paged_kv_cache_attention(
        q, kp, ks, vp, vs, pos, tables, q_pos, d=d, window=w,
        impl="reference"))
    y_int = np.asarray(ops.paged_kv_cache_attention(
        q, kp, ks, vp, vs, pos, tables, q_pos, d=d, window=w,
        q_block=8 if sq > 1 else None, impl="interpret"))
    np.testing.assert_allclose(y_int, y_ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("impl", ["reference", "interpret"])
def test_paged_rolling_table_drops_dead_blocks_identically(impl):
    """The reclaim contract at the kernel level: once a block is fully
    out of every query's window, removing it from the table (the
    scheduler's rolling-window compaction -- pad entries point at the
    null block) must not change the output.  This is what makes
    out-of-window reclaim a pure memory-management change."""
    w, bs = 6, 8
    paged, _ = _paged_inputs(8, lens=(19,), B=1)
    q, kp, ks, vp, vs, pos, tables, q_pos = paged
    d = q.shape[-1]
    L = int(q_pos[0, 0]) + 1                     # 19 resident tokens
    # block j is dead for the (single) query at L-1 iff its last token
    # (j+1)*bs - 1 <= (L-1) - w; here block 0 (pos 0..7): 7 <= 12
    n_dead = max(0, (L - w) // bs)
    assert n_dead >= 1
    y_full = np.asarray(ops.paged_kv_cache_attention(
        q, kp, ks, vp, vs, pos, tables, q_pos, d=d, window=w, impl=impl))
    rolled = np.asarray(tables).copy()
    live = rolled[0, n_dead:].copy()
    rolled[0, :len(live)] = live                 # compact left
    rolled[0, len(live):] = 0                    # pad -> null block
    y_roll = np.asarray(ops.paged_kv_cache_attention(
        q, kp, ks, vp, vs, pos, jnp.asarray(rolled), q_pos, d=d,
        window=w, impl=impl))
    np.testing.assert_allclose(y_roll, y_full, rtol=2e-6, atol=2e-6)


def test_paged_null_block_and_inactive_lanes_return_zero():
    """Padded table entries point at the null block (pos -1) and padded
    batch lanes carry q_pos -1: both must contribute exactly 0 under
    reference AND interpret."""
    paged, _ = _paged_inputs(8)
    q, kp, ks, vp, vs, pos, tables, q_pos = paged
    d = q.shape[-1]
    # lane 1 fully inactive: null table + masked q rows
    tables = tables.at[1].set(0)
    q_pos = q_pos.at[1].set(-1)
    for impl in ("reference", "interpret"):
        y = np.asarray(ops.paged_kv_cache_attention(
            q, kp, ks, vp, vs, pos, tables, q_pos, d=d, impl=impl))
        np.testing.assert_array_equal(y[1], np.zeros_like(y[1]),
                                      err_msg=impl)
        assert np.abs(y[0]).max() > 0      # active lane still attends


def test_paged_block_order_is_table_order():
    """Swapping physical block ids (with the table updated to match)
    must not change the result: position comes from pool_pos, not from
    where a block happens to live in the pool."""
    paged, _ = _paged_inputs(8, lens=(19,), B=1)
    q, kp, ks, vp, vs, pos, tables, q_pos = paged
    d = q.shape[-1]
    y0 = np.asarray(ops.paged_kv_cache_attention(
        q, kp, ks, vp, vs, pos, tables, q_pos, d=d, impl="reference"))

    # swap physical blocks a<->b everywhere and patch the table
    a, b = int(tables[0, 0]), int(tables[0, 2])
    perm = np.arange(kp.shape[0])
    perm[[a, b]] = [b, a]
    swap = lambda arr: jnp.asarray(np.asarray(arr)[perm])
    tbl = np.asarray(tables).copy()
    mask_a, mask_b = tbl == a, tbl == b
    tbl[mask_a], tbl[mask_b] = b, a
    y1 = np.asarray(ops.paged_kv_cache_attention(
        q, swap(kp), swap(ks), swap(vp), swap(vs), swap(pos),
        jnp.asarray(tbl), q_pos, d=d, impl="reference"))
    np.testing.assert_array_equal(y0, y1)
