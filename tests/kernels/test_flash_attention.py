"""Flash-attention Pallas kernel vs the jnp online-softmax oracle
(`layers._attn_core`), swept over shapes, masks, and windows."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models import layers as L

RNG = np.random.default_rng(0)


def _mk(bh, sq, t, d, dtype=jnp.float32):
    q = jnp.asarray(RNG.standard_normal((bh, sq, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((bh, t, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((bh, t, d)), dtype)
    return q, k, v


def _ref(q, k, v, q_pos, kv_pos, causal, window):
    # the direct-path jnp core (b=BH, hk=1 view)
    o = L._attn_core(q[:, None], k[:, None], v[:, None],
                     q_pos, kv_pos, causal=causal, window=window,
                     chunked=False)
    return np.asarray(o[:, 0], dtype=np.float32)


@pytest.mark.parametrize("shape", [(2, 8, 8, 16), (3, 64, 128, 32),
                                   (1, 128, 512, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_reference(shape, causal):
    bh, sq, t, d = shape
    q, k, v = _mk(bh, sq, t, d)
    q_pos = jnp.broadcast_to(jnp.arange(t - sq, t, dtype=jnp.int32),
                             (bh, sq))
    kv_pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (bh, t))
    got = np.asarray(flash_attention(
        q, k, v, q_pos, kv_pos, causal=causal,
        block=(32, 64), interpret=True), dtype=np.float32)
    want = _ref(q, k, v, q_pos, kv_pos, causal, None)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_sliding_window():
    bh, sq, t, d, w = 2, 32, 32, 16, 8
    q, k, v = _mk(bh, sq, t, d)
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (bh, t))
    got = np.asarray(flash_attention(
        q, k, v, pos, pos, causal=True, window=w,
        block=(16, 16), interpret=True), dtype=np.float32)
    want = _ref(q, k, v, pos, pos, True, w)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_invalid_slots_masked():
    """Negative kv positions (empty cache slots) must not contribute."""
    bh, sq, t, d = 1, 16, 64, 16
    q, k, v = _mk(bh, sq, t, d)
    kv_pos = jnp.where(jnp.arange(t) < 40, jnp.arange(t), -1)[None, :]
    kv_pos = jnp.broadcast_to(kv_pos, (bh, t)).astype(jnp.int32)
    q_pos = jnp.broadcast_to(jnp.arange(24, 40, dtype=jnp.int32), (bh, sq))
    got = np.asarray(flash_attention(
        q, k, v, q_pos, kv_pos, causal=True, block=(16, 16),
        interpret=True), dtype=np.float32)
    want = _ref(q, k, v, q_pos, kv_pos, True, None)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_bf16_inputs():
    bh, sq, t, d = 2, 64, 64, 32
    q, k, v = _mk(bh, sq, t, d, jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (bh, t))
    got = np.asarray(flash_attention(q, k, v, pos, pos, causal=True,
                                     block=(32, 32), interpret=True),
                     dtype=np.float32)
    want = _ref(q, k, v, pos, pos, True, None)
    np.testing.assert_allclose(got, want, rtol=3e-2, atol=3e-2)
