"""Cross-impl kernel parity: ``reference`` vs ``interpret`` must agree on
the same packed buffers for every op the model graph dispatches through
:mod:`repro.kernels.ops` -- the APMM GEMMs (bit-exactly) and the
bipolar-quantized KV-cache attention (float tolerance).

This is the contract that makes ``REPRO_KERNEL_IMPL`` a free choice: CPU
correctness runs (`reference`), kernel-body debugging (`interpret`) and
TPU serving (`pallas`) all compute the same function.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.flash_attention import attention_reference

RNG = np.random.default_rng(7)

BITS = [2, 4, 7, 8]
KS = [64, 67]          # word-aligned and odd K (pad-correction path)


def _pair(m, n, k, bits):
    a = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((n, k)), jnp.float32)
    at = ops.quantize_rows(a, 8, pad_bit=0, impl="reference")
    bt = ops.quantize_rows(b, bits, pad_bit=1, impl="reference")
    return at, bt


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("k", KS)
@pytest.mark.parametrize("variant", ["fused", "bitserial"])
def test_ap_matmul_reference_interpret_parity(bits, k, variant):
    at, bt = _pair(24, 40, k, bits)
    y_ref = np.asarray(ops.ap_matmul(at, bt, raw=True, impl="reference",
                                     variant=variant))
    y_int = np.asarray(ops.ap_matmul(at, bt, raw=True, impl="interpret",
                                     variant=variant))
    np.testing.assert_array_equal(y_int, y_ref)


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("k", KS)
def test_ap_linear_reference_interpret_parity(bits, k):
    x = jnp.asarray(RNG.standard_normal((3, 5, k)), jnp.float32)
    wt = ops.pack_weight(jnp.asarray(RNG.standard_normal((17, k)),
                                     jnp.float32), bits, impl="reference")
    y_ref = np.asarray(ops.ap_linear(x, wt, a_bits=8, impl="reference"))
    y_int = np.asarray(ops.ap_linear(x, wt, a_bits=8, impl="interpret"))
    # same int core; dequant runs in a different order -> float tolerance
    np.testing.assert_allclose(y_int, y_ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bits", BITS)          # incl. n_bits == 8
@pytest.mark.parametrize("k", KS)               # word-aligned and odd K
@pytest.mark.parametrize("variant", ["fused", "bitserial"])
def test_ap_linear_fused_reference_interpret_parity(bits, k, variant):
    """One-kernel fused linear: reference (quantize-to-values jnp
    dataflow) vs interpret (the Pallas kernel body with the in-VMEM
    quantize prologue + epilogue).  M=15, N=17 and odd K exercise the
    non-multiple-of-tile pad/slice path; bitserial at 8 bits covers the
    regime where single-group operand recovery would overflow int8."""
    x = jnp.asarray(RNG.standard_normal((3, 5, k)), jnp.float32)
    wt = ops.pack_weight(jnp.asarray(RNG.standard_normal((17, k)),
                                     jnp.float32), bits, impl="reference")
    w2 = ops.pack_weight(jnp.asarray(RNG.standard_normal((17, k)),
                                     jnp.float32), bits, impl="reference")
    res = jnp.asarray(RNG.standard_normal((3, 5, 17)), jnp.float32)
    for kw in ({}, dict(w2=w2, act="silu", residual=res)):
        y_ref = np.asarray(ops.ap_linear_fused(
            x, wt, a_bits=8, variant=variant, impl="reference",
            out_dtype=jnp.float32, **kw))
        y_int = np.asarray(ops.ap_linear_fused(
            x, wt, a_bits=8, variant=variant, impl="interpret",
            out_dtype=jnp.float32, **kw))
        np.testing.assert_allclose(y_int, y_ref, rtol=1e-5, atol=1e-5)


# --- bipolar-quantized KV-cache attention ---------------------------------

def _attn_inputs(bh=4, sq=6, t=37, d=16):
    q = jnp.asarray(RNG.standard_normal((bh, sq, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((bh, t, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((bh, t, d)), jnp.float32)
    q_pos = jnp.broadcast_to(jnp.arange(t - sq, t, dtype=jnp.int32), (bh, sq))
    # a few invalid (empty-ring) slots, like a part-filled cache
    kv_pos = jnp.where(jnp.arange(t) < t - 3, jnp.arange(t), -1)
    kv_pos = jnp.broadcast_to(kv_pos, (bh, t)).astype(jnp.int32)
    return q, k, v, q_pos, kv_pos


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("window", [None, 8])
def test_kv_attention_reference_interpret_parity(bits, window):
    q, k, v, q_pos, kv_pos = _attn_inputs()
    kp, ks = ops.quantize_kv(k, bits)
    vp, vs = ops.quantize_kv(v, bits)
    args = (q, kp, ks, vp, vs, q_pos, kv_pos)
    y_ref = np.asarray(ops.kv_cache_attention(
        *args, d=q.shape[-1], window=window, impl="reference"))
    y_int = np.asarray(ops.kv_cache_attention(
        *args, d=q.shape[-1], window=window, impl="interpret"))
    np.testing.assert_allclose(y_int, y_ref, rtol=2e-5, atol=2e-5)


def _kv_error(bits, impl="reference"):
    q, k, v, q_pos, kv_pos = _attn_inputs()
    y_f = np.asarray(attention_reference(q, k, v, q_pos, kv_pos))
    kp, ks = ops.quantize_kv(k, bits)
    vp, vs = ops.quantize_kv(v, bits)
    y_q = np.asarray(ops.kv_cache_attention(
        q, kp, ks, vp, vs, q_pos, kv_pos, d=q.shape[-1], impl=impl))
    return float(np.abs(y_q - y_f).max()), y_q, y_f


def test_kv8_attention_close_to_float():
    """8-bit bipolar KV must track float attention tightly (the serving
    default): absmax odd-grid step is ~0.8% of the per-head range."""
    err, y_q, y_f = _kv_error(8)
    np.testing.assert_allclose(y_q, y_f, rtol=2e-2, atol=2e-2)


def test_fully_masked_rows_return_zero_everywhere():
    """A row whose every slot is invalid (empty cache lane) must yield 0
    under reference AND interpret -- not mean(V) or padded-slot garbage."""
    q, k, v, q_pos, _ = _attn_inputs()
    kv_pos = jnp.full(k.shape[:2], -1, jnp.int32)       # nothing valid
    kp, ks = ops.quantize_kv(k, 8)
    vp, vs = ops.quantize_kv(v, 8)
    for impl in ("reference", "interpret"):
        y = np.asarray(ops.kv_cache_attention(
            q, kp, ks, vp, vs, q_pos, kv_pos, d=q.shape[-1], impl=impl))
        np.testing.assert_array_equal(y, np.zeros_like(y), err_msg=impl)


def test_kv_bits_degrade_monotonically():
    """Coarser KV caches may only get worse: err(2) >= err(4) >= err(8)."""
    e2, _, _ = _kv_error(2)
    e4, _, _ = _kv_error(4)
    e8, _, _ = _kv_error(8)
    assert e8 <= e4 <= e2, (e8, e4, e2)
    assert e8 < 0.02, e8


# --- nested-precision slice parity (any-precision checkpoints) -------------
#
# One checkpoint packed at NEST_M bits with per-width scales
# (ops.pack_weight -> bipolar.nested_width_scales) must serve every
# width k <= NEST_M by plane-prefix slicing: the k-plane slice is
# BIT-identical on the integer core -- truncating to the top-k planes IS
# round-to-nearest on the coarse grid (the odd-remainder argument in
# bipolar.truncate_values) -- and tolerance-identical through the
# dequant epilogues, whose only difference is float summation order.
# The oracle is a DIRECT quantization at k bits on the same grid: the
# natural coarse scale (base * 2^(m-k)) fixes the integers, the
# clip-searched per-width scale replaces the dequant scale.

NEST_M = 8
NESTED_KS = list(range(1, NEST_M + 1))
# the pallas path runs the same kernel body interpret executes; off-TPU
# it cannot lower, so the three-impl matrix skips it there
NESTED_IMPLS = [
    "reference", "interpret",
    pytest.param("pallas", marks=pytest.mark.skipif(
        jax.default_backend() != "tpu", reason="pallas needs a TPU")),
]


def _nested_weight(n, k):
    w = jnp.asarray(RNG.standard_normal((n, k)), jnp.float32)
    return w, ops.pack_weight(w, NEST_M, impl="reference")


def _direct_at(wt, w, kbits):
    """Quantize ``w`` directly at ``kbits`` on the max-bit grid."""
    natural = wt.scale * float(1 << (NEST_M - kbits))
    direct = ops.quantize_rows(w, kbits, pad_bit=1, scale=natural,
                               impl="reference")
    return dataclasses.replace(direct,
                               scale=wt.width_scales[kbits - 1],
                               width_scales=wt.width_scales[:kbits])


@pytest.mark.parametrize("kbits", NESTED_KS)
@pytest.mark.parametrize("kdim", KS)            # word-aligned and odd K
@pytest.mark.parametrize("impl", NESTED_IMPLS)
def test_nested_slice_integer_core_bit_identical(kbits, kdim, impl):
    """``ap_matmul(a, w, b_bits=k, raw=True)`` -- the kernel reads only
    the leading k planes -- equals the raw GEMM against a direct k-bit
    quantization, bit for bit, at odd M/N and both K alignments."""
    a = jnp.asarray(RNG.standard_normal((15, kdim)), jnp.float32)
    at = ops.quantize_rows(a, 8, pad_bit=0, impl="reference")
    w, wt = _nested_weight(19, kdim)
    direct = _direct_at(wt, w, kbits)
    y_slice = np.asarray(ops.ap_matmul(at, wt, b_bits=kbits, raw=True,
                                       impl=impl))
    y_direct = np.asarray(ops.ap_matmul(at, direct, raw=True, impl=impl))
    np.testing.assert_array_equal(y_slice, y_direct)


@pytest.mark.parametrize("kbits", NESTED_KS)
@pytest.mark.parametrize("impl", NESTED_IMPLS)
def test_nested_slice_linear_fused_matches_direct(kbits, impl):
    """``ap_linear_fused(..., w_bits=k)`` on the max-bit checkpoint ==
    the same op on a direct k-bit quantization with the same per-width
    scale, in single-GEMM and dual-GEMM (gate/up silu) modes, at odd
    M/N/K.  Same integer core (bit-identical above), so any difference
    is float epilogue order -> tight tolerance."""
    kdim = 67
    x = jnp.asarray(RNG.standard_normal((3, 5, kdim)), jnp.float32)
    w, wt = _nested_weight(19, kdim)
    w2, wt2 = _nested_weight(19, kdim)
    res = jnp.asarray(RNG.standard_normal((3, 5, 19)), jnp.float32)
    for kw_direct, kw_slice in (
            ({}, {}),
            (dict(w2=_direct_at(wt2, w2, kbits), act="silu", residual=res),
             dict(w2=wt2, act="silu", residual=res))):
        y_direct = np.asarray(ops.ap_linear_fused(
            x, _direct_at(wt, w, kbits), a_bits=8, impl=impl,
            out_dtype=jnp.float32, **kw_direct))
        y_slice = np.asarray(ops.ap_linear_fused(
            x, wt, a_bits=8, w_bits=kbits, impl=impl,
            out_dtype=jnp.float32, **kw_slice))
        np.testing.assert_allclose(y_slice, y_direct, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kbits", NESTED_KS)
def test_nested_slice_unfused_linear_matches_direct(kbits):
    """``ap_linear(..., w_bits=k)`` (the unfused path) agrees with the
    direct k-bit quantization too -- nested slicing lives in ops, so
    every GEMM entry point serves it."""
    kdim = 67
    x = jnp.asarray(RNG.standard_normal((3, 5, kdim)), jnp.float32)
    w, wt = _nested_weight(19, kdim)
    y_direct = np.asarray(ops.ap_linear(
        x, _direct_at(wt, w, kbits), a_bits=8, impl="reference",
        out_dtype=jnp.float32))
    y_slice = np.asarray(ops.ap_linear(
        x, wt, a_bits=8, w_bits=kbits, impl="reference",
        out_dtype=jnp.float32))
    np.testing.assert_allclose(y_slice, y_direct, rtol=1e-5, atol=1e-5)


# grouped MoE: odd E/C/K/N and mixed partial fills, single + dual GEMM
_ME, _MG, _MSEG, _MK, _MN = 2, 2, 3, 37, 19
_MC = _MG * _MSEG


def _moe_nested_weight(seed):
    w = jnp.asarray(
        np.random.default_rng(seed).standard_normal((_ME, _MN, _MK))
        / np.sqrt(_MK), jnp.float32)
    flat = ops.quantize_rows(w.reshape(-1, _MK), NEST_M, pad_bit=1,
                             impl="reference", scale_search=True)
    kw = flat.packed.shape[-1]
    return w, dataclasses.replace(
        flat,
        packed=flat.packed.reshape(NEST_M, _ME, _MN, kw),
        scale=flat.scale.reshape(_ME, _MN, 1),
        width_scales=flat.width_scales.reshape(NEST_M, _ME, _MN, 1),
        shape=(_ME, _MN, _MK), pack_axis=2)


def _moe_direct_at(wt, w, kbits):
    natural = wt.scale.reshape(-1, 1) * float(1 << (NEST_M - kbits))
    direct = ops.quantize_rows(w.reshape(-1, _MK), kbits, pad_bit=1,
                               scale=natural, impl="reference")
    kw = direct.packed.shape[-1]
    return dataclasses.replace(
        direct,
        packed=direct.packed.reshape(kbits, _ME, _MN, kw),
        scale=wt.width_scales[kbits - 1],
        width_scales=wt.width_scales[:kbits],
        shape=(_ME, _MN, _MK), pack_axis=2)


@pytest.mark.parametrize("kbits", [1, 3, 4, 8])
@pytest.mark.parametrize("impl", NESTED_IMPLS)
def test_nested_slice_moe_expert_matches_direct(kbits, impl):
    """``ap_moe_expert_linear(..., w_bits=k)`` on a max-bit grouped
    expert stack == the same op on direct k-bit expert weights, single
    and dual (gate/up) GEMM, with mixed partial segment fills."""
    x = jnp.asarray(RNG.standard_normal((_ME, _MC, _MK)), jnp.float32)
    counts = jnp.asarray([[3, 1], [0, 2]], jnp.int32)
    w, wt = _moe_nested_weight(seed=11)
    w2, wt2 = _moe_nested_weight(seed=13)
    for kw_direct, kw_slice in (
            ({}, {}),
            (dict(w2=_moe_direct_at(wt2, w2, kbits), act="silu"),
             dict(w2=wt2, act="silu"))):
        y_direct = np.asarray(ops.ap_moe_expert_linear(
            x, _moe_direct_at(wt, w, kbits), counts=counts, a_bits=8,
            impl=impl, out_dtype=jnp.float32, **kw_direct))
        y_slice = np.asarray(ops.ap_moe_expert_linear(
            x, wt, counts=counts, a_bits=8, w_bits=kbits, impl=impl,
            out_dtype=jnp.float32, **kw_slice))
        np.testing.assert_allclose(y_slice, y_direct, rtol=1e-5, atol=1e-5)


def test_width_scales_contract():
    """Structural contract of the per-width scales: top row == the base
    scale exactly, and serving MORE planes never increases dequant
    error (the any-precision quality ladder)."""
    w, wt = _nested_weight(19, 67)
    np.testing.assert_array_equal(np.asarray(wt.width_scales[NEST_M - 1]),
                                  np.asarray(wt.scale))
    from repro.core import bipolar
    errs = []
    for kbits in (2, 4, 8):
        deq = np.asarray(bipolar.dequantize(bipolar.nested_slice(wt, kbits)))
        errs.append(float(np.square(deq - np.asarray(w)).mean()))
    assert errs[0] >= errs[1] >= errs[2], errs
