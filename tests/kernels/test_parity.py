"""Cross-impl kernel parity: ``reference`` vs ``interpret`` must agree on
the same packed buffers for every op the model graph dispatches through
:mod:`repro.kernels.ops` -- the APMM GEMMs (bit-exactly) and the
bipolar-quantized KV-cache attention (float tolerance).

This is the contract that makes ``REPRO_KERNEL_IMPL`` a free choice: CPU
correctness runs (`reference`), kernel-body debugging (`interpret`) and
TPU serving (`pallas`) all compute the same function.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.flash_attention import attention_reference

RNG = np.random.default_rng(7)

BITS = [2, 4, 7, 8]
KS = [64, 67]          # word-aligned and odd K (pad-correction path)


def _pair(m, n, k, bits):
    a = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((n, k)), jnp.float32)
    at = ops.quantize_rows(a, 8, pad_bit=0, impl="reference")
    bt = ops.quantize_rows(b, bits, pad_bit=1, impl="reference")
    return at, bt


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("k", KS)
@pytest.mark.parametrize("variant", ["fused", "bitserial"])
def test_ap_matmul_reference_interpret_parity(bits, k, variant):
    at, bt = _pair(24, 40, k, bits)
    y_ref = np.asarray(ops.ap_matmul(at, bt, raw=True, impl="reference",
                                     variant=variant))
    y_int = np.asarray(ops.ap_matmul(at, bt, raw=True, impl="interpret",
                                     variant=variant))
    np.testing.assert_array_equal(y_int, y_ref)


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("k", KS)
def test_ap_linear_reference_interpret_parity(bits, k):
    x = jnp.asarray(RNG.standard_normal((3, 5, k)), jnp.float32)
    wt = ops.pack_weight(jnp.asarray(RNG.standard_normal((17, k)),
                                     jnp.float32), bits, impl="reference")
    y_ref = np.asarray(ops.ap_linear(x, wt, a_bits=8, impl="reference"))
    y_int = np.asarray(ops.ap_linear(x, wt, a_bits=8, impl="interpret"))
    # same int core; dequant runs in a different order -> float tolerance
    np.testing.assert_allclose(y_int, y_ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bits", BITS)          # incl. n_bits == 8
@pytest.mark.parametrize("k", KS)               # word-aligned and odd K
@pytest.mark.parametrize("variant", ["fused", "bitserial"])
def test_ap_linear_fused_reference_interpret_parity(bits, k, variant):
    """One-kernel fused linear: reference (quantize-to-values jnp
    dataflow) vs interpret (the Pallas kernel body with the in-VMEM
    quantize prologue + epilogue).  M=15, N=17 and odd K exercise the
    non-multiple-of-tile pad/slice path; bitserial at 8 bits covers the
    regime where single-group operand recovery would overflow int8."""
    x = jnp.asarray(RNG.standard_normal((3, 5, k)), jnp.float32)
    wt = ops.pack_weight(jnp.asarray(RNG.standard_normal((17, k)),
                                     jnp.float32), bits, impl="reference")
    w2 = ops.pack_weight(jnp.asarray(RNG.standard_normal((17, k)),
                                     jnp.float32), bits, impl="reference")
    res = jnp.asarray(RNG.standard_normal((3, 5, 17)), jnp.float32)
    for kw in ({}, dict(w2=w2, act="silu", residual=res)):
        y_ref = np.asarray(ops.ap_linear_fused(
            x, wt, a_bits=8, variant=variant, impl="reference",
            out_dtype=jnp.float32, **kw))
        y_int = np.asarray(ops.ap_linear_fused(
            x, wt, a_bits=8, variant=variant, impl="interpret",
            out_dtype=jnp.float32, **kw))
        np.testing.assert_allclose(y_int, y_ref, rtol=1e-5, atol=1e-5)


# --- bipolar-quantized KV-cache attention ---------------------------------

def _attn_inputs(bh=4, sq=6, t=37, d=16):
    q = jnp.asarray(RNG.standard_normal((bh, sq, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((bh, t, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((bh, t, d)), jnp.float32)
    q_pos = jnp.broadcast_to(jnp.arange(t - sq, t, dtype=jnp.int32), (bh, sq))
    # a few invalid (empty-ring) slots, like a part-filled cache
    kv_pos = jnp.where(jnp.arange(t) < t - 3, jnp.arange(t), -1)
    kv_pos = jnp.broadcast_to(kv_pos, (bh, t)).astype(jnp.int32)
    return q, k, v, q_pos, kv_pos


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("window", [None, 8])
def test_kv_attention_reference_interpret_parity(bits, window):
    q, k, v, q_pos, kv_pos = _attn_inputs()
    kp, ks = ops.quantize_kv(k, bits)
    vp, vs = ops.quantize_kv(v, bits)
    args = (q, kp, ks, vp, vs, q_pos, kv_pos)
    y_ref = np.asarray(ops.kv_cache_attention(
        *args, d=q.shape[-1], window=window, impl="reference"))
    y_int = np.asarray(ops.kv_cache_attention(
        *args, d=q.shape[-1], window=window, impl="interpret"))
    np.testing.assert_allclose(y_int, y_ref, rtol=2e-5, atol=2e-5)


def _kv_error(bits, impl="reference"):
    q, k, v, q_pos, kv_pos = _attn_inputs()
    y_f = np.asarray(attention_reference(q, k, v, q_pos, kv_pos))
    kp, ks = ops.quantize_kv(k, bits)
    vp, vs = ops.quantize_kv(v, bits)
    y_q = np.asarray(ops.kv_cache_attention(
        q, kp, ks, vp, vs, q_pos, kv_pos, d=q.shape[-1], impl=impl))
    return float(np.abs(y_q - y_f).max()), y_q, y_f


def test_kv8_attention_close_to_float():
    """8-bit bipolar KV must track float attention tightly (the serving
    default): absmax odd-grid step is ~0.8% of the per-head range."""
    err, y_q, y_f = _kv_error(8)
    np.testing.assert_allclose(y_q, y_f, rtol=2e-2, atol=2e-2)


def test_fully_masked_rows_return_zero_everywhere():
    """A row whose every slot is invalid (empty cache lane) must yield 0
    under reference AND interpret -- not mean(V) or padded-slot garbage."""
    q, k, v, q_pos, _ = _attn_inputs()
    kv_pos = jnp.full(k.shape[:2], -1, jnp.int32)       # nothing valid
    kp, ks = ops.quantize_kv(k, 8)
    vp, vs = ops.quantize_kv(v, 8)
    for impl in ("reference", "interpret"):
        y = np.asarray(ops.kv_cache_attention(
            q, kp, ks, vp, vs, q_pos, kv_pos, d=q.shape[-1], impl=impl))
        np.testing.assert_array_equal(y, np.zeros_like(y), err_msg=impl)


def test_kv_bits_degrade_monotonically():
    """Coarser KV caches may only get worse: err(2) >= err(4) >= err(8)."""
    e2, _, _ = _kv_error(2)
    e4, _, _ = _kv_error(4)
    e8, _, _ = _kv_error(8)
    assert e8 <= e4 <= e2, (e8, e4, e2)
    assert e8 < 0.02, e8
