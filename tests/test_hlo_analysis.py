"""The loop-aware HLO analyzer must fix cost_analysis's while-body
undercounting (it visits scan bodies once)."""

import sys
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks import hlo_analysis as H  # noqa: E402


def _compiled(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_scan_flops_are_trip_weighted():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def f_scan(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    def f_unroll(x, ws):
        for i in range(8):
            x, _ = body(x, ws[i])
        return x

    x = jax.ShapeDtypeStruct((64, 64), jnp.bfloat16)
    ws = jax.ShapeDtypeStruct((8, 64, 64), jnp.bfloat16)
    a_scan = H.analyze(_compiled(f_scan, x, ws).as_text())
    a_unroll = H.analyze(_compiled(f_unroll, x, ws).as_text())
    expect = 8 * 2 * 64 * 64 * 64
    assert a_scan["dot_flops"] == expect, a_scan
    assert a_unroll["dot_flops"] == expect
    assert a_scan["while_trips"] and 8 in a_scan["while_trips"].values()
    # cost_analysis undercounts the scan by ~8x (the bug we're fixing);
    # H.xla_cost_analysis papers over the list-vs-dict return drift
    ca = H.xla_cost_analysis(_compiled(f_scan, x, ws))["flops"]
    assert ca < expect / 4


def test_nested_scan_trip_product():
    def inner(c, _):
        return jnp.tanh(c @ c), None

    def outer(c, _):
        c, _ = jax.lax.scan(inner, c, None, length=3)
        return c, None

    def f(x):
        return jax.lax.scan(outer, x, None, length=5)[0]

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    a = H.analyze(_compiled(f, x).as_text())
    assert a["dot_flops"] == 5 * 3 * 2 * 32 ** 3, a


def test_collective_bytes_counted():
    import subprocess
    script = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, jax, jax.numpy as jnp
sys.path.insert(0, %r)
from benchmarks import hlo_analysis as H
from jax.sharding import NamedSharding, PartitionSpec as P
mesh = jax.make_mesh((8,), ("data",))
xs = NamedSharding(mesh, P("data", None))
def f(x):
    return jnp.sum(x * 2.0)
c = jax.jit(f, in_shardings=(xs,),
            out_shardings=NamedSharding(mesh, P())).lower(
    jax.ShapeDtypeStruct((64, 128), jnp.float32)).compile()
a = H.analyze(c.as_text())
assert a.get("collective_bytes", 0) > 0, a
assert "all-reduce" in a["collectives"], a
print("OK")
""" % os.path.join(os.path.dirname(__file__), "..")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=300,
                       env={**os.environ,
                            "PYTHONPATH": os.environ.get("PYTHONPATH", "")})
    assert r.returncode == 0, r.stderr
    assert "OK" in r.stdout


def test_bytes_grow_with_trip_count():
    def body(x, w):
        return jnp.tanh(x @ w), None

    x = jax.ShapeDtypeStruct((64, 64), jnp.bfloat16)
    for n in (4, 16):
        ws = jax.ShapeDtypeStruct((n, 64, 64), jnp.bfloat16)
        a = H.analyze(_compiled(
            lambda x, ws: jax.lax.scan(body, x, ws)[0], x, ws).as_text())
        if n == 4:
            b4 = a["bytes"]
        else:
            b16 = a["bytes"]
    assert b16 > 2.5 * b4, (b4, b16)
