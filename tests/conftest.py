import os
import sys

# make tests/ importable from nested test dirs (tests/kernels/...) so the
# shared _hypothesis_fallback stub resolves everywhere
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
