"""Deterministic, stateless synthetic LM data pipeline.

``batch = batch_at(spec, step)`` is a pure function of (seed, step, shard),
which is what makes checkpoint/restart *exact*: a resumed run replays the
identical token stream with no iterator state to persist (DESIGN.md §5
fault tolerance).  Host-sharding: each data-parallel host materializes only
its ``shard/num_shards`` slice of the global batch.

The stream is learnable (not uniform noise): each sequence interleaves
Markov-chain n-grams drawn from a small per-seed pattern bank with noise
tokens, so a ~10-20M model visibly reduces loss within a few hundred steps
(used by examples/train_wsd.py and the convergence test).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataSpec:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_patterns: int = 64        # pattern bank size
    pattern_len: int = 8
    noise_prob: float = 0.1
    num_shards: int = 1
    shard: int = 0

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.num_shards == 0
        return self.global_batch // self.num_shards


def _pattern_bank(spec: DataSpec) -> np.ndarray:
    rng = np.random.default_rng(spec.seed ^ 0x5EED)
    return rng.integers(0, spec.vocab, (spec.n_patterns, spec.pattern_len),
                        dtype=np.int32)


def _markov(spec: DataSpec) -> np.ndarray:
    """Pattern-to-pattern transition table (deterministic per seed)."""
    rng = np.random.default_rng(spec.seed ^ 0xA11CE)
    return rng.integers(0, spec.n_patterns, (spec.n_patterns, 4),
                        dtype=np.int32)


def batch_at(spec: DataSpec, step: int) -> dict:
    """Materialize this shard's (local_batch, seq_len) batch for ``step``."""
    bank = _pattern_bank(spec)
    trans = _markov(spec)
    lb = spec.local_batch
    rng = np.random.default_rng(
        (spec.seed * 1_000_003 + step) * 65_537 + spec.shard)
    n_pat = spec.seq_len // spec.pattern_len + 2
    seqs = np.empty((lb, n_pat * spec.pattern_len), np.int32)
    state = rng.integers(0, spec.n_patterns, lb)
    for i in range(n_pat):
        seqs[:, i * spec.pattern_len:(i + 1) * spec.pattern_len] = bank[state]
        state = trans[state, rng.integers(0, 4, lb)]
    seqs = seqs[:, :spec.seq_len + 1]
    noise = rng.random(seqs.shape) < spec.noise_prob
    seqs = np.where(noise, rng.integers(0, spec.vocab, seqs.shape), seqs)
    return {"tokens": seqs[:, :-1].astype(np.int32),
            "labels": seqs[:, 1:].astype(np.int32)}
