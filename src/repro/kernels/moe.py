"""Grouped bipolar-INT MoE expert GEMM Pallas TPU kernel.

Runs the capacity-dispatched expert linear ``(E, C, K) x (E, N, K) ->
(E, C, N)`` as ONE kernel launch over a ``(expert*group, row-tile,
col-tile, k-tile)`` grid, instead of either a per-expert launch loop or
the batched-over-E einsum of ``layers._expert_matmul`` (which unpacks
every expert's bit planes to int32 values in HBM and multiplies all
``E * C`` capacity rows, empty slots included).

Three ideas compose:

* **Scalar-prefetch routing counts** -- the per-(expert, group) live-row
  counts ride scalar prefetch (the same mechanism
  ``flash_attention_paged_quantized`` uses for block tables): they sit in
  SMEM before the grid starts, so the kernel body reads ``counts[eg]``
  and decides per tile whether any of its capacity rows hold a routed
  token.
* **``pl.when`` tile skipping** -- row tiles entirely beyond the live
  prefix skip the quantize prologue and every MXU pass and write zeros.
  Capacity dispatch pads each expert to ``cap`` rows; at decode batch
  sizes almost all of them are empty, so the skipped fraction is the
  decode-path waste the batched einsum silently pays.  (Pallas grid
  skipping elides compute, not the tile DMA.)
* **Fused-APMM prologue/epilogue** -- the dispatched float activations
  are quantized + bit-decomposed in the GEMM kernel's VMEM prologue
  (packed activation planes never exist in HBM) and the packed expert
  weights are recovered tile-locally (unpacked expert weights never
  exist in HBM), mirroring :func:`repro.kernels.apmm.apmm_fused_linear`
  -- including its dual-GEMM gate/up mode streaming one quantized A tile
  against both expert weights with the ``act(Y1) * Y2`` epilogue.

Numeric contract (checked bit-for-bit in tests/kernels/test_moe_expert.py):
activation quantization runs in **f32** from the materialized input --
scale and division exactly as ``layers._expert_quantize`` -- and the
epilogue dequantizes and (in dual mode) composes ``act(Y1) * Y2`` in
f32 with ONE cast to the output dtype, so live rows are bit-identical
to the legacy batched path.  Single-rounding f32 chains are the
load-bearing choice: a native-bf16 chain changes bits under XLA's
excess-precision convert elision depending on the surrounding jit
graph, so "input-dtype division" cannot be made compilation-stable.
Rows at or beyond a group's live count are exactly zero (the legacy
path leaves tiny eps-scale values there; the combine gather never
reads either, which is what keeps the ``moe_apply`` rewire
token-identical).

Like the dense APMM kernels, this kernel is width-agnostic: nested-
precision serving slices the ``(n_bits, E, N, Kw)`` packed expert
weights to their leading ``k`` planes in ``ops.ap_moe_expert_linear``
(``w_bits=k``), so the kernel streams only the served planes from HBM.

A second kernel output, the ``(E*G, n_row_tiles)`` int32 live map,
records which row tiles did work -- the interpret-mode proof of the
skip path and the source of the skipped-tile fraction in
benchmarks/moe_bench.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import bipolar
from repro.kernels import compat, ref
from repro.kernels.apmm import (DEFAULT_BK, DEFAULT_BM, DEFAULT_BN, _NT,
                                _recover_int8, _unpack)


def _quantize_tile(x, s, n_a: int, k_lo, k_orig: int):
    """Quantize a float tile ``(bc, bk)`` with per-row f32 scales
    ``(bc, 1)`` to the unsigned bipolar bit field, dividing in f32.

    ``layers._expert_quantize`` upcasts the materialized activations to
    f32 and runs the whole scale/divide/round chain there (single
    rounding); matching it exactly is what makes the grouped kernel
    bit-identical to the ``_expert_matmul`` oracle.  K-pad columns are
    forced to the all-zero-bit value ``-maxv`` (closed-form pad
    correction)."""
    q = bipolar.quantize_values(x.astype(jnp.float32), n_a, s)
    col = k_lo + jax.lax.broadcasted_iota(jnp.int32, q.shape, 1)
    q = jnp.where(col < k_orig, q, -bipolar.max_value(n_a))
    return bipolar.encode(q, n_a)


def _moe_kernel(cnt_ref, *refs, n_a: int, n_b: int, bc: int, bn: int,
                bk: int, k_orig: int, n_pad: int, variant: str, act: str,
                dual: bool):
    it = iter(refs)
    x_ref, as_ref = next(it), next(it)
    wp_ref, ws_ref = next(it), next(it)
    wp2_ref = next(it) if dual else None
    w2s_ref = next(it) if dual else None
    out_ref, live_ref = next(it), next(it)
    accs = list(it)                       # 1 or 2 scratch accumulators

    eg = pl.program_id(0)
    ci = pl.program_id(1)
    k_idx = pl.program_id(3)
    n_k = pl.num_programs(3)

    # scalar-prefetched live-row count of this (expert, group) segment;
    # the tile is live iff its first capacity row is below the count
    cnt = cnt_ref[eg]
    live = cnt > ci * bc
    live_ref[0, 0] = live.astype(jnp.int32)

    @pl.when(live & (k_idx == 0))
    def _init():
        if variant == "fused":
            init = jnp.full(
                (bc, bn),
                n_pad * bipolar.max_value(n_a) * bipolar.max_value(n_b),
                jnp.int32)
        else:
            init = jnp.full((n_a * n_b, bc, bn), n_pad, jnp.int32)
        for aref in accs:
            aref[...] = init

    @pl.when(live)
    def _compute():
        # prologue: quantize + bit-decompose the dispatched float rows in
        # VMEM.  x_ref holds the whole-K row block (index map ignores j
        # and k), so activations stream from HBM once per row tile.
        xk = x_ref[0, :, pl.dslice(k_idx * bk, bk)]
        ua = _quantize_tile(xk, as_ref[0], n_a, k_idx * bk, k_orig)
        streams = [(wp_ref, accs[0])] \
            + ([(wp2_ref, accs[1])] if dual else [])
        for bref, aref in streams:
            bpl = _unpack(bref[:, 0], n_b, bn, bk)
            if variant == "fused":
                for lo_a, sz_a in ref.plane_groups(n_a):
                    mask = (1 << sz_a) - 1
                    va = ((((ua >> lo_a) & mask) << 1)
                          - bipolar.max_value(sz_a)).astype(jnp.int8)
                    for lo_b, sz_b in ref.plane_groups(n_b):
                        b8 = _recover_int8(bpl, lo_b, sz_b)
                        y = jax.lax.dot_general(
                            va, b8, _NT, preferred_element_type=jnp.int32)
                        aref[...] += y << (lo_a + lo_b)
            else:
                for i in range(n_a):
                    a8 = (((ua >> i) & 1) * 2 - 1).astype(jnp.int8)
                    for j in range(n_b):
                        b8 = (2 * bpl[j] - 1).astype(jnp.int8)
                        aref[i * n_b + j] += jax.lax.dot_general(
                            a8, b8, _NT, preferred_element_type=jnp.int32)

    @pl.when((k_idx == n_k - 1) & live)
    def _finish():
        od = out_ref.dtype

        def recover_acc(aref):
            if variant == "fused":
                return aref[...]
            y = jnp.zeros((bc, bn), jnp.int32)
            for i in range(n_a):
                for j in range(n_b):
                    y = y + (aref[i * n_b + j] << (i + j))
            return y

        # dequant + epilogue in f32 with ONE output-dtype cast -- the
        # same cast point as the legacy f32 composition in moe_apply
        # (bit-identity; intermediate narrowing casts would not be
        # compilation-stable on the jnp side)
        a_s = as_ref[0]                                   # (bc, 1) f32
        yf = recover_acc(accs[0]).astype(jnp.float32) * a_s * ws_ref[0]
        if dual:
            y2 = recover_acc(accs[1]).astype(jnp.float32) \
                * a_s * w2s_ref[0]
            yf = ref.apply_act(yf, act) * y2
        elif act != "none":
            yf = ref.apply_act(yf, act)
        yo = yf.astype(od)
        # rows at/after the live count are exactly zero in every impl
        row = ci * bc + jax.lax.broadcasted_iota(jnp.int32, (bc, bn), 0)
        out_ref[0] = jnp.where(row < cnt, yo, jnp.zeros((), od))

    @pl.when((k_idx == n_k - 1) & jnp.logical_not(live))
    def _skip():
        out_ref[0] = jnp.zeros((bc, bn), out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("n_a", "n_b", "k_orig", "n_groups", "variant", "act",
                     "block", "out_dtype", "interpret"))
def moe_expert_linear(x: jax.Array, a_scale: jax.Array, counts: jax.Array,
                      wp: jax.Array, w_scale: jax.Array, *, wp2=None,
                      w2_scale=None, n_a: int, n_b: int, k_orig: int,
                      n_groups: int, variant: str = "fused",
                      act: str = "none",
                      block: tuple = (DEFAULT_BM, DEFAULT_BN, DEFAULT_BK),
                      out_dtype=jnp.bfloat16, interpret: bool = False):
    """Grouped quantized expert GEMM, one launch for all experts.

    Args:
      x: ``(EG, Cp, Kp)`` float dispatched activations -- ``EG = E *
        n_groups`` row segments of padded capacity ``Cp``, K padded to
        the tile boundary (pad columns masked in-kernel).
      a_scale: ``(EG, Cp, 1)`` f32 per-row activation scales (the f32
        quantize chain -- see :func:`_quantize_tile`).
      counts: ``(EG,)`` int32 live-row counts (scalar-prefetched); rows
        ``>= counts[eg]`` of segment ``eg`` produce exact zeros.
      wp: ``(n_b, E, Np, Kw)`` uint32 packed expert weight planes.
      w_scale: ``(E, 1, Np)`` f32 per-(expert, out-channel) scales.
      wp2/w2_scale: optional second expert weight (dual gate/up mode);
        the epilogue writes ``act(Y1) * Y2``.
      k_orig: unpadded reduction length (closed-form pad correction).

    Returns ``(y, live_map)``: ``y (EG, Cp, Np)`` in ``out_dtype`` and
    ``live_map (EG, Cp // bc)`` int32 marking row tiles that did MXU
    work (0 = skipped by ``pl.when``).

    Shapes must tile exactly (:func:`repro.kernels.ops.ap_moe_expert_linear`
    pads and unpads).
    """
    egs, cp, kp = x.shape
    n_b_, e, n, kw = wp.shape
    assert n_b_ == n_b and kp == kw * bipolar.PACK_WIDTH, (x.shape, wp.shape)
    assert egs == e * n_groups, (egs, e, n_groups)
    bm, bn, bk = block
    bc, bn = min(bm, cp), min(bn, n)
    bk = min(bk, kp)
    if bk % bipolar.PACK_WIDTH:
        raise ValueError(f"bk={bk} must be a multiple of {bipolar.PACK_WIDTH}")
    if cp % bc or n % bn or kp % bk:
        raise ValueError(f"({cp},{n},{kp}) not tiled by ({bc},{bn},{bk})")
    bk32 = bk // bipolar.PACK_WIDTH
    dual = wp2 is not None
    if dual:
        assert w2_scale is not None and wp2.shape == wp.shape, \
            (wp.shape, None if wp2 is None else wp2.shape)
    g = n_groups

    operands = [x, a_scale, wp, w_scale]
    in_specs = [
        # whole-K row block, re-fetched only when (eg, ci) changes
        pl.BlockSpec((1, bc, kp), lambda eg, ci, j, k, cc: (eg, ci, 0)),
        pl.BlockSpec((1, bc, 1), lambda eg, ci, j, k, cc: (eg, ci, 0)),
        # expert index = eg // n_groups (groups share their expert's
        # weights; the weight tile is re-fetched only across experts)
        pl.BlockSpec((n_b, 1, bn, bk32),
                     lambda eg, ci, j, k, cc: (0, eg // g, j, k)),
        pl.BlockSpec((1, 1, bn), lambda eg, ci, j, k, cc: (eg // g, 0, j)),
    ]
    if dual:
        operands += [wp2, w2_scale]
        in_specs += [
            pl.BlockSpec((n_b, 1, bn, bk32),
                         lambda eg, ci, j, k, cc: (0, eg // g, j, k)),
            pl.BlockSpec((1, 1, bn),
                         lambda eg, ci, j, k, cc: (eg // g, 0, j)),
        ]

    acc_shape = ((bc, bn) if variant == "fused" else (n_a * n_b, bc, bn))
    kernel = functools.partial(
        _moe_kernel, n_a=n_a, n_b=n_b, bc=bc, bn=bn, bk=bk, k_orig=k_orig,
        n_pad=kp - k_orig, variant=variant, act=act, dual=dual)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(egs, cp // bc, n // bn, kp // bk),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, bc, bn), lambda eg, ci, j, k, cc: (eg, ci, j)),
            pl.BlockSpec((1, 1), lambda eg, ci, j, k, cc: (eg, ci)),
        ],
        scratch_shapes=[pltpu.VMEM(acc_shape, jnp.int32)
                        for _ in range(1 + dual)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((egs, cp, n), out_dtype),
            jax.ShapeDtypeStruct((egs, cp // bc), jnp.int32),
        ],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(counts, *operands)
