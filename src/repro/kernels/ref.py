"""Pure-jnp oracles for the arbitrary-precision MatMul (APMM) kernels.

Convention (shared with the Pallas kernels): the GEMM is "NT" --

    Y (M, N) = A (M, K) @ B (N, K)^T

with *both* operands packed along their last (reduction) axis.  A is the
activation matrix in its natural ``(tokens, features)`` layout (pad bit 0);
B is the weight matrix in its natural ``(d_out, d_in)`` layout (pad bit 1).
No operand transpose ever materializes.

Reference implementations, all mathematically identical:

* :func:`apmm_exact`     -- exact int32 matmul on bipolar values (ground
  truth the kernels must match bit-for-bit).
* :func:`apmm_bitserial` -- paper-faithful §3.2: n_a * n_b one-bit (+-1)
  matmuls, then shift-add recovery ``Y = sum 2^{i+j} Y^(ij)``.
* :func:`apmm_fused`     -- TPU-native operand-level recovery: planes are
  recombined to int8 *before* a single matmul (distributivity).
* :func:`apmm_packed_ref` -- packed-layout reference used inside jitted
  model graphs on CPU and in the 512-device dry-run (same packed buffers
  and bytes as the Pallas kernel, expressed in plain jnp).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bipolar
from repro.core.bipolar import BipolarTensor

_NT = (((1,), (1,)), ((), ()))  # contract last dims of both operands


def apmm_exact(a_values: jax.Array, b_values: jax.Array) -> jax.Array:
    """Exact int32 NT matmul of odd-integer bipolar values. A:(M,K) B:(N,K)."""
    return jax.lax.dot_general(
        a_values.astype(jnp.int32), b_values.astype(jnp.int32),
        _NT, preferred_element_type=jnp.int32)


def apmm_bitserial(a_values: jax.Array, b_values: jax.Array,
                   n_a: int, n_b: int) -> jax.Array:
    """Paper §3.2: decompose -> n_a*n_b one-bit matmuls -> shift-add recover."""
    ap = bipolar.decompose(a_values, n_a)        # (n_a, M, K) in {0,1}
    bp = bipolar.decompose(b_values, n_b)        # (n_b, N, K)
    a_s = (2 * ap.astype(jnp.int8) - 1)          # {-1,+1}
    b_s = (2 * bp.astype(jnp.int8) - 1)
    y = jnp.zeros((a_values.shape[0], b_values.shape[0]), jnp.int32)
    for i in range(n_a):
        for j in range(n_b):
            yij = jax.lax.dot_general(a_s[i], b_s[j], _NT,
                                      preferred_element_type=jnp.int32)
            y = y + (yij << (i + j))
    return y


def plane_groups(n_bits: int, group: int = 7):
    """Split ``n_bits`` planes into balanced groups of <= ``group`` bits.

    A group's recombined bipolar value is an odd integer of magnitude
    <= 2^size - 1, which fits int8 while size <= 7.  Returns
    ``[(lo, size), ...]``.
    """
    n_groups = -(-n_bits // group)
    base, extra = divmod(n_bits, n_groups)
    out, lo = [], 0
    for g in range(n_groups):
        size = base + (1 if g < extra else 0)
        out.append((lo, size))
        lo += size
    return out


def apmm_fused(a_values: jax.Array, b_values: jax.Array,
               n_a: int, n_b: int) -> jax.Array:
    """Operand-level recovery (beyond-paper, TPU-native).

    ``(sum_i 2^i A^(i)) (sum_j 2^j B^(j))^T = sum_ij 2^{i+j} A^(i) B^(j)T``
    -- exact by distributivity -- so for bit-widths <= 7 the whole GEMM is
    ONE int8 MXU matmul.  Wider operands are split into <=7-bit *plane
    groups* (``ceil(n/7)`` each): ``ceil(n_a/7) * ceil(n_b/7)`` GEMMs
    instead of the paper's ``n_a * n_b``.
    """
    if n_a <= 7 and n_b <= 7:
        return jax.lax.dot_general(a_values.astype(jnp.int8),
                                   b_values.astype(jnp.int8), _NT,
                                   preferred_element_type=jnp.int32)
    ga, gb = plane_groups(n_a), plane_groups(n_b)
    # group value: v_g = (v >> lo) recentered to the group's odd grid:
    #   v = sum_g 2^lo_g * v_g  with  v_g = ((u >> lo) & (2^size-1)) * 2
    #                                        - (2^size - 1)
    ua = bipolar.encode(a_values, n_a)
    ub = bipolar.encode(b_values, n_b)
    y = None
    for lo_a, sz_a in ga:
        va = (((ua >> lo_a) & ((1 << sz_a) - 1)) << 1) - ((1 << sz_a) - 1)
        for lo_b, sz_b in gb:
            vb = (((ub >> lo_b) & ((1 << sz_b) - 1)) << 1) - ((1 << sz_b) - 1)
            yij = jax.lax.dot_general(va.astype(jnp.int8), vb.astype(jnp.int8),
                                      _NT, preferred_element_type=jnp.int32)
            yij = yij << (lo_a + lo_b)
            y = yij if y is None else y + yij
    return y


def _unpack_values(t: BipolarTensor) -> jax.Array:
    """Packed tensor -> bipolar integer values with K padded to the word
    boundary (pad columns decode to +-(2^n - 1) depending on pad bit)."""
    kp = t.packed.shape[-1] * bipolar.PACK_WIDTH
    planes = bipolar.unpack_planes(t.packed, -1, kp)
    return bipolar.recover(planes, t.n_bits)


def apmm_packed_ref(a: BipolarTensor, b: BipolarTensor,
                    fused: bool = True) -> jax.Array:
    """Packed-layout NT reference: unpack -> matmul -> closed-form pad fix.

    A ``(M, K)`` packed with pad_bit=0, B ``(N, K)`` packed with pad_bit=1:
    every padded k contributes ``-(2^{n_a}-1)(2^{n_b}-1)`` to each output,
    removed by adding ``n_pad * (2^{n_a}-1)(2^{n_b}-1)``
    (:func:`bipolar.pad_correction`).  Returns int32 ``A_int @ B_int^T``.
    """
    (m, k), (n, k2) = a.shape, b.shape
    assert k == k2, (a.shape, b.shape)
    kp = a.packed.shape[-1] * bipolar.PACK_WIDTH
    assert b.packed.shape[-1] * bipolar.PACK_WIDTH == kp
    if fused:
        y = apmm_fused(_unpack_values(a), _unpack_values(b), a.n_bits, b.n_bits)
    else:
        ap = bipolar.unpack_planes(a.packed, -1, kp)
        bp = bipolar.unpack_planes(b.packed, -1, kp)
        a_s = 2 * ap.astype(jnp.int8) - 1
        b_s = 2 * bp.astype(jnp.int8) - 1
        y = jnp.zeros((m, n), jnp.int32)
        for i in range(a.n_bits):
            for j in range(b.n_bits):
                yij = jax.lax.dot_general(a_s[i], b_s[j], _NT,
                                          preferred_element_type=jnp.int32)
                y = y + (yij << (i + j))
    n_pad = kp - k
    return y + n_pad * bipolar.max_value(a.n_bits) * bipolar.max_value(b.n_bits)


def gather_paged_kv(pool_leaf: jax.Array,
                    block_tables: jax.Array) -> jax.Array:
    """Materialize a per-request contiguous view of a paged pool leaf.

    ``pool_leaf (n_blocks, bs, ...)`` + ``block_tables (B, NB)`` ->
    ``(B, NB*bs, ...)``: request ``b``'s logical token ``t`` is block
    ``t // bs``, slot ``t % bs`` of its table row.  Pure indexing -- the
    ``reference`` impl of :func:`repro.kernels.ops.paged_kv_cache_attention`
    runs the contiguous attention oracle on this view, which is what
    makes "paging changes memory management, not math" a checkable
    statement (the gathered planes are byte-identical to the pool's).
    """
    b, nb = block_tables.shape
    bs = pool_leaf.shape[1]
    g = pool_leaf[block_tables.reshape(-1)]
    return g.reshape((b, nb * bs) + pool_leaf.shape[2:])


# ---------------------------------------------------------------------------
# Fused quantized linear (quantize-in-graph + epilogue), pure jnp
# ---------------------------------------------------------------------------

def apply_act(y: jax.Array, act: str) -> jax.Array:
    """Epilogue activation (shared by kernel and reference paths)."""
    if act == "silu":
        return jax.nn.silu(y)
    if act == "gelu":
        return jax.nn.gelu(y)
    assert act == "none", act
    return y


def _linear_int_core(q: jax.Array, w: BipolarTensor, n_a: int,
                     variant: str) -> jax.Array:
    """Exact int32 NT GEMM of quantized activation *values* ``q (M, K)``
    against a packed weight, K-pad corrected.

    The activation side never exists as packed planes -- the reference
    twin of the in-VMEM quantize prologue of
    :func:`repro.kernels.apmm.apmm_fused_linear`."""
    k = w.shape[-1]
    assert q.shape[-1] == k, (q.shape, w.shape)
    kp = w.packed.shape[-1] * bipolar.PACK_WIDTH
    vals = bipolar.recover(bipolar.unpack_planes(w.packed, -1, kp),
                           w.n_bits)                 # pads -> +maxw
    if kp > k:   # activation pad columns: all-zero bits = -maxa
        q = jnp.pad(q, ((0, 0), (0, kp - k)),
                    constant_values=-bipolar.max_value(n_a))
    if variant == "fused":
        y = apmm_fused(q, vals, n_a, w.n_bits)
    else:
        y = apmm_bitserial(q, vals, n_a, w.n_bits)
    return y + (kp - k) * bipolar.max_value(n_a) * bipolar.max_value(w.n_bits)


def ap_linear_fused_ref(x2: jax.Array, a_scale: jax.Array,
                        w: BipolarTensor, *, w2=None, bias=None,
                        residual=None, a_bits: int, variant: str = "fused",
                        act: str = "none",
                        out_dtype=jnp.float32) -> jax.Array:
    """Reference fused linear: quantize activations to *values* (no HBM
    packing round trip), integer GEMM(s), then the epilogue with the
    same out-dtype cast points as the Pallas kernel -- bit-identical to
    both the kernel and the unfused quantize_rows -> ap_matmul ->
    jnp-epilogue composition."""
    q = bipolar.quantize_values(x2.astype(jnp.float32), a_bits, a_scale)
    a_s = a_scale.reshape(-1, 1).astype(jnp.float32)
    yf = _linear_int_core(q, w, a_bits, variant).astype(jnp.float32) \
        * a_s * w.scale.reshape(1, -1)
    if bias is not None:
        yf = yf + bias.reshape(1, -1).astype(jnp.float32)
    yo = yf.astype(out_dtype)
    if w2 is not None:
        y2 = _linear_int_core(q, w2, a_bits, variant).astype(jnp.float32) \
            * a_s * w2.scale.reshape(1, -1)
        h = apply_act(yo.astype(jnp.float32), act) \
            * y2.astype(out_dtype).astype(jnp.float32)
        yo = h.astype(out_dtype)
    elif act != "none":
        yo = apply_act(yo.astype(jnp.float32), act).astype(out_dtype)
    if residual is not None:
        yo = yo + residual.astype(out_dtype)
    return yo


_EB = (((2,), (2,)), ((0,), (0,)))  # batch experts, contract last dims


def _moe_expert_int_core(q: jax.Array, w: BipolarTensor, n_a: int,
                         variant: str) -> jax.Array:
    """Exact int32 batched expert NT GEMM ``(E, C, K) x (E, N, K) ->
    (E, C, N)`` of quantized activation *values* against packed expert
    weights, K-pad corrected.

    The lean twin of the grouped kernel's dataflow: weight planes stay
    uint8 out of :func:`bipolar.unpack_planes` and are recombined
    per plane group straight to int8 MXU operands -- the int32 value
    tensor ``(E, N, Kp)`` that ``layers._expert_matmul`` materializes
    never exists (4x less dot-operand traffic, which is what the
    BENCH_moe HLO census measures)."""
    e, c, k = q.shape
    kp = w.packed.shape[-1] * bipolar.PACK_WIDTH
    planes = bipolar.unpack_planes(w.packed, -1, kp)   # (n_b, E, N, Kp) u8
    if kp > k:   # activation pad columns: all-zero bits = -maxa
        q = jnp.pad(q, ((0, 0), (0, 0), (0, kp - k)),
                    constant_values=-bipolar.max_value(n_a))
    ua = bipolar.encode(q, n_a)
    n_b = w.n_bits
    y = None
    if variant == "fused":
        for lo_a, sz_a in plane_groups(n_a):
            va = ((((ua >> lo_a) & ((1 << sz_a) - 1)) << 1)
                  - ((1 << sz_a) - 1)).astype(jnp.int8)
            for lo_b, sz_b in plane_groups(n_b):
                acc = planes[lo_b].astype(jnp.int16) << 1
                for i in range(lo_b + 1, lo_b + sz_b):
                    acc = acc + (planes[i].astype(jnp.int16)
                                 << (i - lo_b + 1))
                vb = (acc - bipolar.max_value(sz_b)).astype(jnp.int8)
                yij = jax.lax.dot_general(
                    va, vb, _EB, preferred_element_type=jnp.int32)
                yij = yij << (lo_a + lo_b)
                y = yij if y is None else y + yij
    else:
        for i in range(n_a):
            a8 = ((((ua >> i) & 1) << 1) - 1).astype(jnp.int8)
            for j in range(n_b):
                b8 = 2 * planes[j].astype(jnp.int8) - 1
                yij = jax.lax.dot_general(
                    a8, b8, _EB, preferred_element_type=jnp.int32)
                yij = yij << (i + j)
                y = yij if y is None else y + yij
    return y + (kp - k) * bipolar.max_value(n_a) * bipolar.max_value(n_b)


def ap_moe_expert_linear_ref(x: jax.Array, a_scale: jax.Array,
                             counts: jax.Array, w: BipolarTensor, *,
                             w2=None, a_bits: int, variant: str = "fused",
                             act: str = "none",
                             out_dtype=None) -> jax.Array:
    """Reference grouped expert linear (see ops.ap_moe_expert_linear).

    Quantizes the dispatched activations in f32 (the single-rounding
    chain of ``layers._expert_quantize``), runs the lean int core per
    weight operand, and composes the epilogue in f32 with ONE cast to
    the output dtype -- the same cast point as the legacy f32
    composition in ``moe_apply``, so live rows are bit-identical to
    ``_expert_matmul``; rows at/after a group's live count are exactly
    zero."""
    od = out_dtype if out_dtype is not None else x.dtype
    q = bipolar.quantize_values(x.astype(jnp.float32), a_bits, a_scale)
    a_s = a_scale                                      # (E, C, 1) f32
    yf = _moe_expert_int_core(q, w, a_bits, variant).astype(jnp.float32) \
        * a_s * w.scale[:, None, :, 0]
    if w2 is not None:
        y2 = _moe_expert_int_core(q, w2, a_bits, variant) \
            .astype(jnp.float32) * a_s * w2.scale[:, None, :, 0]
        yf = apply_act(yf, act) * y2
    elif act != "none":
        yf = apply_act(yf, act)
    yo = yf.astype(od)
    e, c, _ = x.shape
    seg = c // counts.shape[1]
    off = jnp.arange(c, dtype=jnp.int32) % seg
    grp = jnp.arange(c) // seg
    live = off[None, :] < counts[:, grp]               # (E, C)
    return jnp.where(live[..., None], yo, jnp.zeros((), od))


def apmm_dequant_ref(a: BipolarTensor, b: BipolarTensor,
                     fused: bool = True,
                     out_dtype=jnp.float32) -> jax.Array:
    """Full quantized GEMM: int core + scale dequant.

    A scales are per-row ``(M, 1)`` (per token); B scales per-row ``(N, 1)``
    (per output channel) -- they apply as an outer product after the int
    matmul.
    """
    y = apmm_packed_ref(a, b, fused=fused).astype(jnp.float32)
    y = y * a.scale.reshape(-1, 1) * b.scale.reshape(1, -1)
    return y.astype(out_dtype)
