"""Flash attention (online softmax) Pallas TPU kernel.

Motivated by the roofline analysis (EXPERIMENTS.md §Perf): prefill cells
of MHA-heavy archs are dominated by materialized (Sq x T) score traffic
-- e.g. minicpm-2b/prefill_32k moves ~26 TiB/chip, ~80% of it score
tensors the jnp dataflow must round-trip through HBM.  This kernel keeps
the score tile in VMEM: HBM traffic drops to Q/K/V/O (+tiny pos masks).

Layout: heads folded into batch -- ``q (BH, Sq, D)``, ``k/v (BH, T, D)``,
``q_pos (BH, Sq)``, ``kv_pos (BH, T)`` int32 (negative kv_pos = invalid
slot, matching the cache convention).  Causal/window masking is by
absolute position, so GQA folding, ring caches and padded prefixes all
work unchanged.

Grid ``(BH, Sq/bq, T/bk)`` with the KV axis innermost ("arbitrary");
scratch: running max/denominator ``(bq, 1)`` and the f32 output
accumulator ``(bq, D)`` -- the classic two-pass-free online softmax.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 512
DEFAULT_BK = 512


def _kernel(qp_ref, kp_ref, q_ref, k_ref, v_ref, out_ref,
            m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window, bq: int, bk: int, d: int):
    jk = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full((bq, 1), -1e30, jnp.float32)
        l_ref[...] = jnp.zeros((bq, 1), jnp.float32)
        acc_ref[...] = jnp.zeros((bq, d), jnp.float32)

    q = q_ref[0]                                  # (bq, d)
    k = k_ref[0]                                  # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = qp_ref[0][:, None]                     # (bq, 1) int32
    kpos = kp_ref[0][None, :]                     # (1, bk)
    valid = kpos >= 0
    if causal:
        valid &= kpos <= qpos
    if window is not None:
        valid &= kpos > qpos - window
    s = jnp.where(valid, s, -1e30)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * alpha + jnp.sum(p, -1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(jk == nk - 1)
    def _done():
        out_ref[0] = (acc_ref[...]
                      / jnp.maximum(l_ref[...], 1e-20)).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    q_pos: jax.Array, kv_pos: jax.Array, *,
                    causal: bool = True, window=None,
                    block: tuple = (DEFAULT_BQ, DEFAULT_BK),
                    interpret: bool = False) -> jax.Array:
    """Online-softmax attention. q (BH,Sq,D), k/v (BH,T,D) -> (BH,Sq,D).

    Shapes must tile exactly (wrapper in ops pads); fully-masked rows
    return 0 (denominator clamp), matching the jnp reference.
    """
    bh, sq, d = q.shape
    t = k.shape[1]
    bq, bk = min(block[0], sq), min(block[1], t)
    if sq % bq or t % bk:
        raise ValueError(f"({sq},{t}) not tiled by ({bq},{bk})")
    kernel = functools.partial(
        _kernel, scale=1.0 / np.sqrt(d), causal=causal, window=window,
        bq=bq, bk=bk, d=d)
    return pl.pallas_call(
        kernel,
        grid=(bh, sq // bq, t // bk),
        in_specs=[
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),       # q_pos
            pl.BlockSpec((1, bk), lambda b, i, j: (b, j)),       # kv_pos
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),  # q
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),  # k
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),  # v
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, d), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q_pos, kv_pos, q, k, v)
