"""Flash attention (online softmax) Pallas TPU kernels: float + bipolar KV.

Motivated by the roofline analysis (EXPERIMENTS.md §Perf): prefill cells
of MHA-heavy archs are dominated by materialized (Sq x T) score traffic
-- e.g. minicpm-2b/prefill_32k moves ~26 TiB/chip, ~80% of it score
tensors the jnp dataflow must round-trip through HBM.  This kernel keeps
the score tile in VMEM: HBM traffic drops to Q/K/V/O (+tiny pos masks).

Layout: heads folded into batch -- ``q (BH, Sq, D)``, ``k/v (BH, T, D)``,
``q_pos (BH, Sq)``, ``kv_pos (BH, T)`` int32 (negative kv_pos = invalid
slot, matching the cache convention).  Causal/window masking is by
absolute position, so GQA folding, ring caches and padded prefixes all
work unchanged.

Grid ``(BH, Sq/bq, T/bk)`` with the KV axis innermost ("arbitrary");
scratch: running max/denominator ``(bq, 1)`` and the f32 output
accumulator ``(bq, D)`` -- the classic two-pass-free online softmax.

:func:`flash_attention_quantized` extends this to the bipolar-INT KV
cache (paper §3.1/§4.1 applied to the decode-dominating tensor): K/V
arrive as packed uint32 bit planes ``(BH, T, n_bits, D/32)`` with
per-(token, head) absmax scales, and *recovery happens inside the
kernel* -- HBM moves ``kv_bits`` bits per cache element instead of 16,
and the dequantized tile never round-trips through HBM (the §4.2
"recovery in shared memory" scheduling, on the TPU memory hierarchy).
:func:`attention_reference` is the pure-jnp twin used by the
``reference`` impl of the :mod:`repro.kernels.ops` dispatch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import bipolar
from repro.kernels import compat

DEFAULT_BQ = 512
DEFAULT_BK = 512


def _online_softmax_update(s, valid, v, m_ref, l_ref, acc_ref):
    """One KV-tile update of the running (max, denom, acc) state.

    Invalid slots are zeroed in ``p`` (not just pushed to -1e30): a row
    whose every slot is masked must end with denominator ~0 so the final
    clamp returns 0, identically across kernel and reference impls.
    """
    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * alpha + jnp.sum(p, -1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _position_mask(qpos, kpos, causal: bool, window):
    valid = kpos >= 0
    if causal:
        valid &= kpos <= qpos
    if window is not None:
        valid &= kpos > qpos - window
    return valid


def _kernel(qp_ref, kp_ref, q_ref, k_ref, v_ref, out_ref,
            m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window, bq: int, bk: int, d: int):
    jk = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full((bq, 1), -1e30, jnp.float32)
        l_ref[...] = jnp.zeros((bq, 1), jnp.float32)
        acc_ref[...] = jnp.zeros((bq, d), jnp.float32)

    q = q_ref[0]                                  # (bq, d)
    k = k_ref[0]                                  # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = qp_ref[0][:, None]                     # (bq, 1) int32
    kpos = kp_ref[0][None, :]                     # (1, bk)
    valid = _position_mask(qpos, kpos, causal, window)
    s = jnp.where(valid, s, -1e30)
    _online_softmax_update(s, valid, v_ref[0], m_ref, l_ref, acc_ref)

    @pl.when(jk == nk - 1)
    def _done():
        out_ref[0] = (acc_ref[...]
                      / jnp.maximum(l_ref[...], 1e-20)).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    q_pos: jax.Array, kv_pos: jax.Array, *,
                    causal: bool = True, window=None,
                    block: tuple = (DEFAULT_BQ, DEFAULT_BK),
                    interpret: bool = False) -> jax.Array:
    """Online-softmax attention. q (BH,Sq,D), k/v (BH,T,D) -> (BH,Sq,D).

    Shapes must tile exactly (wrapper in ops pads); fully-masked rows
    return 0 (denominator clamp), matching the jnp reference.
    """
    bh, sq, d = q.shape
    t = k.shape[1]
    bq, bk = min(block[0], sq), min(block[1], t)
    if sq % bq or t % bk:
        raise ValueError(f"({sq},{t}) not tiled by ({bq},{bk})")
    kernel = functools.partial(
        _kernel, scale=1.0 / np.sqrt(d), causal=causal, window=window,
        bq=bq, bk=bk, d=d)
    return pl.pallas_call(
        kernel,
        grid=(bh, sq // bq, t // bk),
        in_specs=[
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),       # q_pos
            pl.BlockSpec((1, bk), lambda b, i, j: (b, j)),       # kv_pos
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),  # q
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),  # k
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),  # v
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, d), jnp.float32)],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q_pos, kv_pos, q, k, v)


# ---------------------------------------------------------------------------
# Bipolar-quantized KV cache variant (dequant-on-read in VMEM)
# ---------------------------------------------------------------------------

def _dequant_tile(packed, scale, n_bits: int, bk: int, dp: int):
    """Packed planes (bk, n_bits, dp/32) uint32 + scale (bk, 1) -> f32 tile.

    Bipolar recovery without materializing {-1,+1} planes:
    ``v = (sum_i b_i << (i+1)) - (2^n - 1)`` (see bipolar.recover).
    """
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 1, 32), 3)
    bits = (packed[..., None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(bk, n_bits, dp).astype(jnp.int32)
    acc = bits[:, 0, :] << 1
    for i in range(1, n_bits):
        acc = acc + (bits[:, i, :] << (i + 1))
    vals = acc - bipolar.max_value(n_bits)
    return vals.astype(jnp.float32) * scale


def _kernel_quant(qp_ref, kp_ref, ks_ref, vs_ref, q_ref, kq_ref, vq_ref,
                  out_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window,
                  bq: int, bk: int, dp: int, n_bits: int):
    jk = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full((bq, 1), -1e30, jnp.float32)
        l_ref[...] = jnp.zeros((bq, 1), jnp.float32)
        acc_ref[...] = jnp.zeros((bq, dp), jnp.float32)

    # recover K/V tiles from packed bit planes entirely in VMEM; pad
    # columns of D decode to garbage but q is zero-padded there, and pad
    # T slots carry kv_pos=-1 so the position mask removes them.
    k = _dequant_tile(kq_ref[0], ks_ref[0][:, None], n_bits, bk, dp)
    v = _dequant_tile(vq_ref[0], vs_ref[0][:, None], n_bits, bk, dp)

    q = q_ref[0]                                  # (bq, dp), zero pad cols
    s = jax.lax.dot_general(q.astype(jnp.float32), k,
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qpos = qp_ref[0][:, None]
    kpos = kp_ref[0][None, :]
    valid = _position_mask(qpos, kpos, causal, window)
    s = jnp.where(valid, s, -1e30)
    _online_softmax_update(s, valid, v, m_ref, l_ref, acc_ref)

    @pl.when(jk == nk - 1)
    def _done():
        out_ref[0] = (acc_ref[...]
                      / jnp.maximum(l_ref[...], 1e-20)).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("d", "n_bits", "causal", "window", "block", "interpret"))
def flash_attention_quantized(q: jax.Array,
                              k_packed: jax.Array, k_scale: jax.Array,
                              v_packed: jax.Array, v_scale: jax.Array,
                              q_pos: jax.Array, kv_pos: jax.Array, *,
                              d: int, n_bits: int,
                              causal: bool = True, window=None,
                              block: tuple = (DEFAULT_BQ, DEFAULT_BK),
                              interpret: bool = False) -> jax.Array:
    """Attention over a packed bipolar-INT KV cache, dequant-on-read.

    Args:
      q: ``(BH, Sq, Dp)`` with ``Dp = 32 * ceil(d/32)``; columns past the
        true head dim ``d`` MUST be zero (the ops wrapper pads).
      k_packed/v_packed: ``(BH, T, n_bits, Dp/32)`` uint32 bit planes.
      k_scale/v_scale: ``(BH, T)`` f32 per-(token, head) absmax scales.
      q_pos/kv_pos: ``(BH, Sq)`` / ``(BH, T)`` int32 absolute positions;
        negative kv_pos = invalid slot (also used for T padding).
      d: true head dim (sets the softmax scale).

    Returns ``(BH, Sq, Dp)``; the caller slices ``[..., :d]``.
    """
    bh, sq, dp = q.shape
    t = k_packed.shape[1]
    dw = dp // bipolar.PACK_WIDTH
    assert k_packed.shape == (bh, t, n_bits, dw), (k_packed.shape, q.shape)
    bq, bk = min(block[0], sq), min(block[1], t)
    if sq % bq or t % bk:
        raise ValueError(f"({sq},{t}) not tiled by ({bq},{bk})")
    kernel = functools.partial(
        _kernel_quant, scale=1.0 / np.sqrt(d), causal=causal, window=window,
        bq=bq, bk=bk, dp=dp, n_bits=n_bits)
    return pl.pallas_call(
        kernel,
        grid=(bh, sq // bq, t // bk),
        in_specs=[
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),        # q_pos
            pl.BlockSpec((1, bk), lambda b, i, j: (b, j)),        # kv_pos
            pl.BlockSpec((1, bk), lambda b, i, j: (b, j)),        # k_scale
            pl.BlockSpec((1, bk), lambda b, i, j: (b, j)),        # v_scale
            pl.BlockSpec((1, bq, dp), lambda b, i, j: (b, i, 0)),  # q
            pl.BlockSpec((1, bk, n_bits, dw),
                         lambda b, i, j: (b, j, 0, 0)),            # k planes
            pl.BlockSpec((1, bk, n_bits, dw),
                         lambda b, i, j: (b, j, 0, 0)),            # v planes
        ],
        out_specs=pl.BlockSpec((1, bq, dp), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dp), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, dp), jnp.float32)],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q_pos, kv_pos, k_scale, v_scale, q, k_packed, v_packed)


# ---------------------------------------------------------------------------
# Paged variant: KV read through a block table (serving block pool)
# ---------------------------------------------------------------------------

DEFAULT_PAGED_BQ = 256   # query rows per tile (suffix prefill can be long)


def _kernel_paged(bt_ref, qp_ref, kp_ref, ks_ref, vs_ref, q_ref, kq_ref,
                  vq_ref, out_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window,
                  bq: int, bs: int, dp: int, n_bits: int):
    del bt_ref  # consumed by the index maps (scalar prefetch)
    jk = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full((bq, 1), -1e30, jnp.float32)
        l_ref[...] = jnp.zeros((bq, 1), jnp.float32)
        acc_ref[...] = jnp.zeros((bq, dp), jnp.float32)

    # position mask first: causal + sliding window by absolute position,
    # invalid slots (pos -1: null block / freshly allocated) excluded
    qpos = qp_ref[0][:, None]                     # (bq, 1)
    kpos = kp_ref[0][None, :]                     # (1, bs)
    valid = _position_mask(qpos, kpos, causal, window)

    # grid skip: a block none of this tile's queries may see -- the
    # null block behind a padded table entry, a block fully outside
    # every query's attention window, or (Sq>1 suffix prefill) a block
    # entirely in this tile's causal future -- contributes exactly
    # nothing to the online softmax (p = 0, alpha = 1), so the dequant
    # and both MXU passes are skipped outright.  Out-of-window blocks
    # normally never reach the kernel at all (the scheduler reclaims
    # them and the rolling block table bounds the grid itself); this
    # guard covers the in-between steps and the padded table entries.
    @pl.when(jnp.any(valid))
    def _update():
        # one physical block of the pool, routed here by the block
        # table: kq_ref block is (1, bs, 1, n_bits, dw) -> (bs, n_bits, dw)
        k = _dequant_tile(kq_ref[0][:, 0], ks_ref[0], n_bits, bs, dp)
        v = _dequant_tile(vq_ref[0][:, 0], vs_ref[0], n_bits, bs, dp)
        q = q_ref[0, 0]                           # (bq, dp), zero pad cols
        s = jax.lax.dot_general(q.astype(jnp.float32), k,
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid, s, -1e30)
        _online_softmax_update(s, valid, v, m_ref, l_ref, acc_ref)

    @pl.when(jk == nk - 1)
    def _done():
        out_ref[0, 0] = (acc_ref[...]
                         / jnp.maximum(l_ref[...], 1e-20)).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("d", "n_bits", "causal", "window", "block", "interpret"))
def flash_attention_paged_quantized(q: jax.Array,
                                    k_pool: jax.Array, k_scale: jax.Array,
                                    v_pool: jax.Array, v_scale: jax.Array,
                                    pool_pos: jax.Array,
                                    block_tables: jax.Array,
                                    q_pos: jax.Array, *,
                                    d: int, n_bits: int,
                                    causal: bool = True, window=None,
                                    block: int = DEFAULT_PAGED_BQ,
                                    interpret: bool = False) -> jax.Array:
    """Dequant-on-read attention over a *paged* bipolar-INT KV pool.

    The pool stores fixed-size token blocks shared by all requests; each
    request addresses its blocks through a block table.  The table is a
    scalar-prefetch operand: the Mosaic grid walks ``(B, H, Gq/bq,
    n_blocks)`` and the K/V block specs index the pool with
    ``table[b, j]``, so HBM only ever moves the blocks a request
    actually owns -- the gather never materializes a contiguous copy.

    Decode calls carry one query row per GQA group; block-table *suffix
    prefill* folds the suffix length into the query axis (``Gq = G *
    Sq``), tiled ``bq`` rows at a time with causal masking by absolute
    position -- the suffix attends through the shared prefix blocks and
    its own freshly written blocks in a single pass.

    Sliding-window attention (``window``) masks ``kv_pos <= q_pos -
    window`` by absolute position, and the kernel *skips* any block
    none of the tile's queries may see (fully out-of-window, the null
    block behind padded table entries, or entirely in the causal
    future): the masked tile's dequant and MXU work never issue.  With
    the serving scheduler's out-of-window reclaim the block table
    itself is a rolling window, so the grid's block axis -- and the HBM
    the step moves -- stays O(window / block_size) per request however
    long the generation runs.

    Args:
      q: ``(B, H, Gq, Dp)`` -- per-kv-head grouped queries (``Gq`` =
        group size x query tokens), zero-padded past the true head dim
        ``d`` (``Dp = 32*ceil(d/32)``); ``Gq`` must tile by ``block``.
      k_pool/v_pool: ``(n_blocks, bs, H, n_bits, Dp/32)`` uint32 planes.
      k_scale/v_scale: ``(n_blocks, bs, H)`` f32 absmax scales.
      pool_pos: ``(n_blocks, bs)`` int32 absolute positions, -1 = empty
        slot (freshly allocated or null block 0).
      block_tables: ``(B, NB)`` int32 physical block ids; rows pad with
        0, the reserved null block whose positions stay -1.
      q_pos: ``(B, Gq)`` int32 query positions (-1 rows are masked out).

    Returns ``(B, H, Gq, Dp)``; the caller slices ``[..., :d]``.
    """
    b, h, gq, dp = q.shape
    n_blocks, bs, hp, nb_bits, dw = k_pool.shape
    nb = block_tables.shape[1]
    assert (hp, nb_bits, dw * bipolar.PACK_WIDTH) == (h, n_bits, dp), (
        k_pool.shape, q.shape)
    bq = min(block, gq)
    if gq % bq:
        raise ValueError(f"query rows {gq} not tiled by {bq}")
    kernel = functools.partial(
        _kernel_paged, scale=1.0 / np.sqrt(d), causal=causal, window=window,
        bq=bq, bs=bs, dp=dp, n_bits=n_bits)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, h, gq // bq, nb),
        in_specs=[
            pl.BlockSpec((1, bq), lambda i, j, q, k, bt: (i, q)),   # q_pos
            pl.BlockSpec((1, bs), lambda i, j, q, k, bt: (bt[i, k], 0)),
            pl.BlockSpec((1, bs, 1),
                         lambda i, j, q, k, bt: (bt[i, k], 0, j)),  # k_scale
            pl.BlockSpec((1, bs, 1),
                         lambda i, j, q, k, bt: (bt[i, k], 0, j)),  # v_scale
            pl.BlockSpec((1, 1, bq, dp),
                         lambda i, j, q, k, bt: (i, j, q, 0)),      # q
            pl.BlockSpec((1, bs, 1, n_bits, dw),
                         lambda i, j, q, k, bt: (bt[i, k], 0, j, 0, 0)),
            pl.BlockSpec((1, bs, 1, n_bits, dw),
                         lambda i, j, q, k, bt: (bt[i, k], 0, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dp),
                               lambda i, j, q, k, bt: (i, j, q, 0)),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, dp), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, gq, dp), q.dtype),
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(block_tables, q_pos, pool_pos, k_scale, v_scale, q, k_pool, v_pool)


def attention_reference(q, k, v, q_pos, kv_pos, *, causal=True, window=None):
    """Pure-jnp oracle in the folded (BH, S, D) kernel layout.

    Direct (non-online) softmax; fully-masked rows return 0, matching the
    kernels' denominator clamp.
    """
    d = q.shape[-1]
    s = jnp.einsum("bqd,btd->bqt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(d)
    valid = _position_mask(q_pos[:, :, None], kv_pos[:, None, :],
                           causal, window)
    s = jnp.where(valid, s, -1e30)
    m = jnp.maximum(jnp.max(s, -1, keepdims=True), -1e30)
    p = jnp.where(valid, jnp.exp(s - m), 0.0)
    o = jnp.einsum("bqt,btd->bqd", p, v.astype(jnp.float32))
    o = o / jnp.maximum(p.sum(-1, keepdims=True), 1e-20)
    return o.astype(q.dtype)
