"""Arbitrary-precision MatMul (APMM) Pallas TPU kernels.

Implements the paper's bit-wise MatMul reconstitution (§3.2) with the
recovery-oriented memory scheduling of §4.2, adapted from GPU shared
memory / tensor-core fragments to the TPU memory hierarchy:

* HBM holds only the §4.1 *packed* layout: both operands are row-major and
  packed along their last (reduction) axis K into uint32 words --
  ``A (n_a, M, K/32)`` for activations (tokens x features, pad bit 0) and
  ``B (n_b, N, K/32)`` for weights (d_out x d_in, pad bit 1).  The GEMM is
  "NT" (``Y = A @ B^T``), so no operand transpose ever materializes and an
  n-bit matrix costs exactly n bits/element of HBM traffic.
* Each Pallas grid cell owns one ``(bm, bn)`` output tile (the paper's
  "one SM computes all bit-pair products of one block", §4.2 ①); all
  ``n_a * n_b`` bit-plane combinations for that tile are produced from
  VMEM-resident packed tiles, so *recovery never touches HBM* (§4.2 ②).
* Pallas grid pipelining double-buffers the HBM->VMEM tile streams --
  the TPU analogue of the paper's two alternating shared-memory buffers
  (§4.2 ③).
* Two variants:

  - ``variant="bitserial"`` (paper-faithful): unpack each plane to a
    {-1,+1} int8 tile, run one MXU GEMM per (i, j) bit pair, keep
    ``n_a * n_b`` int32 accumulators in VMEM scratch, and shift-add them
    into the output after the K loop -- the literal §3.2 dataflow with the
    §4.2 ④ loop order (one A plane reused against all B planes).  On GPU
    each per-pair GEMM is a 1-bit XOR-popcount MMA; the TPU has no 1-bit
    MXU mode, so plane GEMMs execute as int8 MXU ops (DESIGN.md §2,
    "what does not transfer").

  - ``variant="fused"`` (beyond-paper, TPU-native): because the MXU's
    atomic precision is already int8, the recovery sum can be folded into
    the *operands* -- ``(sum_i 2^i A^(i)) (sum_j 2^j B^(j))^T =
    sum_ij 2^{i+j} A^(i) B^(j)T`` exactly -- turning ``n_a * n_b`` GEMMs
    into one int8 GEMM per tile (valid for bit-widths <= 7).

K padding to the 32-bit word boundary is corrected in closed form by
pre-loading the accumulator with ``n_pad * (2^{n_a}-1)(2^{n_b}-1)``, so
arbitrary K is exact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import bipolar
from repro.kernels import compat, ref

# Default tile sizes: MXU-aligned (multiples of 128 on the GEMM dims) and
# sized so packed tiles + unpacked int8 tiles + the int32 accumulator fit
# v5e VMEM (~128 MiB) with double buffering:
#   packed A/B      n * 256 * (512/32) * 4 B  = n * 16 KiB each
#   unpacked int8   2 * 256 * 512             = 256 KiB
#   acc int32       256 * 256 * 4             = 256 KiB (x n_a*n_b bitserial)
DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 512

_NT = (((1,), (1,)), ((), ()))  # contract last dims (A @ B^T)


def _unpack(p, n_bits: int, r: int, bk: int):
    """(n, r, bk//32) uint32 -> (n, r, bk) int32 bit planes in {0,1}.

    Element k = 32*w + b of a row is bit b of word w: unpack the 32 bits of
    each word onto a trailing axis and merge it with the word axis.
    """
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 1, 32), 3)
    bits = (p[..., None] >> shifts) & jnp.uint32(1)        # (n, r, bk32, 32)
    return bits.reshape(n_bits, r, bk).astype(jnp.int32)


def _recover_int8(planes, lo: int, size: int):
    """{0,1} planes (n, r, k) -> recombined bipolar int8 (r, k) for the
    plane group ``[lo, lo+size)``.

    ``sum_{i in group} 2^{i-lo} (2 b_i - 1)
        = (sum b_i << (i-lo+1)) - (2^size - 1)``.
    """
    acc = planes[lo] << 1
    for i in range(lo + 1, lo + size):
        acc = acc + (planes[i] << (i - lo + 1))
    return (acc - bipolar.max_value(size)).astype(jnp.int8)


def _kernel(ap_ref, bp_ref, as_ref, bs_ref, out_ref, acc_ref, *,
            n_a: int, n_b: int, bm: int, bn: int, bk: int,
            n_pad: int, variant: str, dequant: bool):
    k_idx = pl.program_id(2)
    n_k = pl.num_programs(2)

    ap = _unpack(ap_ref[...], n_a, bm, bk)         # {0,1} int32
    bp = _unpack(bp_ref[...], n_b, bn, bk)

    if variant == "fused":
        # Operand-level recovery: one int8 MXU GEMM per <=7-bit plane-group
        # pair (a single GEMM for the common n <= 7 case).
        @pl.when(k_idx == 0)
        def _init():
            acc_ref[...] = jnp.full(
                (bm, bn),
                n_pad * bipolar.max_value(n_a) * bipolar.max_value(n_b),
                jnp.int32)

        for lo_a, sz_a in ref.plane_groups(n_a):
            a8 = _recover_int8(ap, lo_a, sz_a)     # (bm, bk) int8
            for lo_b, sz_b in ref.plane_groups(n_b):
                b8 = _recover_int8(bp, lo_b, sz_b)  # (bn, bk) int8
                y = jax.lax.dot_general(
                    a8, b8, _NT, preferred_element_type=jnp.int32)
                acc_ref[...] += y << (lo_a + lo_b)
    else:
        # Paper-faithful §3.2: one GEMM per bit pair; per-pair accumulators
        # live in VMEM scratch ("recovery in shared memory", §4.2).
        @pl.when(k_idx == 0)
        def _init():
            acc_ref[...] = jnp.full((n_a * n_b, bm, bn), n_pad, jnp.int32)

        for i in range(n_a):                        # §4.2 ④ loop order:
            a8 = (2 * ap[i] - 1).astype(jnp.int8)   # one A plane ...
            for j in range(n_b):                    # ... x all B planes
                b8 = (2 * bp[j] - 1).astype(jnp.int8)
                acc_ref[i * n_b + j] += jax.lax.dot_general(
                    a8, b8, _NT, preferred_element_type=jnp.int32)

    @pl.when(k_idx == n_k - 1)
    def _finish():
        if variant == "fused":
            y = acc_ref[...]
        else:
            # Shift-add recovery Y = sum_ij 2^{i+j} Y^(ij)  (paper Fig. 2).
            y = jnp.zeros((bm, bn), jnp.int32)
            for i in range(n_a):
                for j in range(n_b):
                    y = y + (acc_ref[i * n_b + j] << (i + j))
        if dequant:
            yf = y.astype(jnp.float32) * as_ref[...] * bs_ref[...]
            out_ref[...] = yf.astype(out_ref.dtype)
        else:
            out_ref[...] = y


@functools.partial(
    jax.jit,
    static_argnames=("n_a", "n_b", "k_orig", "variant", "block",
                     "out_dtype", "interpret"))
def apmm_packed(ap: jax.Array, bp: jax.Array, a_scale, b_scale, *,
                n_a: int, n_b: int, k_orig: int,
                variant: str = "fused",
                block: tuple = (DEFAULT_BM, DEFAULT_BN, DEFAULT_BK),
                out_dtype=jnp.float32,
                interpret: bool = False) -> jax.Array:
    """Packed-layout arbitrary-precision NT GEMM: ``Y = A @ B^T``.

    Args:
      ap: ``(n_a, M, Kw)`` uint32 packed A planes (pad bit 0).
      bp: ``(n_b, N, Kw)`` uint32 packed B planes (pad bit 1).
      a_scale: ``(M, 1)`` f32 per-row scales, or None (with b_scale=None)
        for a raw int32 output.
      b_scale: ``(N, 1)`` f32 per-row (output-channel) scales.
      k_orig: unpadded reduction length (pad columns are corrected in
        closed form).
      variant: "fused" | "bitserial" (see module docstring).
      block: ``(bm, bn, bk)`` tile sizes; ``bk % 32 == 0``.

    Shapes must tile exactly: ``M % bm == N % bn == (Kw*32) % bk == 0``
    (the :mod:`repro.kernels.ops` wrapper pads and unpads).
    """
    n_a_, m, kw = ap.shape
    n_b_, n, kw2 = bp.shape
    assert (n_a_, n_b_) == (n_a, n_b) and kw == kw2, (ap.shape, bp.shape)
    bm, bn, bk = block
    bm, bn = min(bm, m), min(bn, n)
    kp = kw * bipolar.PACK_WIDTH
    bk = min(bk, kp)
    if bk % bipolar.PACK_WIDTH:
        raise ValueError(f"bk={bk} must be a multiple of {bipolar.PACK_WIDTH}")
    if m % bm or n % bn or kp % bk:
        raise ValueError(f"({m},{n},{kp}) not tiled by ({bm},{bn},{bk})")
    bk32 = bk // bipolar.PACK_WIDTH
    dequant = a_scale is not None
    if dequant:
        assert b_scale is not None
        a_scale = a_scale.reshape(m, 1).astype(jnp.float32)
        b_scale = b_scale.reshape(1, n).astype(jnp.float32)
    else:
        out_dtype = jnp.int32
        # dummy 1-element scale operands keep a single kernel signature
        a_scale = jnp.ones((m, 1), jnp.float32)
        b_scale = jnp.ones((1, n), jnp.float32)

    grid = (m // bm, n // bn, kp // bk)
    acc_shape = ((bm, bn) if variant == "fused" else (n_a * n_b, bm, bn))
    kernel = functools.partial(
        _kernel, n_a=n_a, n_b=n_b, bm=bm, bn=bn, bk=bk,
        n_pad=kp - k_orig, variant=variant, dequant=dequant)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_a, bm, bk32), lambda i, j, k: (0, i, k)),
            pl.BlockSpec((n_b, bn, bk32), lambda i, j, k: (0, j, k)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM(acc_shape, jnp.int32)],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(ap, bp, a_scale, b_scale)
