"""Arbitrary-precision MatMul (APMM) Pallas TPU kernels.

Implements the paper's bit-wise MatMul reconstitution (§3.2) with the
recovery-oriented memory scheduling of §4.2, adapted from GPU shared
memory / tensor-core fragments to the TPU memory hierarchy:

* HBM holds only the §4.1 *packed* layout: both operands are row-major and
  packed along their last (reduction) axis K into uint32 words --
  ``A (n_a, M, K/32)`` for activations (tokens x features, pad bit 0) and
  ``B (n_b, N, K/32)`` for weights (d_out x d_in, pad bit 1).  The GEMM is
  "NT" (``Y = A @ B^T``), so no operand transpose ever materializes and an
  n-bit matrix costs exactly n bits/element of HBM traffic.
* Each Pallas grid cell owns one ``(bm, bn)`` output tile (the paper's
  "one SM computes all bit-pair products of one block", §4.2 ①); all
  ``n_a * n_b`` bit-plane combinations for that tile are produced from
  VMEM-resident packed tiles, so *recovery never touches HBM* (§4.2 ②).
* Pallas grid pipelining double-buffers the HBM->VMEM tile streams --
  the TPU analogue of the paper's two alternating shared-memory buffers
  (§4.2 ③).
* Two variants:

  - ``variant="bitserial"`` (paper-faithful): unpack each plane to a
    {-1,+1} int8 tile, run one MXU GEMM per (i, j) bit pair, keep
    ``n_a * n_b`` int32 accumulators in VMEM scratch, and shift-add them
    into the output after the K loop -- the literal §3.2 dataflow with the
    §4.2 ④ loop order (one A plane reused against all B planes).  On GPU
    each per-pair GEMM is a 1-bit XOR-popcount MMA; the TPU has no 1-bit
    MXU mode, so plane GEMMs execute as int8 MXU ops (DESIGN.md §2,
    "what does not transfer").

  - ``variant="fused"`` (beyond-paper, TPU-native): because the MXU's
    atomic precision is already int8, the recovery sum can be folded into
    the *operands* -- ``(sum_i 2^i A^(i)) (sum_j 2^j B^(j))^T =
    sum_ij 2^{i+j} A^(i) B^(j)T`` exactly -- turning ``n_a * n_b`` GEMMs
    into one int8 GEMM per tile (valid for bit-widths <= 7).

K padding to the 32-bit word boundary is corrected in closed form by
pre-loading the accumulator with ``n_pad * (2^{n_a}-1)(2^{n_b}-1)``, so
arbitrary K is exact.

One-kernel quantized linear (:func:`apmm_fused_linear`): the activation
operand arrives as *float* tiles plus per-row scales, and the §4.1
preprocessing (quantize to bipolar-INT, bit-decompose) runs inside the
GEMM kernel's VMEM prologue -- packed activation planes never exist in
HBM, so one ``ops.ap_linear`` costs one kernel launch instead of two and
skips the ``n_a * M * K / 8``-byte packed round trip.  A fused epilogue
(bias add, none|silu|gelu activation, residual add, and a dual-GEMM
gate/up mode that streams one A tile against two weight operands and
writes ``act(gate) * up``) keeps the whole linear in the kernel; every
epilogue stage round-trips through ``out_dtype`` exactly where the
unfused composition casts, so fused and unfused outputs are
*bit-identical* (greedy decode is token-identical by construction).

Nested-precision serving needs no kernel changes: the kernels are
width-agnostic (``n_a``/``n_b`` are static parameters and the packed
plane axis is BlockSpec'd whole), so when ``ops`` plane-prefix slices a
nested weight (``bipolar.nested_slice``) the operand physically shipped
to the kernel holds only the served ``k`` planes -- HBM weight traffic
scales with the served width, not the stored one.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import bipolar
from repro.kernels import compat, ref

# Default tile sizes: MXU-aligned (multiples of 128 on the GEMM dims) and
# sized so packed tiles + unpacked int8 tiles + the int32 accumulator fit
# v5e VMEM (~128 MiB) with double buffering:
#   packed A/B      n * 256 * (512/32) * 4 B  = n * 16 KiB each
#   unpacked int8   2 * 256 * 512             = 256 KiB
#   acc int32       256 * 256 * 4             = 256 KiB (x n_a*n_b bitserial)
DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 512

_NT = (((1,), (1,)), ((), ()))  # contract last dims (A @ B^T)


def _unpack(p, n_bits: int, r: int, bk: int):
    """(n, r, bk//32) uint32 -> (n, r, bk) int32 bit planes in {0,1}.

    Element k = 32*w + b of a row is bit b of word w: unpack the 32 bits of
    each word onto a trailing axis and merge it with the word axis.
    """
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 1, 32), 3)
    bits = (p[..., None] >> shifts) & jnp.uint32(1)        # (n, r, bk32, 32)
    return bits.reshape(n_bits, r, bk).astype(jnp.int32)


def _recover_int8(planes, lo: int, size: int):
    """{0,1} planes (n, r, k) -> recombined bipolar int8 (r, k) for the
    plane group ``[lo, lo+size)``.

    ``sum_{i in group} 2^{i-lo} (2 b_i - 1)
        = (sum b_i << (i-lo+1)) - (2^size - 1)``.
    """
    acc = planes[lo] << 1
    for i in range(lo + 1, lo + size):
        acc = acc + (planes[i] << (i - lo + 1))
    return (acc - bipolar.max_value(size)).astype(jnp.int8)


def _kernel(ap_ref, bp_ref, as_ref, bs_ref, out_ref, acc_ref, *,
            n_a: int, n_b: int, bm: int, bn: int, bk: int,
            n_pad: int, variant: str, dequant: bool):
    k_idx = pl.program_id(2)
    n_k = pl.num_programs(2)

    ap = _unpack(ap_ref[...], n_a, bm, bk)         # {0,1} int32
    bp = _unpack(bp_ref[...], n_b, bn, bk)

    if variant == "fused":
        # Operand-level recovery: one int8 MXU GEMM per <=7-bit plane-group
        # pair (a single GEMM for the common n <= 7 case).
        @pl.when(k_idx == 0)
        def _init():
            acc_ref[...] = jnp.full(
                (bm, bn),
                n_pad * bipolar.max_value(n_a) * bipolar.max_value(n_b),
                jnp.int32)

        for lo_a, sz_a in ref.plane_groups(n_a):
            a8 = _recover_int8(ap, lo_a, sz_a)     # (bm, bk) int8
            for lo_b, sz_b in ref.plane_groups(n_b):
                b8 = _recover_int8(bp, lo_b, sz_b)  # (bn, bk) int8
                y = jax.lax.dot_general(
                    a8, b8, _NT, preferred_element_type=jnp.int32)
                acc_ref[...] += y << (lo_a + lo_b)
    else:
        # Paper-faithful §3.2: one GEMM per bit pair; per-pair accumulators
        # live in VMEM scratch ("recovery in shared memory", §4.2).
        @pl.when(k_idx == 0)
        def _init():
            acc_ref[...] = jnp.full((n_a * n_b, bm, bn), n_pad, jnp.int32)

        for i in range(n_a):                        # §4.2 ④ loop order:
            a8 = (2 * ap[i] - 1).astype(jnp.int8)   # one A plane ...
            for j in range(n_b):                    # ... x all B planes
                b8 = (2 * bp[j] - 1).astype(jnp.int8)
                acc_ref[i * n_b + j] += jax.lax.dot_general(
                    a8, b8, _NT, preferred_element_type=jnp.int32)

    @pl.when(k_idx == n_k - 1)
    def _finish():
        if variant == "fused":
            y = acc_ref[...]
        else:
            # Shift-add recovery Y = sum_ij 2^{i+j} Y^(ij)  (paper Fig. 2).
            y = jnp.zeros((bm, bn), jnp.int32)
            for i in range(n_a):
                for j in range(n_b):
                    y = y + (acc_ref[i * n_b + j] << (i + j))
        if dequant:
            yf = y.astype(jnp.float32) * as_ref[...] * bs_ref[...]
            out_ref[...] = yf.astype(out_ref.dtype)
        else:
            out_ref[...] = y


# ---------------------------------------------------------------------------
# One-kernel quantized linear: quantize-pack prologue + epilogue in VMEM
# ---------------------------------------------------------------------------

def _quantize_tile(x, s, n_a: int, k_lo, k_orig: int):
    """Float tile ``(bm, bk)`` + per-row scale ``(bm, 1)`` -> unsigned
    bipolar bit field (int32) -- the §4.1 quantize + encode performed in
    VMEM (same math as :mod:`repro.kernels.pack`).  Columns at absolute
    index >= ``k_orig`` (K padding) are forced to the all-zero-bit value
    ``-maxv``, matching the activation pad-bit-0 convention of the
    closed-form pad correction."""
    q = bipolar.quantize_values(x.astype(jnp.float32), n_a, s)
    col = k_lo + jax.lax.broadcasted_iota(jnp.int32, q.shape, 1)
    q = jnp.where(col < k_orig, q, -bipolar.max_value(n_a))
    return bipolar.encode(q, n_a)


_apply_act = ref.apply_act


def _fused_linear_kernel(*refs, n_a: int, n_b: int, bm: int, bn: int,
                         bk: int, k_orig: int, n_pad: int, variant: str,
                         act: str, dual: bool, has_bias: bool,
                         has_res: bool):
    it = iter(refs)
    x_ref, as_ref = next(it), next(it)
    bp_ref, bs_ref = next(it), next(it)
    bp2_ref = next(it) if dual else None
    b2s_ref = next(it) if dual else None
    bias_ref = next(it) if has_bias else None
    res_ref = next(it) if has_res else None
    out_ref = next(it)
    accs = list(it)                       # 1 or 2 scratch accumulators

    k_idx = pl.program_id(2)
    n_k = pl.num_programs(2)

    # -- prologue: quantize + bit-decompose the float A tile in VMEM -----
    # x_ref holds the whole-K row block (index map depends only on i), so
    # the float activations stream from HBM ONCE per M tile -- not once
    # per (j, k) grid cell -- and the quantize recompute is VPU-only
    xk = x_ref[:, pl.dslice(k_idx * bk, bk)]
    ua = _quantize_tile(xk, as_ref[...], n_a, k_idx * bk, k_orig)

    @pl.when(k_idx == 0)
    def _init():
        if variant == "fused":
            init = jnp.full(
                (bm, bn),
                n_pad * bipolar.max_value(n_a) * bipolar.max_value(n_b),
                jnp.int32)
        else:
            init = jnp.full((n_a * n_b, bm, bn), n_pad, jnp.int32)
        for aref in accs:
            aref[...] = init

    streams = [(bp_ref, accs[0])] + ([(bp2_ref, accs[1])] if dual else [])
    for bref, aref in streams:
        bpl = _unpack(bref[...], n_b, bn, bk)
        if variant == "fused":
            for lo_a, sz_a in ref.plane_groups(n_a):
                mask = (1 << sz_a) - 1
                va = ((((ua >> lo_a) & mask) << 1)
                      - bipolar.max_value(sz_a)).astype(jnp.int8)
                for lo_b, sz_b in ref.plane_groups(n_b):
                    b8 = _recover_int8(bpl, lo_b, sz_b)
                    y = jax.lax.dot_general(
                        va, b8, _NT, preferred_element_type=jnp.int32)
                    aref[...] += y << (lo_a + lo_b)
        else:
            for i in range(n_a):
                a8 = (((ua >> i) & 1) * 2 - 1).astype(jnp.int8)
                for j in range(n_b):
                    b8 = (2 * bpl[j] - 1).astype(jnp.int8)
                    aref[i * n_b + j] += jax.lax.dot_general(
                        a8, b8, _NT, preferred_element_type=jnp.int32)

    @pl.when(k_idx == n_k - 1)
    def _finish():
        od = out_ref.dtype

        def recover_acc(aref):
            if variant == "fused":
                return aref[...]
            y = jnp.zeros((bm, bn), jnp.int32)
            for i in range(n_a):
                for j in range(n_b):
                    y = y + (aref[i * n_b + j] << (i + j))
            return y

        # epilogue stages round-trip through out_dtype exactly where the
        # unfused composition casts, so fused == unfused bitwise
        yf = recover_acc(accs[0]).astype(jnp.float32) \
            * as_ref[...] * bs_ref[...]
        if has_bias:
            yf = yf + bias_ref[...]
        yo = yf.astype(od)
        if dual:
            y2 = recover_acc(accs[1]).astype(jnp.float32) \
                * as_ref[...] * b2s_ref[...]
            h = _apply_act(yo.astype(jnp.float32), act) \
                * y2.astype(od).astype(jnp.float32)
            yo = h.astype(od)
        elif act != "none":
            yo = _apply_act(yo.astype(jnp.float32), act).astype(od)
        if has_res:
            yo = yo + res_ref[...]
        out_ref[...] = yo


@functools.partial(
    jax.jit,
    static_argnames=("n_a", "n_b", "k_orig", "variant", "act", "block",
                     "out_dtype", "interpret"))
def apmm_fused_linear(x: jax.Array, a_scale: jax.Array, bp: jax.Array,
                      b_scale, *, bp2=None, b2_scale=None, bias=None,
                      residual=None, n_a: int, n_b: int, k_orig: int,
                      variant: str = "fused", act: str = "none",
                      block: tuple = (DEFAULT_BM, DEFAULT_BN, DEFAULT_BK),
                      out_dtype=jnp.float32,
                      interpret: bool = False) -> jax.Array:
    """One-kernel quantized linear ``Y = epilogue(Q(X) @ B^T)``.

    Args:
      x: ``(M, Kp)`` float activations (K already padded to the packed
        word width; pad columns are masked in-kernel).
      a_scale: ``(M, 1)`` f32 per-row activation scales.
      bp: ``(n_b, N, Kw)`` uint32 packed weight planes (pad bit 1).
      b_scale: ``(N, 1)`` f32 per-output-channel weight scales.
      bp2/b2_scale: optional second weight operand (dual-GEMM gate/up
        mode): the quantized A tile streams against both weights and the
        epilogue writes ``act(Y1) * Y2`` (SwiGLU: Y1 = gate, Y2 = up).
      bias: optional ``(N,)``-broadcastable f32 bias, added to Y1 before
        the out-dtype cast.
      residual: optional ``(M, N)`` tensor (out_dtype) added after the
        activation, in out_dtype arithmetic.
      act: "none" | "silu" | "gelu" epilogue activation.
      k_orig: unpadded reduction length.

    Shapes must tile exactly (:mod:`repro.kernels.ops` pads and unpads).
    """
    m, kp = x.shape
    n_b_, n, kw = bp.shape
    assert n_b_ == n_b and kp == kw * bipolar.PACK_WIDTH, (x.shape, bp.shape)
    bm, bn, bk = block
    bm, bn = min(bm, m), min(bn, n)
    bk = min(bk, kp)
    if bk % bipolar.PACK_WIDTH:
        raise ValueError(f"bk={bk} must be a multiple of {bipolar.PACK_WIDTH}")
    if m % bm or n % bn or kp % bk:
        raise ValueError(f"({m},{n},{kp}) not tiled by ({bm},{bn},{bk})")
    bk32 = bk // bipolar.PACK_WIDTH
    dual = bp2 is not None
    if dual:
        assert b2_scale is not None and bp2.shape == bp.shape, \
            (bp.shape, None if bp2 is None else bp2.shape)
    a_scale = a_scale.reshape(m, 1).astype(jnp.float32)
    b_scale = b_scale.reshape(1, n).astype(jnp.float32)

    operands = [x, a_scale, bp, b_scale]
    in_specs = [
        # whole-K row block, re-fetched only when i changes: activations
        # cost M*K*itemsize of HBM traffic total, independent of N/bn
        pl.BlockSpec((bm, kp), lambda i, j, k: (i, 0)),
        pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
        pl.BlockSpec((n_b, bn, bk32), lambda i, j, k: (0, j, k)),
        pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
    ]
    if dual:
        operands += [bp2, b2_scale.reshape(1, n).astype(jnp.float32)]
        in_specs += [
            pl.BlockSpec((n_b, bn, bk32), lambda i, j, k: (0, j, k)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ]
    if bias is not None:
        operands.append(bias.reshape(1, n).astype(jnp.float32))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
    if residual is not None:
        operands.append(residual.reshape(m, n).astype(out_dtype))
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)))

    acc_shape = ((bm, bn) if variant == "fused" else (n_a * n_b, bm, bn))
    scratch = [pltpu.VMEM(acc_shape, jnp.int32) for _ in range(1 + dual)]
    kernel = functools.partial(
        _fused_linear_kernel, n_a=n_a, n_b=n_b, bm=bm, bn=bn, bk=bk,
        k_orig=k_orig, n_pad=kp - k_orig, variant=variant, act=act,
        dual=dual, has_bias=bias is not None, has_res=residual is not None)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, kp // bk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=scratch,
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)


@functools.partial(
    jax.jit,
    static_argnames=("n_a", "n_b", "k_orig", "variant", "block",
                     "out_dtype", "interpret"))
def apmm_packed(ap: jax.Array, bp: jax.Array, a_scale, b_scale, *,
                n_a: int, n_b: int, k_orig: int,
                variant: str = "fused",
                block: tuple = (DEFAULT_BM, DEFAULT_BN, DEFAULT_BK),
                out_dtype=jnp.float32,
                interpret: bool = False) -> jax.Array:
    """Packed-layout arbitrary-precision NT GEMM: ``Y = A @ B^T``.

    Args:
      ap: ``(n_a, M, Kw)`` uint32 packed A planes (pad bit 0).
      bp: ``(n_b, N, Kw)`` uint32 packed B planes (pad bit 1).
      a_scale: ``(M, 1)`` f32 per-row scales, or None (with b_scale=None)
        for a raw int32 output.
      b_scale: ``(N, 1)`` f32 per-row (output-channel) scales.
      k_orig: unpadded reduction length (pad columns are corrected in
        closed form).
      variant: "fused" | "bitserial" (see module docstring).
      block: ``(bm, bn, bk)`` tile sizes; ``bk % 32 == 0``.

    Shapes must tile exactly: ``M % bm == N % bn == (Kw*32) % bk == 0``
    (the :mod:`repro.kernels.ops` wrapper pads and unpads).
    """
    n_a_, m, kw = ap.shape
    n_b_, n, kw2 = bp.shape
    assert (n_a_, n_b_) == (n_a, n_b) and kw == kw2, (ap.shape, bp.shape)
    bm, bn, bk = block
    bm, bn = min(bm, m), min(bn, n)
    kp = kw * bipolar.PACK_WIDTH
    bk = min(bk, kp)
    if bk % bipolar.PACK_WIDTH:
        raise ValueError(f"bk={bk} must be a multiple of {bipolar.PACK_WIDTH}")
    if m % bm or n % bn or kp % bk:
        raise ValueError(f"({m},{n},{kp}) not tiled by ({bm},{bn},{bk})")
    bk32 = bk // bipolar.PACK_WIDTH
    dequant = a_scale is not None
    if dequant:
        assert b_scale is not None
        a_scale = a_scale.reshape(m, 1).astype(jnp.float32)
        b_scale = b_scale.reshape(1, n).astype(jnp.float32)
    else:
        out_dtype = jnp.int32
        # dummy 1-element scale operands keep a single kernel signature
        a_scale = jnp.ones((m, 1), jnp.float32)
        b_scale = jnp.ones((1, n), jnp.float32)

    grid = (m // bm, n // bn, kp // bk)
    acc_shape = ((bm, bn) if variant == "fused" else (n_a * n_b, bm, bn))
    kernel = functools.partial(
        _kernel, n_a=n_a, n_b=n_b, bm=bm, bn=bn, bk=bk,
        n_pad=kp - k_orig, variant=variant, dequant=dequant)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_a, bm, bk32), lambda i, j, k: (0, i, k)),
            pl.BlockSpec((n_b, bn, bk32), lambda i, j, k: (0, j, k)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM(acc_shape, jnp.int32)],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(ap, bp, a_scale, b_scale)
