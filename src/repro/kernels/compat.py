"""Compatibility shims over JAX / Pallas-TPU API drift.

The Pallas TPU surface has been renamed across JAX releases and the
kernels in this package must run on whichever release the container
ships.  Every drift we paper over is centralized here so kernel files
stay drift-free:

* **Compiler-params class** -- ``pltpu.CompilerParams`` (new name) vs
  ``pltpu.TPUCompilerParams`` (<= 0.4.x).  Use :func:`compiler_params`.
* **Dimension semantics** -- the ``dimension_semantics=("parallel", ...,
  "arbitrary")`` tuple is accepted as a constructor field on both
  classes today, but releases have moved it between ``pallas_call`` and
  the params object; :func:`compiler_params` retries without the field
  (losing only a scheduling hint, never correctness) if the installed
  class rejects it.
* **shard_map location / kwarg** -- ``jax.shard_map`` (new) vs
  ``jax.experimental.shard_map.shard_map`` (old), and the replication
  check kwarg renamed ``check_rep`` -> ``check_vma``.  Use
  :func:`shard_map`.

Dispatch contract (see :mod:`repro.kernels.ops`): every kernel built on
these shims runs identically under ``impl="pallas"`` (Mosaic, TPU),
``impl="interpret"`` (kernel body in Python on CPU) and has a pure-jnp
``impl="reference"`` twin operating on the same buffers.
"""

from __future__ import annotations

import jax
from jax.experimental.pallas import tpu as pltpu

# new name first: releases that have both alias one to the other
_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams", None)


def compiler_params(dimension_semantics=None, **kwargs):
    """Build the TPU compiler-params object for the installed JAX.

    ``dimension_semantics``: tuple of "parallel"/"arbitrary" per grid dim
    (a Mosaic scheduling hint).  Dropped silently when the installed
    params class does not accept it -- the kernels only ever use it as a
    hint; correctness never depends on it.
    """
    if _PARAMS_CLS is None:                      # pragma: no cover
        return None
    if dimension_semantics is not None:
        try:
            return _PARAMS_CLS(dimension_semantics=tuple(dimension_semantics),
                               **kwargs)
        except TypeError:
            pass
    return _PARAMS_CLS(**kwargs)


def _resolve_shard_map():
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn
    from jax.experimental.shard_map import shard_map as fn  # <= 0.4.x
    return fn


_SHARD_MAP = _resolve_shard_map()


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` under either import location / kwarg spelling."""
    try:
        return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=check_vma)
    except TypeError:
        return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)
