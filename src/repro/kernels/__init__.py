"""APMM kernel layer: Pallas TPU kernels + jnp oracles + dispatch.

The paper's compute hot-spot is the arbitrary-precision GEMM (§3.2 + §4.2)
and the §4.1 quantize/pack preprocessing -- both have Pallas kernels here.
"""
from repro.kernels import apmm, ops, pack, ref  # noqa: F401
