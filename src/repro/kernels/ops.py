"""Dispatch layer for the APMM kernels.

Every quantized op runs under one of three interchangeable implementations:

* ``"pallas"``    -- the real Pallas TPU kernels (Mosaic), for TPU targets.
* ``"interpret"`` -- the same Pallas kernels under ``interpret=True``
  (kernel body executed in Python on CPU) -- used by the correctness suite.
* ``"reference"`` -- pure-jnp dataflow (:mod:`repro.kernels.ref`) operating
  on the *same packed buffers*; used inside jitted model graphs on CPU and
  in the 512-device dry-run, where a Mosaic kernel cannot lower.

The default comes from ``$REPRO_KERNEL_IMPL`` or the JAX backend
(``pallas`` on TPU, ``reference`` elsewhere).

Dispatch contract: all three impls consume the *same packed buffers* and
compute the same function -- bit-exactly for the integer GEMM cores,
to float tolerance for dequantizing ops (kv attention) -- enforced by
tests/kernels/test_parity.py.  Ops covered:

* ``quantize_rows`` / ``pack_weight`` -- §4.1 quantize + bit-plane pack;
* ``ap_matmul`` -- packed-x-packed NT GEMM (operands packed to different
  K word-widths are padded to the common width, pad bit 0/1);
* ``ap_linear`` -- unfused quantized linear: a standalone quantize-pack
  launch writes the activation planes to HBM, then ``ap_matmul`` reads
  them back (kept as the fused path's bit-exactness oracle/baseline);
* ``ap_linear_fused`` -- ONE-kernel quantized linear: activation
  quantize + decompose run in the GEMM kernel's VMEM prologue (packed
  activation planes never exist in HBM) and a fused epilogue applies
  ``bias``, ``act in {none, silu, gelu}``, an optional residual add and
  a dual-GEMM gate/up mode (``w2``: SwiGLU's two projections share one
  A-tile stream, ``act(x@w1^T) * (x@w2^T)``).  Bit-identical to the
  composed unfused pipeline (tests/kernels/test_fused_linear.py);
* ``ap_moe_expert_linear`` -- grouped MoE expert linear ``(E, C, K) x
  (E, N, K) -> (E, C, N)``: ONE launch for all experts over an
  ``(expert*group, row-tile, col-tile, k-tile)`` grid, per-(expert,
  group) live-row counts riding scalar prefetch, ``pl.when`` skipping
  row tiles whose capacity slots hold no routed token, and the fused
  quantize prologue / dequant epilogue of ``ap_linear_fused`` batched
  per expert (dual gate/up mode included).  Live rows are bit-identical
  to ``layers._expert_matmul``; dead capacity rows are exact zeros.
  ``with_stats=True`` additionally returns the kernel's live-tile map
  (the interpret-mode skip proof and the BENCH_moe skipped-tile
  fraction);
* the bipolar KV-cache path ``quantize_kv`` / ``dequantize_kv`` /
  ``kv_cache_attention`` (dequant-on-read flash attention) /
  ``paged_kv_cache_attention`` (same, reading K/V through a serving
  block table; with ``window`` set the kernel masks by absolute
  position and skips blocks no query may see -- null-padded table
  entries and fully-out-of-window blocks -- matching the scheduler's
  rolling-table out-of-window reclaim.
  tests/kernels/test_paged_attention.py covers the window boundaries).
"""

from __future__ import annotations

import dataclasses
import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bipolar
from repro.core.bipolar import BipolarTensor
from repro.kernels import apmm as apmm_kernel
from repro.kernels import flash_attention as flash_kernel
from repro.kernels import moe as moe_kernel
from repro.kernels import pack as pack_kernel
from repro.kernels import ref

_IMPLS = ("pallas", "interpret", "reference")
_impl_override = None


def default_impl() -> str:
    if _impl_override is not None:
        return _impl_override
    env = os.environ.get("REPRO_KERNEL_IMPL")
    if env:
        assert env in _IMPLS, env
        return env
    return "pallas" if jax.default_backend() == "tpu" else "reference"


def set_impl(impl) -> None:
    """Override the global kernel implementation (None = auto)."""
    global _impl_override
    assert impl is None or impl in _IMPLS, impl
    _impl_override = impl


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _pad_dim(arr: jax.Array, axis: int, target: int, value=0) -> jax.Array:
    pad = target - arr.shape[axis]
    if pad <= 0:
        return arr
    cfg = [(0, 0)] * arr.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(arr, cfg, constant_values=np.asarray(value, arr.dtype))


# ---------------------------------------------------------------------------
# Quantize + pack
# ---------------------------------------------------------------------------

def quantize_rows(x: jax.Array, n_bits: int, *, pad_bit: int,
                  impl: str | None = None,
                  scale: jax.Array | None = None,
                  scale_search: bool = False) -> BipolarTensor:
    """Quantize a row-major ``(R, K)`` matrix to packed bipolar planes.

    Per-row absmax scales (``scale_search=True``: per-row MSE clip search,
    :func:`bipolar.mse_scale` -- weight preprocessing only); K padded to
    the 32-bit word boundary with the given pad bit (0 for
    activations/LHS, 1 for weights/RHS).

    ``scale_search=True`` additionally fits the per-width nested scales
    (:func:`bipolar.nested_width_scales`) so every plane prefix of the
    result is directly servable via :func:`bipolar.nested_slice` -- the
    any-precision checkpoint contract (offline cost only, like the clip
    search itself).
    """
    impl = impl or default_impl()
    r, k = x.shape
    if scale is None and scale_search:
        scale = bipolar.mse_scale(x, n_bits, axis=-1)
    if scale is None:
        scale = bipolar.absmax_scale(x, n_bits, axis=-1, keepdims=True)
    scale = scale.astype(jnp.float32).reshape(r, 1)
    width_scales = None
    if scale_search and n_bits > 1:
        qv = bipolar.quantize_values(x, n_bits, scale)
        width_scales = bipolar.nested_width_scales(x, qv, n_bits, scale)
    if impl == "reference":
        q = bipolar.quantize_values(x, n_bits, scale)
        planes = bipolar.decompose(q, n_bits)
        planes = bipolar.pad_for_packing(planes, -1, pad_bit)
        packed = bipolar.pack_planes(planes, -1)
    else:
        kp = _round_up(k, bipolar.PACK_WIDTH)
        maxv = bipolar.max_value(n_bits)
        pad_val = scale * (maxv if pad_bit else -maxv)   # all-1/all-0 bits
        xp = _pad_dim(x.astype(jnp.float32), 1, kp)
        if kp > k:
            xp = xp.at[:, k:].set(jnp.broadcast_to(pad_val, (r, kp - k)))
        # row tiling: pad rows to the block multiple, slice planes after
        br = min(pack_kernel.DEFAULT_BR, _round_up(r, 8))
        rp = _round_up(r, br)
        xp = _pad_dim(xp, 0, rp, 1.0)
        sp = _pad_dim(scale, 0, rp, 1.0)
        bk = next(b for b in (1024, 512, 256, 128, 64, 32) if kp % b == 0)
        packed = pack_kernel.quantize_pack_rows(
            xp, sp, n_bits=n_bits, block=(br, bk),
            interpret=(impl == "interpret"))[:, :r, :]
    return BipolarTensor(packed=packed, scale=scale, n_bits=n_bits,
                         shape=(r, k), pack_axis=1,
                         width_scales=width_scales)


# ---------------------------------------------------------------------------
# Arbitrary-precision GEMM
# ---------------------------------------------------------------------------

def _normalize_packed_kw(a: BipolarTensor,
                         b: BipolarTensor) -> tuple:
    """Pad operands packed to different K word-widths to the common one.

    Both describe the same logical K; a weight preprocessed offline may
    carry extra alignment words.  A pads with all-zero bits (-1s), B
    with all-one bits (+1s) -- the pad conventions the closed-form
    K-pad correction already accounts for, so the product is unchanged.
    """
    assert a.shape[-1] == b.shape[-1], \
        f"reduction dims differ: {a.shape} vs {b.shape}"
    kw = max(a.packed.shape[-1], b.packed.shape[-1])
    if a.packed.shape[-1] < kw:
        a = dataclasses.replace(
            a, packed=_pad_dim(a.packed, a.packed.ndim - 1, kw, 0))
    if b.packed.shape[-1] < kw:
        b = dataclasses.replace(
            b, packed=_pad_dim(b.packed, b.packed.ndim - 1, kw, 0xFFFFFFFF))
    return a, b


def ap_matmul(a: BipolarTensor, b: BipolarTensor, *,
              variant: str = "fused", impl: str | None = None,
              out_dtype=jnp.float32, raw: bool = False,
              b_bits: int | None = None) -> jax.Array:
    """NT GEMM of packed tensors: ``Y (M,N) = A (M,K) @ B (N,K)^T``.

    ``raw=True`` returns the exact int32 product of the bipolar integer
    values (no scale dequant).  ``b_bits`` serves a nested B operand at
    a lower width: only the leading ``b_bits`` plane rows of the packed
    buffer are shipped to the kernel (:func:`bipolar.nested_slice` --
    HBM weight traffic scales with the served width, and the reference
    impl slices the same buffers inside the jitted graph).
    """
    impl = impl or default_impl()
    if b_bits is not None:
        b = bipolar.nested_slice(b, b_bits)
    a, b = _normalize_packed_kw(a, b)
    if impl == "reference":
        if raw:
            return ref.apmm_packed_ref(a, b, fused=(variant == "fused"))
        return ref.apmm_dequant_ref(a, b, fused=(variant == "fused"),
                                    out_dtype=out_dtype)
    (m, k), (n, _) = a.shape, b.shape
    ap, bp = a.packed, b.packed
    kw = ap.shape[-1]
    # --- pad to tile multiples ------------------------------------------
    bm = min(apmm_kernel.DEFAULT_BM, _round_up(m, 8))
    bn = min(apmm_kernel.DEFAULT_BN, _round_up(n, 128))
    mp, np_ = _round_up(m, bm), _round_up(n, bn)
    kp0 = kw * bipolar.PACK_WIDTH
    bk = min(apmm_kernel.DEFAULT_BK, _round_up(kp0, 32))
    kp = _round_up(kp0, bk)
    ap = _pad_dim(_pad_dim(ap, 1, mp), 2, kp // 32, 0x00000000)  # A pads: bit 0
    bp = _pad_dim(_pad_dim(bp, 1, np_), 2, kp // 32, 0xFFFFFFFF)  # B pads: bit 1
    a_scale = None if raw else _pad_dim(a.scale.reshape(m, 1), 0, mp, 1.0)
    b_scale = None if raw else _pad_dim(b.scale.reshape(n, 1), 0, np_, 1.0)
    y = apmm_kernel.apmm_packed(
        ap, bp, a_scale, b_scale, n_a=a.n_bits, n_b=b.n_bits, k_orig=k,
        variant=variant, block=(bm, bn, bk), out_dtype=out_dtype,
        interpret=(impl == "interpret"))
    return y[:m, :n]


def ap_linear(x: jax.Array, w: BipolarTensor, *, a_bits: int,
              variant: str = "fused", impl: str | None = None,
              out_dtype=None, w_bits: int | None = None) -> jax.Array:
    """Quantized linear: ``y (..., N) = x (..., K) @ W (N, K)^T``.

    Activations are quantized on the fly (per-token absmax, the paper's
    runtime preprocessing path); weights arrive pre-packed.  ``w_bits``
    serves a nested weight at a lower width (plane-prefix slice, see
    :func:`ap_matmul`).
    """
    impl = impl or default_impl()
    out_dtype = out_dtype or x.dtype
    lead = x.shape[:-1]
    k = x.shape[-1]
    xq = quantize_rows(x.reshape(-1, k), a_bits, pad_bit=0, impl=impl)
    y = ap_matmul(xq, w, variant=variant, impl=impl, out_dtype=out_dtype,
                  b_bits=w_bits)
    return y.reshape(*lead, w.shape[0])


def ap_linear_fused(x: jax.Array, w: BipolarTensor, *, a_bits: int,
                    w2: BipolarTensor | None = None,
                    bias: jax.Array | None = None,
                    act: str = "none",
                    residual: jax.Array | None = None,
                    variant: str = "fused", impl: str | None = None,
                    out_dtype=None, w_bits: int | None = None) -> jax.Array:
    """One-kernel quantized linear with a fused epilogue (paper §4.2
    taken to its conclusion: preprocessing AND recovery in fast memory).

    ``y (..., N) = epi(x (..., K) @ W (N, K)^T)`` where the epilogue is
    ``act(y + bias) [* (x @ W2^T) if w2] [+ residual]``:

    * activation quantize + bit-decompose run inside the GEMM kernel's
      VMEM prologue -- packed activation planes never round-trip HBM and
      one linear is ONE kernel launch instead of two;
    * ``w2`` (dual-GEMM gate/up mode) streams the quantized A tile
      against a second weight and the epilogue computes
      ``act(y1) * y2`` -- SwiGLU's two projections share one A stream;
    * ``bias`` adds in f32 before the out-dtype cast; ``act`` and the
      out-dtype cast points mirror the unfused composition exactly, and
      ``residual`` adds in out_dtype -- so the fused path is
      *bit-identical* to ``ap_linear`` + jnp epilogue (greedy decode is
      token-identical by construction).

    Dispatch: pallas | interpret run
    :func:`repro.kernels.apmm.apmm_fused_linear`; reference runs
    :func:`repro.kernels.ref.ap_linear_fused_ref` (quantize to values,
    integer GEMM, same epilogue -- no packed activation buffer in the
    graph at all).

    ``w_bits`` serves nested weights at a lower width: both GEMM
    operands (``w`` and ``w2``) are plane-prefix sliced up front
    (:func:`bipolar.nested_slice`), so the pallas/interpret kernel
    physically streams only ``w_bits`` planes from HBM and the
    reference impl slices the same packed buffers in-graph.
    """
    impl = impl or default_impl()
    out_dtype = out_dtype or x.dtype
    if w_bits is not None:
        w = bipolar.nested_slice(w, w_bits)
        if w2 is not None:
            w2 = bipolar.nested_slice(w2, w_bits)
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = w.shape[0]
    assert w.shape[-1] == k, (x.shape, w.shape)
    if w2 is not None:
        assert w2.shape == w.shape and w2.n_bits == w.n_bits, \
            (w.shape, w2.shape)
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    res2 = residual.reshape(m, n) if residual is not None else None
    # scale computed exactly as quantize_rows does (absmax in the INPUT
    # dtype, then cast) -- a f32-side absmax would differ in the last
    # bit for bf16 activations and break fused==unfused bit-identity
    scale = bipolar.absmax_scale(x2, a_bits, axis=-1, keepdims=True)
    scale = scale.astype(jnp.float32)
    if impl == "reference":
        # the residual adds AFTER the reshape, at the exact graph
        # position the unfused model-level add occupies: XLA-CPU elides
        # bf16 rounding differently across fusion boundaries, so a
        # structurally different add site can flip near-tie argmax even
        # though the arithmetic is identical (the pallas/interpret
        # kernels add in-kernel, where the rounding is explicit)
        y = ref.ap_linear_fused_ref(
            x2, scale, w, w2=w2, bias=bias, residual=None, a_bits=a_bits,
            variant=variant, act=act, out_dtype=out_dtype)
        y = y.reshape(*lead, n)
        if residual is not None:
            y = y + residual.astype(out_dtype)
        return y
    # --- pad to tile multiples (kernel masks the K pad in-prologue) -----
    wp = w.packed
    w2p = w2.packed if w2 is not None else None
    kw = max(bipolar.packed_words(k), wp.shape[-1],
             w2p.shape[-1] if w2p is not None else 0)
    wp = _pad_dim(wp, 2, kw, 0xFFFFFFFF)
    if w2p is not None:
        w2p = _pad_dim(w2p, 2, kw, 0xFFFFFFFF)
    bm = min(apmm_kernel.DEFAULT_BM, _round_up(m, 8))
    bn = min(apmm_kernel.DEFAULT_BN, _round_up(n, 128))
    mp, np_ = _round_up(m, bm), _round_up(n, bn)
    kp0 = kw * bipolar.PACK_WIDTH
    bk = min(apmm_kernel.DEFAULT_BK, _round_up(kp0, 32))
    kp = _round_up(kp0, bk)
    xp = _pad_dim(_pad_dim(x2, 1, kp), 0, mp)
    sp = _pad_dim(scale, 0, mp, 1.0)
    wp = _pad_dim(_pad_dim(wp, 1, np_), 2, kp // 32, 0xFFFFFFFF)
    ws = _pad_dim(w.scale.reshape(n, 1), 0, np_, 1.0)
    kw_args: dict = {}
    if w2p is not None:
        kw_args["bp2"] = _pad_dim(_pad_dim(w2p, 1, np_), 2, kp // 32,
                                  0xFFFFFFFF)
        kw_args["b2_scale"] = _pad_dim(w2.scale.reshape(n, 1), 0, np_, 1.0)
    if bias is not None:
        kw_args["bias"] = _pad_dim(
            bias.reshape(n, 1).astype(jnp.float32), 0, np_)
    if res2 is not None:
        kw_args["residual"] = _pad_dim(
            _pad_dim(res2.astype(out_dtype), 1, np_), 0, mp)
    y = apmm_kernel.apmm_fused_linear(
        xp, sp, wp, ws, n_a=a_bits, n_b=w.n_bits, k_orig=k,
        variant=variant, act=act, block=(bm, bn, bk), out_dtype=out_dtype,
        interpret=(impl == "interpret"), **kw_args)
    return y[:m, :n].reshape(*lead, n)


def ap_moe_expert_linear(x: jax.Array, w: BipolarTensor, *,
                         counts: jax.Array, a_bits: int,
                         w2: BipolarTensor | None = None,
                         act: str = "none", variant: str = "fused",
                         impl: str | None = None, out_dtype=None,
                         with_stats: bool = False,
                         w_bits: int | None = None):
    """Grouped quantized MoE expert linear (one launch for all experts).

    ``y (E, C, N) = epi(Q(x) (E, C, K) @ W (E, N, K)^T)`` where ``C =
    G * seg`` capacity rows per expert hold ``G`` dispatch-group
    segments whose live tokens form a prefix of length ``counts[e, g]``
    (``counts (E, G)`` int32, the one-hot-cumsum keep counts of
    ``moe_apply``'s capacity dispatch).  Per segment:

    * rows ``< counts[e, g]`` are **bit-identical** to the legacy
      batched ``layers._expert_matmul`` path -- activations are
      quantized per row in f32 from the materialized input (the
      single-rounding chain of ``_expert_quantize``) and the epilogue
      composes in f32 with one cast at the output write, matching the
      legacy composition's cast point.  The op pins its operand and
      result materialization (``lax.optimization_barrier`` on the
      reference dataflow; the pallas call boundary pins physically), so
      the bit pattern cannot drift with the surrounding jit graph;
    * rows ``>= counts[e, g]`` are **exact zeros** in every impl (the
      legacy path leaves tiny eps-scale values in dead capacity rows;
      the combine gather reads neither, so rewiring is token-identical).

    ``w2`` enables the dual gate/up mode: one quantized A-tile stream
    against both expert weights, ``act(Y1) * Y2`` fused before the
    output write (SwiGLU: w = gate, w2 = up -- the convention of
    ``mlp_apply``).  The pallas/interpret impls run
    :func:`repro.kernels.moe.moe_expert_linear`: counts ride scalar
    prefetch and ``pl.when`` skips the quantize prologue and every MXU
    pass of row tiles holding no live token.  ``with_stats=True``
    additionally returns the ``(E*G, n_row_tiles)`` int32 live-tile map
    (kernel-reported for pallas/interpret, analytic for reference --
    the interpret parity test asserting they agree is the skip-path
    proof).

    ``w_bits`` serves nested expert weights at a lower width (leading
    plane-prefix slice of the ``(n_bits, E, N, Kw)`` packed buffers,
    see :func:`ap_linear_fused`).
    """
    impl = impl or default_impl()
    out_dtype = out_dtype or x.dtype
    if w_bits is not None:
        w = bipolar.nested_slice(w, w_bits)
        if w2 is not None:
            w2 = bipolar.nested_slice(w2, w_bits)
    e, c, k = x.shape
    g = counts.shape[1]
    assert c % g == 0, (c, g)
    seg = c // g
    n = w.shape[1]
    assert w.shape == (e, n, k), (x.shape, w.shape)
    if w2 is not None:
        assert w2.shape == w.shape and w2.n_bits == w.n_bits, \
            (w.shape, w2.shape)
    counts = counts.astype(jnp.int32)
    # pin the operand materialization: the kernel reads x from HBM in
    # its storage dtype, so the reference dataflow must quantize the
    # SAME rounded values -- the barrier stops XLA from feeding it the
    # pre-cast excess-precision f32 upstream value instead
    x = jax.lax.optimization_barrier(x)
    # per-row absmax scale in f32 -- exactly _expert_quantize's chain
    a_scale = bipolar.absmax_scale(x.astype(jnp.float32), a_bits,
                                   axis=-1, keepdims=True)
    # tile geometry shared by all impls so the live map is comparable
    bc = min(apmm_kernel.DEFAULT_BM, _round_up(seg, 8))
    n_ci = _round_up(seg, bc) // bc
    if impl == "reference":
        # result barrier = the kernel's HBM write: downstream consumers
        # (the next GEMM's quantizer, the combine) see materialized
        # out_dtype bits, never the fused f32 intermediate
        y = jax.lax.optimization_barrier(ref.ap_moe_expert_linear_ref(
            x, a_scale, counts, w, w2=w2, a_bits=a_bits, variant=variant,
            act=act, out_dtype=out_dtype))
        if with_stats:
            live = (counts.reshape(e * g, 1)
                    > jnp.arange(n_ci, dtype=jnp.int32)[None, :] * bc)
            return y, live.astype(jnp.int32)
        return y
    # --- pad to tile multiples (kernel masks the K pad in-prologue) -----
    wp = w.packed
    w2p = w2.packed if w2 is not None else None
    kw = max(bipolar.packed_words(k), wp.shape[-1],
             w2p.shape[-1] if w2p is not None else 0)
    bn = min(apmm_kernel.DEFAULT_BN, _round_up(n, 128))
    np_ = _round_up(n, bn)
    kp0 = kw * bipolar.PACK_WIDTH
    bk = min(apmm_kernel.DEFAULT_BK, _round_up(kp0, 32))
    kp = _round_up(kp0, bk)
    cp = n_ci * bc
    xg = _pad_dim(_pad_dim(x.reshape(e * g, seg, k), 2, kp), 1, cp)
    sg = _pad_dim(a_scale.reshape(e * g, seg, 1), 1, cp, 1.0)
    wp = _pad_dim(_pad_dim(wp, 2, np_), 3, kp // 32, 0xFFFFFFFF)
    ws = _pad_dim(w.scale.reshape(e, 1, n).astype(jnp.float32), 2, np_, 1.0)
    kw_args: dict = {}
    if w2p is not None:
        kw_args["wp2"] = _pad_dim(_pad_dim(w2p, 2, np_), 3, kp // 32,
                                  0xFFFFFFFF)
        kw_args["w2_scale"] = _pad_dim(
            w2.scale.reshape(e, 1, n).astype(jnp.float32), 2, np_, 1.0)
    y, live = moe_kernel.moe_expert_linear(
        xg, sg, counts.reshape(e * g), wp, ws,
        n_a=a_bits, n_b=w.n_bits, k_orig=k, n_groups=g, variant=variant,
        act=act, block=(bc, bn, bk), out_dtype=out_dtype,
        interpret=(impl == "interpret"), **kw_args)
    y = y[:, :seg, :n].reshape(e, c, n)
    return (y, live) if with_stats else y


def pack_weight(w: jax.Array, n_bits: int, *,
                impl: str | None = None) -> BipolarTensor:
    """Offline weight preprocessing (§4.1): ``W (d_out, d_in)`` -> packed,
    with the per-row MSE clip search (cheap: happens once at load)."""
    return quantize_rows(w, n_bits, pad_bit=1, impl=impl, scale_search=True)


# ---------------------------------------------------------------------------
# Bipolar-quantized KV cache (pack on write, dequant on read)
# ---------------------------------------------------------------------------

def fold_kv_heads(a: jax.Array) -> jax.Array:
    """``(B, T, H, ...) -> (BH, T, ...)``: fold the KV-head axis into
    batch, the layout every KV-cache attention kernel consumes (one
    shared definition so the packed-plane layout can change in one
    place)."""
    b, t, h = a.shape[:3]
    return a.transpose((0, 2, 1) + tuple(range(3, a.ndim))).reshape(
        (b * h, t) + a.shape[3:])


def quantize_kv(x: jax.Array, kv_bits: int):
    """K/V tensor ``(..., D)`` -> packed bipolar planes + per-head scales.

    Quantizes along the head dim with a per-(token, head) absmax scale
    (axis -1), decomposes into ``kv_bits`` bit planes and packs D into
    uint32 words (paper §4.1 applied to the KV stream).  Returns
    ``(packed (..., kv_bits, ceil(D/32)) uint32, scale (..., 1) f32)``.
    Pure jnp: the pack is elementwise-cheap next to the projections that
    produce K/V, and runs identically under every impl.
    """
    xf = x.astype(jnp.float32)
    scale = bipolar.absmax_scale(xf, kv_bits, axis=-1, keepdims=True)
    q = bipolar.quantize_values(xf, kv_bits, scale)
    planes = bipolar.decompose(q, kv_bits)            # (kv_bits, ..., D)
    planes = bipolar.pad_for_packing(planes, -1, 0)
    packed = bipolar.pack_planes(planes, -1)          # (kv_bits, ..., Dw)
    return jnp.moveaxis(packed, 0, -2), scale


def dequantize_kv(packed: jax.Array, scale: jax.Array, d: int,
                  dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`quantize_kv`: planes ``(..., n_bits, Dw)`` +
    scale ``(..., 1)`` -> ``(..., D)`` (the ``reference``-impl read path
    and the oracle for the in-kernel recovery)."""
    n_bits = packed.shape[-2]
    planes = jnp.moveaxis(packed, -2, 0)
    vals = bipolar.recover(bipolar.unpack_planes(planes, -1, d), n_bits)
    return (vals.astype(jnp.float32) * scale).astype(dtype)


def kv_cache_attention(q: jax.Array,
                       k_packed: jax.Array, k_scale: jax.Array,
                       v_packed: jax.Array, v_scale: jax.Array,
                       q_pos: jax.Array, kv_pos: jax.Array, *,
                       d: int, causal: bool = True, window=None,
                       impl: str | None = None) -> jax.Array:
    """Attention over a packed bipolar KV cache, folded (BH, ...) layout.

    ``q (BH, Sq, D)``; ``k_packed/v_packed (BH, T, n_bits, Dw)`` uint32;
    ``k_scale/v_scale (BH, T, 1)`` f32; positions int32 with negative
    kv_pos marking invalid slots.  Dispatches pallas | interpret (the
    dequant-on-read flash kernel) | reference (jnp dequant + direct
    softmax) -- all three agree to float tolerance.
    """
    impl = impl or default_impl()
    bh, sq, _ = q.shape
    t = k_packed.shape[1]
    n_bits = k_packed.shape[-2]
    if impl == "reference":
        k = dequantize_kv(k_packed, k_scale, d)
        v = dequantize_kv(v_packed, v_scale, d)
        return flash_kernel.attention_reference(
            q, k, v, q_pos, kv_pos, causal=causal, window=window)
    dp = k_packed.shape[-1] * bipolar.PACK_WIDTH
    # pad q's head dim with zeros to the packed word boundary (pad cols of
    # the recovered K decode to garbage but meet only zeros in q . k)
    qp_arr = _pad_dim(q, 2, dp)
    sqp = _round_up(sq, 8)
    bq = min(flash_kernel.DEFAULT_BQ, sqp)
    sqp = _round_up(sqp, bq)
    bk = min(flash_kernel.DEFAULT_BK, _round_up(t, 32))
    tp = _round_up(t, bk)
    qp_arr = _pad_dim(qp_arr, 1, sqp)
    q_pos_p = _pad_dim(q_pos, 1, sqp)
    kv_pos_p = _pad_dim(kv_pos, 1, tp, -1)      # pad slots are masked out
    kpk = _pad_dim(k_packed, 1, tp)
    vpk = _pad_dim(v_packed, 1, tp)
    ks = _pad_dim(k_scale.reshape(bh, t), 1, tp, 1.0)
    vs = _pad_dim(v_scale.reshape(bh, t), 1, tp, 1.0)
    out = flash_kernel.flash_attention_quantized(
        qp_arr, kpk, ks, vpk, vs, q_pos_p, kv_pos_p,
        d=d, n_bits=n_bits, causal=causal, window=window,
        block=(bq, bk), interpret=(impl == "interpret"))
    return out[:, :sq, :d]


def paged_kv_cache_attention(q: jax.Array,
                             k_pool: jax.Array, k_scale: jax.Array,
                             v_pool: jax.Array, v_scale: jax.Array,
                             pool_pos: jax.Array, block_tables: jax.Array,
                             q_pos: jax.Array, *,
                             d: int, causal: bool = True, window=None,
                             q_block: int | None = None,
                             impl: str | None = None) -> jax.Array:
    """Attention over a *paged* packed bipolar KV pool via a block table.

    ``q (B, H, Gq, D)`` per-kv-head grouped queries -- ``Gq`` is the
    GQA group size for decode, or ``G * Sq`` with the suffix length
    folded in for block-table suffix prefill (causality is by absolute
    ``q_pos``, so multi-token causal queries need no extra plumbing).
    The pool holds fixed-size token blocks shared by every request:
    ``k_pool/v_pool (n_blocks, bs, H, n_bits, Dw)`` uint32 planes,
    ``k_scale/v_scale (n_blocks, bs, H, 1)`` f32, ``pool_pos
    (n_blocks, bs)`` int32 (-1 = empty slot).  ``block_tables (B, NB)``
    int32 maps each request's logical blocks to physical ids; rows pad
    with 0, the reserved null block whose positions stay -1.

    Dispatch: pallas | interpret run the block-table-gathering flash
    kernel (the table is a scalar-prefetch operand indexing the pool
    block specs, the query axis tiled by ``q_block`` rows); reference
    gathers the request's blocks with :func:`repro.kernels.ref.gather_paged_kv`
    and reuses the contiguous :func:`kv_cache_attention` reference path
    on the exact same packed planes.
    """
    impl = impl or default_impl()
    b, h, g, _ = q.shape
    n_blocks, bs = pool_pos.shape
    n_bits = k_pool.shape[-2]
    if impl == "reference":
        gath = partial(ref.gather_paged_kv, block_tables=block_tables)
        kv_pos = gath(pool_pos[:, :, None])[..., 0]
        o = kv_cache_attention(
            q.reshape(b * h, g, q.shape[-1]),
            fold_kv_heads(gath(k_pool)), fold_kv_heads(gath(k_scale)),
            fold_kv_heads(gath(v_pool)), fold_kv_heads(gath(v_scale)),
            jnp.repeat(q_pos, h, 0), jnp.repeat(kv_pos, h, 0),
            d=d, causal=causal, window=window, impl=impl)
        return o.reshape(b, h, g, d)
    dp = k_pool.shape[-1] * bipolar.PACK_WIDTH
    gp = _round_up(g, 8)
    bq = min(q_block or flash_kernel.DEFAULT_PAGED_BQ, gp)
    gp = _round_up(gp, bq)
    qp_arr = _pad_dim(_pad_dim(q, 3, dp), 2, gp)
    q_pos_p = _pad_dim(q_pos, 1, gp, -1)          # pad rows fully masked
    out = flash_kernel.flash_attention_paged_quantized(
        qp_arr, k_pool, k_scale[..., 0], v_pool, v_scale[..., 0],
        pool_pos, block_tables, q_pos_p,
        d=d, n_bits=n_bits, causal=causal, window=window, block=bq,
        interpret=(impl == "interpret"))
    return out[:, :, :g, :d]
