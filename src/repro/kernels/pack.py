"""Fused quantize -> bit-plane decompose -> uint32 pack Pallas kernel.

The paper preprocesses matrices ahead of time (§4.1).  Weights can always
be preprocessed offline, but LLM *activations* appear on the fly; this
kernel performs the whole §4.1 pipeline (quantize to bipolar-INT, 1-bit
decompose, pack into uint32 words, concatenate planes) in one VMEM pass so
the activation matrix is read once from HBM and only ``n_bits/16`` of its
bf16 volume is written back.

Layout produced: ``(n_bits, R, K/32)`` uint32 for a row-major matrix
``X (R, K)`` packed along the trailing reduction axis K (element k = 32w+b
-> bit b of word w), matching :func:`repro.kernels.apmm.apmm_packed` --
the same function packs activations (R = tokens) and weights (R = d_out).

Scales are computed *outside* (a cheap jnp absmax) and passed in; the
kernel is the bandwidth-heavy part.  K must be a multiple of 32 and tiled
exactly; the ops wrapper pads rows with ``-scale*(2^n-1)`` / ``+scale*
(2^n-1)`` values, which quantize to all-zero / all-one bits = the pad-bit
conventions of the closed-form K-pad correction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import bipolar
from repro.kernels import compat

DEFAULT_BR = 256
DEFAULT_BK = 1024


def _kernel(x_ref, scale_ref, out_ref, *, n_bits: int, br: int, bk: int):
    x = x_ref[...].astype(jnp.float32)             # (br, bk)
    s = scale_ref[...]                             # (br, 1)
    maxv = bipolar.max_value(n_bits)
    q = 2.0 * jnp.round((x / s - 1.0) * 0.5) + 1.0   # round to odd
    q = jnp.clip(q, -maxv, maxv)
    u = ((q.astype(jnp.int32) + maxv) >> 1).astype(jnp.uint32)  # bit field
    u = u.reshape(br, bk // 32, 32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
    for i in range(n_bits):                        # plane i -> word sum
        bits = (u >> jnp.uint32(i)) & jnp.uint32(1)
        out_ref[i] = jnp.sum(bits << shifts, axis=2, dtype=jnp.uint32)


@functools.partial(
    jax.jit, static_argnames=("n_bits", "block", "interpret"))
def quantize_pack_rows(x: jax.Array, scale: jax.Array, *, n_bits: int,
                       block: tuple = (DEFAULT_BR, DEFAULT_BK),
                       interpret: bool = False) -> jax.Array:
    """Quantize + pack a row-major matrix ``X (R, K)`` along K.

    ``scale``: ``(R, 1)`` f32 per-row symmetric scales.
    Returns ``(n_bits, R, K/32)`` uint32.  Requires ``K % 32 == 0`` and
    exact tiling (the ops wrapper pads).
    """
    r, k = x.shape
    br, bk = block
    br, bk = min(br, r), min(bk, k)
    if k % 32 or r % br or k % bk or bk % 32:
        raise ValueError(f"shape ({r},{k}) not tiled by ({br},{bk})")
    kernel = functools.partial(_kernel, n_bits=n_bits, br=br, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=(r // br, k // bk),
        in_specs=[
            pl.BlockSpec((br, bk), lambda i, j: (i, j)),
            pl.BlockSpec((br, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((n_bits, br, bk // 32),
                               lambda i, j: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((n_bits, r, k // 32), jnp.uint32),
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x, scale)
