"""Per-request lifecycle tracing with Chrome/Perfetto trace export.

Every submitted request gets a ``RequestTrace``: a span tree recording
the lifecycle ``queued -> admitted -> [prefix_hit] -> chunk_prefill[i]
-> decode -> finished|preempted|cancelled|timeout`` plus per-token
emission timestamps, TTFT, preemption count, prefix-hit tokens, and
peak blocks held.  Timestamps come from the *engine's* injectable clock
(``Engine(clock=...)``), so traces are fully deterministic under test.

``Tracer.export()`` emits Chrome ``trace_event`` JSON (the classic
array-of-events format): each request maps to its own ``tid`` inside
one ``pid``, spans become ``"X"`` complete events (``ts``/``dur`` in
microseconds), token emissions and prefix hits become ``"i"`` instant
events, and ``"M"`` metadata events name the rows.  The file opens
directly in ``ui.perfetto.dev`` or ``chrome://tracing``.

Span integrity is a test invariant: ``RequestTrace.validate()`` checks
that a finished request's tree is *balanced* -- every span that was
opened is closed, exactly one root "request" span covers the lifetime,
and no event timestamps fall outside it.  ``tests/test_obs.py`` runs
this for every request in preemption/cancel/timeout walks.
"""

from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

__all__ = ["Span", "RequestTrace", "Tracer"]

_US = 1e6   # clock is in seconds; trace_event wants microseconds


class Span:
    """One closed-or-open interval in a request's lifecycle."""

    __slots__ = ("name", "t0", "t1", "args")

    def __init__(self, name: str, t0: float,
                 args: Optional[Dict[str, Any]] = None) -> None:
        self.name = name
        self.t0 = t0
        self.t1: Optional[float] = None
        self.args = args or {}

    @property
    def open(self) -> bool:
        return self.t1 is None

    def close(self, t1: float) -> None:
        if self.t1 is not None:
            raise RuntimeError(f"span {self.name!r} closed twice")
        self.t1 = t1

    def __repr__(self) -> str:
        end = "open" if self.open else f"{self.t1:.6f}"
        return f"Span({self.name}, {self.t0:.6f}..{end})"


class RequestTrace:
    """Span tree + event log for one request's lifetime."""

    def __init__(self, rid: int, label: str, t_submit: float) -> None:
        self.rid = rid
        self.label = label
        self.t_submit = t_submit
        self.t_finish: Optional[float] = None
        self.finish_reason: Optional[str] = None
        self.spans: List[Span] = []          # closed-or-open, in t0 order
        self._open: Dict[str, Span] = {}     # name -> currently open span
        self.instants: List[Dict[str, Any]] = []
        self.token_times: List[float] = []
        self.ttft: Optional[float] = None
        self.n_preemptions = 0
        self.n_chunks = 0
        self.prefix_hit_tokens = 0
        self.peak_blocks = 0

    # -- span API -------------------------------------------------------
    def begin(self, name: str, t: float,
              args: Optional[Dict[str, Any]] = None) -> Span:
        if name in self._open:
            raise RuntimeError(
                f"req {self.rid}: span {name!r} already open")
        s = Span(name, t, args)
        self._open[name] = s
        self.spans.append(s)
        return s

    def end(self, name: str, t: float,
            args: Optional[Dict[str, Any]] = None) -> None:
        s = self._open.pop(name, None)
        if s is None:
            raise RuntimeError(
                f"req {self.rid}: end of unopened span {name!r}")
        if args:
            s.args.update(args)
        s.close(t)

    def complete(self, name: str, t0: float, t1: float,
                 args: Optional[Dict[str, Any]] = None) -> None:
        """Record an already-closed span (no open/close pairing)."""
        s = Span(name, t0, args)
        s.close(t1)
        self.spans.append(s)

    def instant(self, name: str, t: float,
                args: Optional[Dict[str, Any]] = None) -> None:
        self.instants.append(dict(name=name, t=t, args=args or {}))

    # -- lifecycle bookkeeping -----------------------------------------
    def token(self, t: float, index: int, tok: int) -> None:
        if self.ttft is None:
            self.ttft = t - self.t_submit
        self.token_times.append(t)
        self.instant("token", t, dict(index=index, id=int(tok)))

    def finish(self, t: float, reason: str) -> None:
        # close anything still open (e.g. "running" on cancel mid-step)
        for name in list(self._open):
            self.end(name, t)
        self.t_finish = t
        self.finish_reason = reason

    @property
    def done(self) -> bool:
        return self.t_finish is not None

    def intertoken(self) -> List[float]:
        tt = self.token_times
        return [b - a for a, b in zip(tt, tt[1:])]

    # -- invariants -----------------------------------------------------
    def validate(self) -> None:
        """Balanced-tree check for a finished request.  Raises on any
        dangling span or event outside the request envelope."""
        if not self.done:
            raise AssertionError(f"req {self.rid}: not finished")
        if self._open:
            raise AssertionError(
                f"req {self.rid}: dangling spans {list(self._open)}")
        t0, t1 = self.t_submit, self.t_finish
        for s in self.spans:
            if s.open:
                raise AssertionError(
                    f"req {self.rid}: unclosed span {s!r}")
            if not (t0 <= s.t0 <= s.t1 <= t1):
                raise AssertionError(
                    f"req {self.rid}: span {s!r} outside envelope "
                    f"[{t0}, {t1}]")
        for ev in self.instants:
            if not (t0 <= ev["t"] <= t1):
                raise AssertionError(
                    f"req {self.rid}: instant {ev['name']!r}@{ev['t']} "
                    f"outside envelope [{t0}, {t1}]")
        if self.finish_reason is None:
            raise AssertionError(f"req {self.rid}: no finish_reason")

    # -- export ---------------------------------------------------------
    def _events(self, pid: int) -> List[Dict[str, Any]]:
        tid = self.rid
        ev: List[Dict[str, Any]] = [dict(
            ph="M", pid=pid, tid=tid, name="thread_name",
            args=dict(name=self.label))]
        root_args = dict(finish_reason=self.finish_reason,
                         ttft=self.ttft,
                         n_tokens=len(self.token_times),
                         n_preemptions=self.n_preemptions,
                         n_chunks=self.n_chunks,
                         prefix_hit_tokens=self.prefix_hit_tokens,
                         peak_blocks=self.peak_blocks)
        ev.append(dict(ph="X", pid=pid, tid=tid, name="request",
                       cat="request", ts=self.t_submit * _US,
                       dur=(self.t_finish - self.t_submit) * _US,
                       args=root_args))
        for s in self.spans:
            ev.append(dict(ph="X", pid=pid, tid=tid, name=s.name,
                           cat="lifecycle", ts=s.t0 * _US,
                           dur=(s.t1 - s.t0) * _US, args=s.args))
        for i in self.instants:
            ev.append(dict(ph="i", pid=pid, tid=tid, name=i["name"],
                           cat="event", ts=i["t"] * _US, s="t",
                           args=i["args"]))
        return ev


class Tracer:
    """Registry of per-request traces; owns nothing but the dict."""

    PID = 1

    def __init__(self) -> None:
        self.traces: Dict[int, RequestTrace] = {}
        self._next_rid = 0

    def start(self, t_submit: float,
              label: Optional[str] = None) -> RequestTrace:
        rid = self._next_rid
        self._next_rid += 1
        tr = RequestTrace(rid, label or f"req {rid}", t_submit)
        self.traces[rid] = tr
        return tr

    def validate_all(self) -> None:
        for tr in self.traces.values():
            tr.validate()

    def export(self) -> Dict[str, Any]:
        """Chrome trace_event JSON object (``{"traceEvents": [...]}``)."""
        events: List[Dict[str, Any]] = [dict(
            ph="M", pid=self.PID, tid=0, name="process_name",
            args=dict(name="repro serving engine"))]
        for rid in sorted(self.traces):
            events.extend(self.traces[rid]._events(self.PID))
        return dict(traceEvents=events, displayTimeUnit="ms")

    def export_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.export(), f, indent=1)
