"""Observability for the serving stack: metrics, traces, hooks.

Dependency-free telemetry threaded through the engine / scheduler /
paged pool (ISSUE 7):

* :mod:`repro.obs.metrics` -- counters, gauges, fixed-bucket
  histograms in one :class:`MetricsRegistry` namespace with
  Prometheus-style text exposition (``registry.render()``).  The
  pool's and scheduler's legacy counter attributes (``pool.n_cow``,
  ``sch.n_preemptions``, ...) and their ``report()`` dicts are
  snapshots of this registry -- one source of truth.
* :mod:`repro.obs.trace` -- per-request lifecycle span trees
  (``queued -> running -> chunk_prefill[i] -> decode -> finish``)
  exportable as Chrome/Perfetto ``trace_event`` JSON.
* :mod:`repro.obs.hooks` -- the :class:`ServingObs` facade the stack
  reports through, and its no-op twin :data:`NULL_OBS` (the default:
  observability off costs one no-op call per event and leaves the hot
  path token-identical).

Enable per engine: ``Engine(..., metrics=True)`` (or pass a
``MetricsRegistry`` / ``ServingObs``); then ``eng.obs.registry.render()``
for the Prometheus snapshot and ``eng.obs.tracer.export()`` for the
Perfetto timeline.
"""

from repro.obs.hooks import NULL_OBS, ServingObs
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               LATENCY_BUCKETS, TOKEN_BUCKETS)
from repro.obs.trace import RequestTrace, Span, Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "LATENCY_BUCKETS", "TOKEN_BUCKETS",
    "Span", "RequestTrace", "Tracer",
    "ServingObs", "NULL_OBS",
]
