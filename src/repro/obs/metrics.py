"""Dependency-free metrics registry: counters, gauges, histograms.

The serving stack (engine / scheduler / paged pool) reports through one
``MetricsRegistry`` namespace so the ``report()`` dicts, the benchmark
scripts, and a scraped ``/metrics`` endpoint can never drift apart: the
registry *is* the source of truth and ``report()`` is a snapshot of it.

Design constraints (see ROADMAP "Observability layer" contract):

* **No dependencies** -- plain Python, no prometheus_client.
* **Hot-path cost == a plain int add.**  ``Counter.inc`` / ``Gauge.set``
  mutate a float attribute; no locks, no dict lookups on the hot path
  (label children are resolved once and cached by the caller).
* **Allocation-free when disabled.**  Call sites that need timing or
  per-step work go through the ``ServingObs`` facade (obs/hooks.py)
  whose no-op twin ``NULL_OBS`` makes every hook a constant-return
  method -- the registry itself is cheap enough to always be live for
  event counters, which is what keeps legacy ``pool.n_cow``-style
  attributes exact.

Exposition is Prometheus text format 0.0.4 via ``registry.render()``::

    # HELP repro_pool_cow_total copy-on-write block copies
    # TYPE repro_pool_cow_total counter
    repro_pool_cow_total 3

Histograms are fixed-bucket (chosen at declaration), rendering the
standard cumulative ``_bucket{le=...}`` / ``_sum`` / ``_count`` series.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "LATENCY_BUCKETS", "TOKEN_BUCKETS",
]

# default bucket ladders ------------------------------------------------
# seconds: 100us .. 30s, roughly x3 steps -- covers TTFT and inter-token
# latency on anything from a stubbed clock to a CPU interpret run
LATENCY_BUCKETS = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 0.1, 0.3,
                   1.0, 3.0, 10.0, 30.0)
# token counts: powers of two up to a long prompt
TOKEN_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)


def _fmt_label_values(labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


def _fmt_num(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


class _Metric:
    """Base: a named family of children keyed by label values."""

    kind = "untyped"

    def __init__(self, name: str, help: str,
                 labelnames: Sequence[str] = ()) -> None:
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._children: Dict[Tuple[str, ...], "_Metric"] = {}
        # the unlabeled metric acts as its own (sole) child
        if not self.labelnames:
            self._children[()] = self

    def labels(self, **kv: str) -> "_Metric":
        """Resolve (and cache) the child for a label-value combination.

        Resolve once at setup, hold the child: the returned object's
        ``inc``/``set``/``observe`` are then plain attribute mutations.
        """
        if tuple(kv) != self.labelnames:
            raise ValueError(
                f"{self.name}: labels {tuple(kv)} != declared "
                f"{self.labelnames}")
        key = tuple(str(kv[k]) for k in self.labelnames)
        child = self._children.get(key)
        if child is None:
            child = self._make_child()
            self._children[key] = child
        return child

    def _make_child(self) -> "_Metric":
        raise NotImplementedError

    # -- exposition -----------------------------------------------------
    def _sample_lines(self) -> List[str]:
        raise NotImplementedError

    def render(self) -> str:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} {self.kind}"]
        for key in sorted(self._children):
            child = self._children[key]
            labels = tuple(zip(self.labelnames, key))
            lines.extend(child._render_samples(labels))
        return "\n".join(lines) + "\n"

    def _render_samples(
            self, labels: Tuple[Tuple[str, str], ...]) -> List[str]:
        raise NotImplementedError


class Counter(_Metric):
    """Monotonically increasing count; rendered as ``<name>_total``."""

    kind = "counter"

    def __init__(self, name, help, labelnames=()):
        super().__init__(name, help, labelnames)
        self.value = 0.0

    def _make_child(self):
        c = Counter(self.name, self.help)
        return c

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    @property
    def total_name(self) -> str:
        return self.name if self.name.endswith("_total") \
            else self.name + "_total"

    def _render_samples(self, labels):
        return [f"{self.total_name}{_fmt_label_values(labels)} "
                f"{_fmt_num(self.value)}"]


class Gauge(_Metric):
    """A value that can go up and down (occupancy, batch lanes, ...)."""

    kind = "gauge"

    def __init__(self, name, help, labelnames=()):
        super().__init__(name, help, labelnames)
        self.value = 0.0

    def _make_child(self):
        return Gauge(self.name, self.help)

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.value -= amount

    def _render_samples(self, labels):
        return [f"{self.name}{_fmt_label_values(labels)} "
                f"{_fmt_num(self.value)}"]


class Histogram(_Metric):
    """Fixed-bucket histogram with cumulative Prometheus exposition."""

    kind = "histogram"

    def __init__(self, name, help, labelnames=(),
                 buckets: Sequence[float] = LATENCY_BUCKETS):
        super().__init__(name, help, labelnames)
        self.buckets = tuple(sorted(buckets))
        self.counts = [0] * (len(self.buckets) + 1)   # +1 for +Inf
        self.sum = 0.0
        self.count = 0

    def _make_child(self):
        return Histogram(self.name, self.help, buckets=self.buckets)

    def observe(self, value: float) -> None:
        self.sum += value
        self.count += 1
        # buckets are few (~12): linear scan beats bisect's call cost
        for i, b in enumerate(self.buckets):
            if value <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def percentile(self, q: float) -> float:
        """Approximate percentile: upper edge of the bucket holding the
        q-quantile observation (``inf`` if it lands in the overflow
        bucket).  Good enough for a stats bar; tests should compare
        within a bucket's tolerance, not exactly."""
        if not self.count:
            return 0.0
        rank = q / 100.0 * self.count
        seen = 0
        for i, b in enumerate(self.buckets):
            seen += self.counts[i]
            if seen >= rank:
                return b
        return float("inf")

    def _render_samples(self, labels):
        out = []
        cum = 0
        for i, b in enumerate(self.buckets):
            cum += self.counts[i]
            lb = labels + (("le", _fmt_num(b)),)
            out.append(f"{self.name}_bucket{_fmt_label_values(lb)} {cum}")
        lb = labels + (("le", "+Inf"),)
        out.append(f"{self.name}_bucket{_fmt_label_values(lb)} "
                   f"{self.count}")
        out.append(f"{self.name}_sum{_fmt_label_values(labels)} "
                   f"{_fmt_num(self.sum)}")
        out.append(f"{self.name}_count{_fmt_label_values(labels)} "
                   f"{self.count}")
        return out


class MetricsRegistry:
    """A named collection of metrics with Prometheus text exposition.

    ``counter``/``gauge``/``histogram`` are get-or-create: declaring the
    same name twice returns the existing metric (so the pool, scheduler,
    and engine can share one registry without coordinating declaration
    order), but redeclaring with a different kind raises.
    """

    def __init__(self) -> None:
        self._metrics: Dict[str, _Metric] = {}

    def _declare(self, cls, name, help, labelnames, **kw) -> _Metric:
        m = self._metrics.get(name)
        if m is not None:
            if type(m) is not cls or m.labelnames != tuple(labelnames):
                raise ValueError(
                    f"metric {name!r} redeclared with different "
                    f"kind/labels")
            return m
        m = cls(name, help, labelnames, **kw)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._declare(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._declare(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = LATENCY_BUCKETS
                  ) -> Histogram:
        return self._declare(Histogram, name, help, labelnames,
                             buckets=buckets)

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    def value(self, name: str, **labels: str) -> float:
        """Current value of a counter/gauge (0 if undeclared)."""
        m = self._metrics.get(name)
        if m is None:
            return 0.0
        child = m.labels(**labels) if labels else m
        return child.value

    def snapshot(self) -> Dict[str, float]:
        """Flat ``{name{labels}: value}`` dict of counters and gauges
        (histograms contribute ``_sum`` and ``_count``)."""
        out: Dict[str, float] = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            for key in sorted(m._children):
                child = m._children[key]
                suffix = _fmt_label_values(
                    tuple(zip(m.labelnames, key)))
                if isinstance(child, Histogram):
                    out[f"{name}_sum{suffix}"] = child.sum
                    out[f"{name}_count{suffix}"] = float(child.count)
                elif isinstance(child, Counter):
                    out[f"{child.total_name}{suffix}"] = child.value
                else:
                    out[f"{name}{suffix}"] = child.value
        return out

    def render(self) -> str:
        """Prometheus text exposition (format 0.0.4) of every metric."""
        return "".join(self._metrics[n].render()
                       for n in sorted(self._metrics))
