"""ServingObs: the one facade the serving stack reports through.

The engine, scheduler, and pool do not talk to the registry or tracer
directly on timed paths -- they call lifecycle hooks on a ``ServingObs``
(``on_submit`` / ``on_admit`` / ``on_token`` / ``on_preempt`` /
``on_finish`` / ``on_step`` / ``on_dispatch``), which owns:

* a :class:`~repro.obs.metrics.MetricsRegistry` (shared with the pool
  and scheduler, so every counter lives in ONE namespace),
* a :class:`~repro.obs.trace.Tracer` building the per-request span
  trees, and
* the **engine's clock**: the engine binds its injectable ``clock`` to
  the facade at construction, so every timestamp -- TTFT, inter-token,
  span edges, step durations -- is deterministic under an injected
  test clock (the same one deadline expiry already uses).

``NULL_OBS`` is the disabled twin: a stateless singleton whose hooks
are constant no-ops (``enabled = False``).  The engine's hot path calls
the cheap per-event hooks unconditionally (one attribute access + one
no-op call, no clock read, no allocation) and guards anything that
would *compute* (per-step gauge math, forward-pass timing) behind
``obs.enabled`` -- which is how metrics-off keeps token-identity and
a <= 2% step-time overhead (benchmarks/obs_overhead.py measures it).

Traces ride the request object (``req._trace``): preemption re-queues
the request but the trace survives, so a preempted-then-resumed
request shows ``queued -> running -> queued -> running`` with one root
span.  Every hook tolerates a request with no trace (a scheduler used
standalone, without an engine's ``on_submit``).
"""

from __future__ import annotations

import time
from typing import Any, Callable, Optional

import numpy as np

from repro.obs.metrics import (LATENCY_BUCKETS, TOKEN_BUCKETS,
                               MetricsRegistry)
from repro.obs.trace import Tracer

__all__ = ["ServingObs", "NULL_OBS"]


class ServingObs:
    """Live observability: registry + tracer + clock, with the
    lifecycle hooks the serving stack calls (see module docstring)."""

    enabled = True

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 clock: Optional[Callable[[], float]] = None,
                 tracer: Optional[Tracer] = None):
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.tracer = tracer if tracer is not None else Tracer()
        self.clock = clock or time.monotonic
        r = self.registry
        # per-request latency distributions
        self._h_ttft = r.histogram(
            "repro_request_ttft_seconds",
            "submit-to-first-token latency")
        self._h_intertok = r.histogram(
            "repro_request_intertoken_seconds",
            "gap between consecutive emitted tokens of one request")
        self._h_queue = r.histogram(
            "repro_request_queue_wait_seconds",
            "time spent waiting (initial queue + re-queues after "
            "preemption)")
        self._h_step = r.histogram(
            "repro_engine_step_seconds", "engine step wall time")
        # lifecycle counters
        self._c_submitted = r.counter(
            "repro_requests_submitted", "requests handed to submit()")
        self._c_finished = r.counter(
            "repro_requests_finished",
            "finished requests by finish_reason",
            labelnames=("reason",))
        self._finished_children: dict = {}
        self._c_tokens = r.counter(
            "repro_engine_tokens", "output tokens emitted")
        self._c_steps = r.counter(
            "repro_engine_steps", "engine steps executed")
        self._c_prefill_tokens = r.counter(
            "repro_engine_prefill_tokens",
            "prompt tokens run through prefill passes (chunked "
            "step-loop chunks or whole-prompt admission)")
        # step-loop gauges (set once per step / dispatch)
        self._g_running = r.gauge(
            "repro_engine_running", "requests currently running")
        self._g_waiting = r.gauge(
            "repro_engine_waiting", "requests queued for admission")
        self._g_lanes = r.gauge(
            "repro_engine_batch_lanes",
            "dispatch lanes by kind (bucket padding waste = padded)",
            labelnames=("kind",))
        self._g_lanes_live = self._g_lanes.labels(kind="live")
        self._g_lanes_pad = self._g_lanes.labels(kind="padded")
        self._g_pad_waste = r.gauge(
            "repro_engine_padding_waste",
            "fraction of dispatched token slots that were padding")
        self._g_chunk_util = r.gauge(
            "repro_engine_chunk_budget_utilization",
            "fraction of the chunk budget the step's plan used")
        self._g_occupancy = r.gauge(
            "repro_pool_occupancy", "used / usable pool blocks")
        # MoE capacity pressure (per forward dispatch, fed by the
        # engine's moe_stats-specialized steps)
        self._h_moe_load = r.histogram(
            "repro_moe_expert_load",
            "tokens dispatched to one expert in one MoE layer pass",
            buckets=TOKEN_BUCKETS)
        self._c_moe_dropped = r.counter(
            "repro_moe_dropped_tokens",
            "routed assignments lost to the expert capacity bound")
        self._g_moe_util = r.gauge(
            "repro_moe_capacity_utilization",
            "kept assignments / dispatch slots over the last forward")

    # -- clock ---------------------------------------------------------------
    def t(self) -> float:
        return self.clock()

    # -- request lifecycle ---------------------------------------------------
    def on_submit(self, req: Any, label: Optional[str] = None) -> None:
        now = self.clock()
        self._c_submitted.inc()
        tr = self.tracer.start(now, label)
        req._trace = tr
        tr.begin("queued", now)

    def on_admit(self, seq: Any, cached_tokens: int = 0,
                 prefilling: bool = False) -> None:
        now = self.clock()
        tr = getattr(seq.req, "_trace", None)
        if tr is None:
            return
        if "queued" in tr._open:
            q = tr._open["queued"]
            tr.end("queued", now)
            self._h_queue.observe(now - q.t0)
        tr.begin("running", now)
        if cached_tokens:
            tr.prefix_hit_tokens += cached_tokens
            tr.instant("prefix_hit", now, dict(tokens=cached_tokens))
        if not prefilling:
            tr.begin("decode", now)
        self._track_blocks(tr, seq)

    def on_decode_begin(self, seq: Any) -> None:
        tr = getattr(seq.req, "_trace", None)
        if tr is not None and "decode" not in tr._open:
            tr.begin("decode", self.clock())

    def on_chunk(self, seq: Any, n: int, t0: float, t1: float) -> None:
        """One chunk of ``seq``'s prompt landed between ``t0`` and
        ``t1`` (whole-prompt admission records its single prefill pass
        through here too, as chunk 0)."""
        self._c_prefill_tokens.inc(n)
        tr = getattr(seq.req, "_trace", None)
        if tr is None:
            return
        tr.complete("chunk_prefill", t0, t1,
                    dict(index=tr.n_chunks, tokens=n))
        tr.n_chunks += 1
        self._track_blocks(tr, seq)

    def on_token(self, req: Any, tok: int) -> None:
        now = self.clock()
        self._c_tokens.inc()
        tr = getattr(req, "_trace", None)
        if tr is None:
            return
        if tr.token_times:
            self._h_intertok.observe(now - tr.token_times[-1])
        else:
            self._h_ttft.observe(now - tr.t_submit)
        tr.token(now, len(req.out) - 1, tok)

    def on_preempt(self, seq: Any) -> None:
        now = self.clock()
        tr = getattr(seq.req, "_trace", None)
        if tr is None:
            return
        tr.n_preemptions += 1
        if "decode" in tr._open:
            tr.end("decode", now)
        if "running" in tr._open:
            tr.end("running", now)
        tr.begin("queued", now)

    def on_finish(self, req: Any, reason: str,
                  seq: Any = None) -> None:
        child = self._finished_children.get(reason)
        if child is None:
            child = self._c_finished.labels(reason=reason)
            self._finished_children[reason] = child
        child.inc()
        tr = getattr(req, "_trace", None)
        if tr is None:
            return
        if seq is not None:
            self._track_blocks(tr, seq)
        tr.finish(self.clock(), reason)

    @staticmethod
    def _track_blocks(tr: Any, seq: Any) -> None:
        held = getattr(seq, "freed_prefix", 0) \
            + len(getattr(seq, "blocks", ()))
        if held > tr.peak_blocks:
            tr.peak_blocks = held

    # -- step loop -----------------------------------------------------------
    def on_step(self, t0: float, *, running: int, waiting: int,
                chunk_used: Optional[int] = None,
                chunk_budget: Optional[int] = None,
                occupancy: Optional[float] = None) -> None:
        self._c_steps.inc()
        self._h_step.observe(self.clock() - t0)
        self._g_running.set(running)
        self._g_waiting.set(waiting)
        if chunk_budget:
            self._g_chunk_util.set((chunk_used or 0) / chunk_budget)
        if occupancy is not None:
            self._g_occupancy.set(occupancy)

    def on_dispatch(self, *, live: int, lanes: int,
                    tok_live: int, tok_lanes: int) -> None:
        """Record one forward dispatch's bucket-padding waste:
        ``live`` real lanes padded to ``lanes`` bucket lanes, carrying
        ``tok_live`` real tokens of ``tok_lanes`` dispatched slots."""
        self._g_lanes_live.set(live)
        self._g_lanes_pad.set(lanes - live)
        if tok_lanes:
            self._g_pad_waste.set(1.0 - tok_live / tok_lanes)

    def on_moe(self, stats: Any) -> None:
        """Record one forward pass's MoE capacity telemetry: ``stats``
        is the :func:`repro.models.model.forward` dict -- ``load``
        ``(L_moe, E)`` kept tokens per expert, ``dropped (L_moe,)``
        assignments lost to the capacity bound, ``capacity (L_moe,)``
        dispatch slots -- device arrays; the host transfer happens
        here, off the jitted step."""
        if stats is None:
            return
        load = np.asarray(stats["load"])
        for v in load.reshape(-1):
            self._h_moe_load.observe(float(v))
        dropped = int(np.asarray(stats["dropped"]).sum())
        if dropped:
            self._c_moe_dropped.inc(dropped)
        cap = int(np.asarray(stats["capacity"]).sum())
        if cap:
            self._g_moe_util.set(float(load.sum()) / cap)


class _NullObs:
    """Disabled twin of :class:`ServingObs`: every hook is a constant
    no-op -- no clock reads, no allocations, nothing retained.  One
    shared singleton (``NULL_OBS``) serves every disabled engine."""

    __slots__ = ()
    enabled = False
    registry = None
    tracer = None

    def t(self):
        return 0.0

    def on_submit(self, req, label=None):
        pass

    def on_admit(self, seq, cached_tokens=0, prefilling=False):
        pass

    def on_decode_begin(self, seq):
        pass

    def on_chunk(self, seq, n, t0, t1):
        pass

    def on_token(self, req, tok):
        pass

    def on_preempt(self, seq):
        pass

    def on_finish(self, req, reason, seq=None):
        pass

    def on_step(self, t0, **kw):
        pass

    def on_dispatch(self, **kw):
        pass

    def on_moe(self, stats):
        pass


NULL_OBS = _NullObs()
