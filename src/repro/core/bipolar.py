"""Bipolar-INT data format (paper §3.1) and bit-plane pack/reassembly (§4.1).

An ``n``-bit bipolar-INT value ``x = x^(n-1) ... x^(1) x^(0)`` has decimal
value

    (x)_D = sum_i (2 * x^(i) - 1) * 2^i            (paper Eq. 1)

i.e. every bit is interpreted as -1 (bit=0) or +1 (bit=1).  The representable
set is the 2^n *odd* integers in ``[-(2^n - 1), 2^n - 1]`` -- perfectly
symmetric, no sign bit, no zero-point.  Every bit-plane is handled
identically, which is what makes the bit-serial MatMul decomposition a
uniform parallel loop (no two's-complement MSB special case).

This module is pure jnp and serves as both the public quantization API and
the oracle for the Pallas kernels (kernels/ref.py re-exports from here).

Conventions
-----------
* "value"  -- odd-integer bipolar value, int32.
* "ubits"  -- the unsigned bit field ``u = (value + (2^n - 1)) / 2`` in
  ``[0, 2^n)``; bit ``i`` of ``u`` is the bipolar bit ``x^(i)``.
* "planes" -- bit-plane tensor, leading axis = bit index, entries in {0, 1}
  (uint8), *interpreted* as {-1, +1}.
* "packed" -- planes packed along the reduction axis into uint32 words,
  planes concatenated on the leading axis (paper Fig. 3 steps 1-3).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

PACK_WIDTH = 32  # bits per packed word (uint32), paper §4.1 step 2


# ---------------------------------------------------------------------------
# Value-level encode / decode
# ---------------------------------------------------------------------------

def max_value(n_bits: int) -> int:
    """Largest representable bipolar-INT magnitude: 2^n - 1."""
    return (1 << n_bits) - 1


def encode(values: jax.Array, n_bits: int) -> jax.Array:
    """Odd-integer bipolar values -> unsigned bit field ``u`` (int32).

    ``u = (v + (2^n - 1)) / 2``; bit i of u is the bipolar bit x^(i).
    """
    v = values.astype(jnp.int32)
    return (v + max_value(n_bits)) >> 1


def decode(ubits: jax.Array, n_bits: int) -> jax.Array:
    """Unsigned bit field -> odd-integer bipolar value (int32)."""
    return (ubits.astype(jnp.int32) << 1) - max_value(n_bits)


def round_to_odd(x: jax.Array) -> jax.Array:
    """Round to the nearest odd integer (ties away from the even side)."""
    # nearest odd = 2 * round((x - 1) / 2) + 1;  jnp.round is
    # round-half-to-even on .5 ties which keeps the result unbiased.
    return 2.0 * jnp.round((x - 1.0) * 0.5) + 1.0


def quantize_values(x: jax.Array, n_bits: int, scale: jax.Array) -> jax.Array:
    """Real tensor -> odd-integer bipolar values (int32), symmetric scaling.

    ``q = clip(round_to_odd(x / scale), -(2^n-1), 2^n-1)``.
    """
    m = max_value(n_bits)
    q = round_to_odd(x / scale)
    return jnp.clip(q, -m, m).astype(jnp.int32)


def absmax_scale(x: jax.Array, n_bits: int, axis=None, keepdims=True,
                 eps: float = 1e-8) -> jax.Array:
    """Symmetric absmax scale so that absmax maps to +-(2^n - 1).

    Written as a reciprocal multiply, not a divide: XLA folds a
    constant-divisor divide into exactly this multiply when compiling,
    while eager mode executes a true division -- the explicit multiply
    is the one form that produces identical bits in every compilation
    context, which the bit-exact parity contracts between kernel impls
    rely on."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=keepdims)
    return jnp.maximum(amax, eps) * (1.0 / max_value(n_bits))


def mse_scale(x: jax.Array, n_bits: int, axis=-1, *,
              candidates: int = 15, lo: float = 0.65) -> jax.Array:
    """Per-group clip-searched scale minimizing quantization MSE.

    Sweeps ``candidates`` shrink factors in ``[lo, 1.0]`` of the absmax
    scale and keeps, per reduction group, the one with the smallest
    ``||q * s - x||^2``.  At low bit widths (<= 4) absmax wastes most of
    the grid on outliers; a mild clip roughly halves weight MSE and is
    what keeps greedy decode faithful at W4 (calibration-free analogue of
    the ABQ-LLM/AWQ clip search).  Offline-cost only -- used for weight
    preprocessing, never on the activation path.
    """
    xf = x.astype(jnp.float32)
    base = absmax_scale(xf, n_bits, axis=axis, keepdims=True)
    best_s, best_e = base, jnp.full_like(base, jnp.inf)
    for c in np.linspace(lo, 1.0, candidates):
        s = base * float(c)
        q = quantize_values(xf, n_bits, s)
        err = jnp.sum(jnp.square(q.astype(jnp.float32) * s - xf),
                      axis=axis, keepdims=True)
        take = err < best_e
        best_s = jnp.where(take, s, best_s)
        best_e = jnp.where(take, err, best_e)
    return best_s


def truncate_values(values: jax.Array, n_bits: int, k: int) -> jax.Array:
    """Top-``k`` plane prefix of ``n_bits``-bit bipolar values, as k-bit
    bipolar values (int32).

    Dropping the ``n_bits - k`` least-significant planes of the unsigned
    bit field is *round-to-nearest* onto the coarse k-bit grid scaled by
    ``2^{n_bits-k}``: the discarded low bits form an odd remainder of
    magnitude ``<= 2^{n_bits-k} - 1``, strictly under half the coarse
    spacing ``2^{n_bits-k+1}`` and never a tie -- which is why a plane
    slice of a packed tensor matches a direct k-bit quantization at the
    natural scale ``s * 2^{n_bits-k}`` (the nested-precision parity
    contract in tests/kernels/test_parity.py)."""
    if k == n_bits:
        return values.astype(jnp.int32)
    return decode(encode(values, n_bits) >> (n_bits - k), k)


def nested_width_scales(x: jax.Array, values: jax.Array, n_bits: int,
                        scale: jax.Array, axis=-1, *,
                        candidates: int = 15, lo: float = 0.8,
                        hi: float = 1.2) -> jax.Array:
    """Per-width dequant scales for a nested (prefix-truncatable) tensor.

    Row ``k-1`` is the scale to dequantize the top-``k`` plane slice of
    ``values`` (the integers are FIXED by the max-bit grid -- truncation
    only, no requantization), chosen by a clip search around the natural
    slice scale ``scale * 2^{n_bits-k}``: sweep ``candidates`` factors in
    ``[lo, hi]`` and keep, per reduction group, the one minimizing
    ``||v_k * s - x||^2`` (the fixed-integer analogue of
    :func:`mse_scale`'s clip search; offline cost only).  Row
    ``n_bits-1`` is ``scale`` itself, unconditionally -- a full-width
    slice must be the identity.  Returns ``(n_bits, *scale.shape)``.
    """
    xf = x.astype(jnp.float32)
    base = scale.astype(jnp.float32)
    rows = []
    for k in range(1, n_bits + 1):
        if k == n_bits:
            rows.append(base)
            continue
        vk = truncate_values(values, n_bits, k).astype(jnp.float32)
        natural = base * float(1 << (n_bits - k))
        best_s = natural
        best_e = jnp.full_like(natural, jnp.inf)
        for c in np.linspace(lo, hi, candidates):
            s = natural * float(c)
            err = jnp.sum(jnp.square(vk * s - xf), axis=axis,
                          keepdims=True)
            take = err < best_e
            best_s = jnp.where(take, s, best_s)
            best_e = jnp.where(take, err, best_e)
        rows.append(best_s)
    return jnp.stack(rows, axis=0)


# ---------------------------------------------------------------------------
# Bit-plane decomposition / recovery (paper §3.2 data decomposition step)
# ---------------------------------------------------------------------------

def decompose(values: jax.Array, n_bits: int) -> jax.Array:
    """Bipolar values -> bit planes ``(n_bits, *shape)`` uint8 in {0,1}."""
    u = encode(values, n_bits)
    shifts = jnp.arange(n_bits, dtype=jnp.int32)
    shifts = shifts.reshape((n_bits,) + (1,) * values.ndim)
    return ((u[None] >> shifts) & 1).astype(jnp.uint8)


def recover(planes: jax.Array, n_bits: int) -> jax.Array:
    """Bit planes -> bipolar values (int32).  Inverse of :func:`decompose`."""
    weights = (1 << jnp.arange(n_bits, dtype=jnp.int32))
    weights = weights.reshape((n_bits,) + (1,) * (planes.ndim - 1))
    signed = 2 * planes.astype(jnp.int32) - 1          # {0,1} -> {-1,+1}
    return jnp.sum(signed * weights, axis=0)


# ---------------------------------------------------------------------------
# uint32 packing / reassembly (paper §4.1, Fig. 3)
# ---------------------------------------------------------------------------

def packed_words(k: int) -> int:
    """Number of uint32 words covering ``k`` reduction elements."""
    return (k + PACK_WIDTH - 1) // PACK_WIDTH


def pack_planes(planes: jax.Array, axis: int) -> jax.Array:
    """Pack {0,1} planes into uint32 words along ``axis`` (step 2 of Fig. 3).

    ``axis`` indexes the *underlying tensor* dims (excluding the leading
    plane axis).  The packed axis shrinks by 32x; ``axis`` length must be a
    multiple of 32 (callers pad with :func:`pad_for_packing` first).

    Bit layout: element ``k`` lives in word ``k // 32`` at bit ``k % 32``.
    """
    axis = axis + 1 if axis >= 0 else axis  # account for leading plane axis
    k = planes.shape[axis]
    if k % PACK_WIDTH != 0:
        raise ValueError(f"pack axis length {k} not a multiple of {PACK_WIDTH}")
    x = jnp.moveaxis(planes, axis, -1).astype(jnp.uint32)
    x = x.reshape(x.shape[:-1] + (k // PACK_WIDTH, PACK_WIDTH))
    shifts = jnp.arange(PACK_WIDTH, dtype=jnp.uint32)
    words = jnp.sum(x << shifts, axis=-1, dtype=jnp.uint32)
    return jnp.moveaxis(words, -1, axis)


def unpack_planes(packed: jax.Array, axis: int, k: int) -> jax.Array:
    """uint32 words -> {0,1} planes (uint8); inverse of :func:`pack_planes`."""
    axis = axis + 1 if axis >= 0 else axis
    x = jnp.moveaxis(packed, axis, -1)
    shifts = jnp.arange(PACK_WIDTH, dtype=jnp.uint32)
    bits = (x[..., None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(x.shape[:-1] + (x.shape[-1] * PACK_WIDTH,))
    bits = bits[..., :k]
    return jnp.moveaxis(bits, -1, axis).astype(jnp.uint8)


def pad_for_packing(planes: jax.Array, axis: int, pad_bit: int) -> jax.Array:
    """Pad the pack axis to a multiple of 32 with a constant bit.

    Padding a bipolar plane is never free (bit 0 *means* -1), so matmul
    callers pad W with bit 1 (+1) and X with bit 0 (-1) and subtract the
    closed-form correction ``n_pad * (2^{n_w}-1) * (2^{n_x}-1) * (-1)``
    (see :func:`pad_correction`).
    """
    axis = axis + 1 if axis >= 0 else axis
    k = planes.shape[axis]
    pad = (-k) % PACK_WIDTH
    if pad == 0:
        return planes
    cfg = [(0, 0)] * planes.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(planes, cfg, constant_values=pad_bit)


def pad_correction(k: int, n_w: int, n_x: int) -> int:
    """Additive correction for W-pad-bit=1 / X-pad-bit=0 K padding.

    Each padded k contributes ``(sum_i 2^i * (+1)) * (sum_j 2^j * (-1))
    = -(2^{n_w}-1)(2^{n_x}-1)`` to every output element; the true product
    is ``Y_raw + n_pad * (2^{n_w}-1)(2^{n_x}-1)``.
    """
    n_pad = (-k) % PACK_WIDTH
    return n_pad * max_value(n_w) * max_value(n_x)


# ---------------------------------------------------------------------------
# Quantized tensor container
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BipolarTensor:
    """A bipolar-INT quantized tensor in packed §4.1 layout.

    ``packed`` has shape ``(n_bits, *shape_with_K_packed)`` -- the n planes
    are concatenated on the leading axis (Fig. 3 step 3) with the reduction
    axis packed 32x into uint32 (step 2).  ``scale`` broadcasts against the
    dequantized tensor.

    ``width_scales`` (optional, ``(n_bits, *scale.shape)``) makes the
    tensor *nested*: row ``k-1`` is the clip-searched dequant scale for
    the top-``k`` plane prefix (:func:`nested_width_scales`), so one
    max-bit checkpoint serves every k <= n_bits via :func:`nested_slice`
    with no requantization.  Row ``n_bits-1`` equals ``scale``.
    """
    packed: jax.Array
    scale: jax.Array
    n_bits: int = dataclasses.field(metadata=dict(static=True))
    shape: tuple = dataclasses.field(metadata=dict(static=True))
    pack_axis: int = dataclasses.field(metadata=dict(static=True))
    width_scales: Optional[jax.Array] = None

    @property
    def nbytes_packed(self) -> int:
        return int(np.prod(self.packed.shape)) * 4 + int(np.prod(self.scale.shape)) * self.scale.dtype.itemsize

    @property
    def nbytes_dense_bf16(self) -> int:
        return int(np.prod(self.shape)) * 2


def quantize_pack(x: jax.Array, n_bits: int, pack_axis: int,
                  scale_axis=None, pad_bit: int = 1) -> BipolarTensor:
    """Real tensor -> packed bipolar-INT (quantize + decompose + pack).

    ``scale_axis``: axes reduced for the absmax scale (None = per-tensor).
    ``pad_bit``: 1 for weights (LHS), 0 for activations (RHS) -- see
    :func:`pad_correction`.
    """
    if scale_axis is None:
        scale = absmax_scale(x, n_bits)
    else:
        scale = absmax_scale(x, n_bits, axis=scale_axis, keepdims=True)
    q = quantize_values(x, n_bits, scale)
    planes = decompose(q, n_bits)
    planes = pad_for_packing(planes, pack_axis, pad_bit)
    packed = pack_planes(planes, pack_axis)
    return BipolarTensor(packed=packed, scale=scale.astype(jnp.float32),
                         n_bits=n_bits, shape=tuple(x.shape),
                         pack_axis=pack_axis if pack_axis >= 0 else x.ndim + pack_axis)


def nested_slice(t: BipolarTensor, k: int) -> BipolarTensor:
    """Top-``k`` plane prefix of a packed tensor as a k-bit tensor.

    :func:`decompose` puts bit ``i`` (LSB first) at plane index ``i``,
    so the k most-significant planes are the TRAILING k entries of the
    leading plane axis -- the slice ``packed[n_bits-k:]`` reinterpreted
    with ``n_bits=k`` is exactly the truncated integers of
    :func:`truncate_values`.  K-pad columns stay valid: a weight packed
    with pad bit 1 keeps bit 1 in every remaining plane, decoding to
    ``+max_value(k)``, which is what :func:`pad_correction` at the
    sliced widths assumes.  The dequant scale comes from
    ``width_scales`` when present (clip-searched per width), else the
    natural ``scale * 2^{n_bits-k}``; the sliced tensor keeps the first
    k width-scale rows (top-j of top-k == top-j of the original), so
    slicing composes.  Expects the plane axis leading (``packed`` as
    stored by :func:`quantize_pack` / ``ops.quantize_rows``; stacked
    per-layer weights are sliced after the scan peels their unit axis).
    """
    m = t.n_bits
    if k == m:
        return t
    if not 1 <= k < m:
        raise ValueError(f"nested slice width {k} outside [1, {m}]")
    drop = m - k
    if t.width_scales is not None:
        scale = t.width_scales[k - 1]
        ws = t.width_scales[:k]
    else:
        scale = t.scale * float(1 << drop)
        ws = None
    return dataclasses.replace(t, packed=t.packed[drop:], scale=scale,
                               width_scales=ws, n_bits=k)


def dequantize(t: BipolarTensor) -> jax.Array:
    """Packed bipolar-INT -> real tensor (float32)."""
    k = t.shape[t.pack_axis]
    planes = unpack_planes(t.packed, t.pack_axis, k)
    values = recover(planes, t.n_bits)
    return values.astype(jnp.float32) * t.scale
