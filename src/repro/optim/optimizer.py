"""Optimizers: AdamW with optionally int8-quantized moments, schedules.

No optax in this environment -- implemented from scratch as pure functions
over param pytrees.

``state_bits=8`` stores Adam's m/v in int8 with per-row (last-axis) f32
scales -- a *beyond-paper but in-theme* application of the paper's
bit-level storage idea to optimizer state.  It cuts optimizer HBM from
8 bytes/param to ~2.1, which is what lets the 398B Jamba train cell fit a
single v5e pod (DESIGN.md §6).  m is signed-symmetric (bipolar-style
symmetric absmax, no zero point); v is non-negative so it quantizes to
unsigned levels on the same grid.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def wsd_schedule(*, peak_lr: float, warmup_steps: int, total_steps: int,
                 decay_frac: float = 0.1, min_ratio: float = 0.01):
    """Warmup-Stable-Decay (MiniCPM, arXiv:2404.06395).

    Linear warmup -> flat stable phase -> sharp exponential-style decay on
    the final ``decay_frac`` of steps.
    """
    decay_steps = max(int(total_steps * decay_frac), 1)
    stable_end = total_steps - decay_steps

    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / jnp.maximum(warmup_steps, 1)
        decay_t = (step - stable_end) / decay_steps
        decay = jnp.power(jnp.asarray(min_ratio, jnp.float32),
                          jnp.clip(decay_t, 0.0, 1.0))
        r = jnp.where(step < warmup_steps, warm,
                      jnp.where(step < stable_end, 1.0, decay))
        return peak_lr * r

    return schedule


def cosine_schedule(*, peak_lr: float, warmup_steps: int, total_steps: int,
                    min_ratio: float = 0.1):
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / jnp.maximum(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps)
                     / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(np.pi * t))
        return peak_lr * jnp.where(step < warmup_steps, warm, cos)

    return schedule


# ---------------------------------------------------------------------------
# int8 moment quantization
# ---------------------------------------------------------------------------

def _q8(x: jax.Array, signed: bool):
    """f32 -> (int8 codes, f32 per-row scale). Rows = last axis.

    The second moment is quantized in the *sqrt domain*: v spans many
    orders of magnitude and a linear int8 grid collapses small entries to
    zero (1/sqrt(v) then explodes -> NaN); sqrt compresses the dynamic
    range enough that the f32 trajectory is tracked closely (see
    tests/test_train.py::test_int8_adamw_tracks_fp32).
    """
    xf = x.astype(jnp.float32)
    if not signed:                       # v >= 0: sqrt-domain codes
        xf = jnp.sqrt(xf)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127 if signed else 0, 127)
    return q.astype(jnp.int8), scale


def _dq8(q: jax.Array, scale: jax.Array, signed: bool):
    out = q.astype(jnp.float32) * scale
    return out if signed else jnp.square(out)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_bits: Optional[int] = None    # None = f32 moments, 8 = int8


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    m_scale: Any        # None when state_bits is None
    v_scale: Any


def adamw_init(params, cfg: AdamWConfig) -> AdamWState:
    def zeros_like_moment(p):
        if cfg.state_bits == 8:
            return jnp.zeros(p.shape, jnp.int8)
        return jnp.zeros(p.shape, jnp.float32)

    def zeros_scale(p):
        if cfg.state_bits == 8:
            return jnp.zeros(p.shape[:-1] + (1,), jnp.float32)
        return None

    m = jax.tree.map(zeros_like_moment, params)
    v = jax.tree.map(zeros_like_moment, params)
    ms = jax.tree.map(zeros_scale, params)
    vs = jax.tree.map(zeros_scale, params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=m, v=v,
                      m_scale=ms, v_scale=vs)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, state: AdamWState, params, *, lr,
                 cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, stats)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, ms, vs):
        g = g.astype(jnp.float32) * clip
        mf = _dq8(m, ms, signed=True) if cfg.state_bits == 8 else m
        vf = _dq8(v, vs, signed=False) if cfg.state_bits == 8 else v
        mf = cfg.b1 * mf + (1 - cfg.b1) * g
        vf = cfg.b2 * vf + (1 - cfg.b2) * jnp.square(g)
        mh = mf / bc1
        vh = vf / bc2
        pf = p.astype(jnp.float32)
        new_p = pf - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                           + cfg.weight_decay * pf)
        if cfg.state_bits == 8:
            m8, ms8 = _q8(mf, signed=True)
            v8, vs8 = _q8(vf, signed=False)
            return new_p.astype(p.dtype), m8, v8, ms8, vs8
        return new_p.astype(p.dtype), mf, vf, None, None

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    if cfg.state_bits == 8:
        flat_ms = treedef.flatten_up_to(state.m_scale)
        flat_vs = treedef.flatten_up_to(state.v_scale)
    else:
        flat_ms = [None] * len(flat_p)
        flat_vs = [None] * len(flat_p)

    out = [upd(p, g, m, v, ms, vs) for p, g, m, v, ms, vs
           in zip(flat_p, flat_g, flat_m, flat_v, flat_ms, flat_vs)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    if cfg.state_bits == 8:
        new_ms = jax.tree.unflatten(treedef, [o[3] for o in out])
        new_vs = jax.tree.unflatten(treedef, [o[4] for o in out])
    else:
        new_ms, new_vs = None, None
    new_state = AdamWState(step=step, m=new_m, v=new_v,
                           m_scale=new_ms, v_scale=new_vs)
    return new_p, new_state, {"grad_norm": gnorm}
