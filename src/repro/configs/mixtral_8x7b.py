"""Mixtral-8x7B [arXiv:2401.04088; hf] -- MoE 8e top-2 + sliding window.

32L d_model=4096 32H (kv=8) d_ff=14336 vocab=32000.  Every FFN is MoE
(8 experts, top-2).  Sliding-window attention (window 4096) => decode
cost is context-independent: the KV cache is a 4096-slot ring, so
long_500k runs.  8 experts < model-axis 16 => EP off, experts are
TP-sharded on d_ff (DESIGN.md §6).
"""

from repro.models.config import ModelConfig, QuantConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    window=4096,
    n_experts=8,
    top_k=2,
    expert_d_ff=14336,
    quant=QuantConfig(w_bits=2, a_bits=8, kv_bits=8),
    max_seq_len=1048576,
)
