"""Assigned architecture configs (public literature; see each file's source).

``get_config(name)`` returns the full-scale :class:`ModelConfig`;
``get_config(name).reduced()`` the CPU smoke-test variant.
"""

from importlib import import_module

ARCHS = (
    "minicpm-2b",
    "stablelm-3b",
    "glm4-9b",
    "llama3-8b",
    "mamba2-130m",
    "jamba-1.5-large-398b",
    "qwen2-vl-7b",
    "deepseek-moe-16b",
    "mixtral-8x7b",
    "seamless-m4t-medium",
)


def get_config(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    mod = import_module(f"repro.configs.{name.replace('-', '_').replace('.', '_')}")
    return mod.CONFIG
