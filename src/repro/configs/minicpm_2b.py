"""MiniCPM-2B [arXiv:2404.06395; hf] -- dense llama-like, WSD schedule.

40L d_model=2304 36H (kv=36, i.e. MHA) d_ff=5760 vocab=122753.
MiniCPM specifics: embedding scale 12, depth-scaled residuals
(1.4/sqrt(L)), logits scaled by d_model/256 (dim_model_base).
Trains with the WSD (warmup-stable-decay) schedule -> optim.wsd_schedule.
"""

import numpy as np

from repro.models.config import ModelConfig, QuantConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab=122753,
    tie_embeddings=True,
    emb_scale=12.0,
    residual_scale=float(1.4 / np.sqrt(40)),
    logit_scale=256.0 / 2304.0,
    rope_theta=10000.0,
    quant=QuantConfig(w_bits=2, a_bits=8),
    max_seq_len=524288,
)
