"""Mamba2-130M [arXiv:2405.21060; unverified] -- attention-free SSM (SSD).

24L d_model=768, ssm_state=128, expand 2 (d_inner 1536), headdim 64
(24 SSM heads), 1 group, conv window 4, vocab 50280 (GPT-NeoX tok).
Sub-quadratic: long_500k decode is an O(1) state update.
Parameters are small (130M) => no tensor parallelism (DESIGN.md §6);
the model axis shards activations/sequence only.
"""

from repro.models.config import ModelConfig, QuantConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=12,          # unused (attention-free); kept for config uniformity
    n_kv_heads=12,
    d_ff=0,
    vocab=50280,
    tie_embeddings=True,
    ssm_d_state=128,
    ssm_d_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_n_groups=1,
    ssm_chunk=128,
    quant=QuantConfig(w_bits=4, a_bits=8),
    max_seq_len=1048576,
)
