"""Qwen2-VL-7B [arXiv:2409.12191; hf] -- VLM backbone, M-RoPE.

28L d_model=3584 28H (kv=4) d_ff=18944 vocab=152064.  M-RoPE: rotary
position split into (temporal, height, width) sections (16, 24, 24) over
the 128-dim head half.  Per task spec the vision frontend is a STUB:
``input_specs()`` provides precomputed patch embeddings (B, n_patches,
d_model) fused into the leading token slots, plus (3, B, S) position ids.
"""

from repro.models.config import ModelConfig, QuantConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    rope_theta=1000000.0,
    mrope_sections=(16, 24, 24),
    n_patches=1024,
    quant=QuantConfig(w_bits=2, a_bits=8),
    max_seq_len=524288,
)
