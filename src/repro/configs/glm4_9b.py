"""GLM-4-9B [hf:THUDM/glm-4-9b; hf] -- dense, extreme GQA (kv=2), RoPE.

40L d_model=4096 32H (kv=2) d_ff=13696 vocab=151552.  Partial rotary
(half dims), RMSNorm, SwiGLU.  kv=2 < model-axis 16 => KV projections
replicate across TP subgroups (DESIGN.md §6).
"""

from repro.models.config import ModelConfig, QuantConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=151552,
    rope_pct=0.5,
    rope_theta=10000.0,
    quant=QuantConfig(w_bits=2, a_bits=8),
    max_seq_len=524288,
)
