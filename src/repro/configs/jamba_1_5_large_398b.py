"""Jamba-1.5-Large 398B [arXiv:2403.19887; hf] -- hybrid Mamba+attn, MoE.

72L d_model=8192, attention every 8th layer (1:7 attn:mamba interleave),
64H (kv=8) d_ff=24576, MoE 16 experts top-2 applied every other layer,
vocab 65536.  Mamba sublayers: d_inner 16384, state 128, headdim 128
(128 SSM heads), 8 groups.  Scan unit = the 8-layer hybrid group.
long_500k runs: 9 attention layers see the full KV; 63 mamba layers are
O(1) state updates.
"""

from repro.models.config import ModelConfig, QuantConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    attn_every=8,
    n_experts=16,
    top_k=2,
    expert_d_ff=24576,
    moe_every=2,
    ssm_d_state=128,
    ssm_d_conv=4,
    ssm_expand=2,
    ssm_head_dim=128,
    ssm_n_groups=8,
    ssm_chunk=128,
    quant=QuantConfig(w_bits=2, a_bits=8),
    max_seq_len=1048576,
)
