"""Llama-3-8B [arXiv:2407.21783; unverified] -- dense GQA, 128k vocab.

32L d_model=4096 32H (kv=8) d_ff=14336 vocab=128256, rope theta 500k.
The 128k vocab exercises vocab-sharded embeddings + chunked CE loss.
"""

from repro.models.config import ModelConfig, QuantConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    rope_theta=500000.0,
    quant=QuantConfig(w_bits=2, a_bits=8, kv_bits=8),
    max_seq_len=524288,
)
