"""StableLM-2 [hf:stabilityai/stablelm-2-1_6b; unverified] -- dense.

32L d_model=2560 32H (kv=32, MHA) d_ff=6912 vocab=50304.
StableLM-2 family traits: partial rotary (25%), LayerNorm, SwiGLU.
"""

from repro.models.config import ModelConfig, QuantConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab=50304,
    norm_type="layernorm",
    rope_pct=0.25,
    rope_theta=10000.0,
    quant=QuantConfig(w_bits=3, a_bits=8),
    max_seq_len=524288,
)
