"""DeepSeekMoE-16B [arXiv:2401.06066; hf] -- fine-grained MoE.

28L d_model=2048 16H (kv=16, MHA) vocab=102400.  MoE: 2 shared + 64
routed experts top-6, fine-grained expert d_ff=1408 (dense-equivalent
d_ff = 10944).  Layer 0 keeps a dense FFN (d_ff 10944) -- modeled as the
unrolled prelude; layers 1-27 are MoE.
"""

from repro.models.config import ModelConfig, QuantConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,
    vocab=102400,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    expert_d_ff=1408,
    first_dense=1,
    quant=QuantConfig(w_bits=3, a_bits=8),
    max_seq_len=524288,
)
