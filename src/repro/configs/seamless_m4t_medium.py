"""SeamlessM4T-medium [arXiv:2308.11596; hf] -- enc-dec, multimodal audio.

12+12L d_model=1024 16H (kv=16, MHA) d_ff=4096 vocab=256206.
Encoder consumes STUB frame embeddings (precomputed speech frontend per
task spec); decoder is causal with cross-attention.  Decode shapes run
(enc-dec, not encoder-only).  long_500k skipped: full attention.
"""

from repro.models.config import ModelConfig, QuantConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    frontend_dim=1024,
    act="gelu",
    norm_type="layernorm",
    quant=QuantConfig(w_bits=4, a_bits=8),
    max_seq_len=524288,
)
