"""int8 gradient-compressed data-parallel all-reduce (shard_map).

A distributed-optimization trick in the paper's bit-level spirit: before
the DP all-reduce, each gradient leaf is quantized to int8 with a shared
symmetric absmax scale (bipolar-style, no zero point), summed on the wire
in int32, and dequantized -- cutting DP all-reduce bytes 4x vs f32 (2x vs
bf16).  Two small collectives replace one big one:

    scale = psum_max(|g|) / 127        (f32 scalars per leaf)
    g_sum = psum(int32(round(g / scale)))
    g_avg = g_sum * scale / n_devices

Used as the ``grad_transform`` hook of a shard_map DP training step
(:func:`dp_train_step`); the pjit/FSDP path keeps XLA-inserted reduces
(compression there would need custom XLA passes -- recorded in DESIGN.md).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.kernels.compat import shard_map


def compressed_psum(tree, axis_name: str, *, bits: int = 8):
    """int-quantized mean-psum of a gradient tree over ``axis_name``.

    Must be called inside shard_map/pmap.  int32 wire sum is exact for
    <= 2^(31-bits) devices.
    """
    assert bits == 8, "int8 is the supported wire format"
    n = jax.lax.psum(1.0, axis_name)

    def one(g):
        gf = g.astype(jnp.float32)
        amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name)
        scale = jnp.maximum(amax, 1e-30) / 127.0
        q = jnp.round(gf / scale).astype(jnp.int32)   # int8 codes, int32 wire
        s = jax.lax.psum(q, axis_name)
        return (s.astype(jnp.float32) * scale / n).astype(g.dtype)

    return jax.tree.map(one, tree)


def dp_train_step(loss_fn, mesh: Mesh, *, axis_name: str = "data",
                  compress: bool = True):
    """Build a pure-DP shard_map step: params replicated, batch sharded,
    grads all-reduced (optionally int8-compressed).

    Returns ``step(params, batch) -> (loss, grads)`` -- optimizer update
    is applied outside (identically on every shard).
    """
    def local(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        loss = jax.lax.pmean(loss, axis_name)
        if compress:
            grads = compressed_psum(grads, axis_name)
        else:
            grads = jax.lax.pmean(grads, axis_name)
        return loss, grads

    pspec = P()          # params replicated
    bspec = P(axis_name)  # batch sharded on leading dim

    return shard_map(
        local, mesh=mesh,
        in_specs=(pspec, bspec),
        out_specs=(pspec, pspec),
        check_vma=False)
