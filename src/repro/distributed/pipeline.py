"""GPipe-style pipeline parallelism over a mesh axis (shard_map).

For deployments that prefer pod-level PP over pure DP across pods
(DESIGN.md §5): the layer stack is split into ``n_stages`` contiguous
stages, microbatches stream through with ``collective_permute`` hops, and
the bubble is the standard (S-1)/(M+S-1) GPipe bubble.

This is the *collective pattern* proof (tested on a host mesh); wiring it
to the full LM stack is a config choice (`pod` axis as the stage axis).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels.compat import shard_map


def pipeline_apply(stage_fn, n_stages: int, n_micro: int, axis: str = "pipe"):
    """Build a pipelined forward: ``f(stage_params, x) -> y``.

    ``stage_params``: leaves with leading dim ``n_stages`` (sharded over
    ``axis``); ``x``: (n_micro, micro_batch, ...) activations entering
    stage 0.  Inside shard_map each device holds ONE stage's params and
    runs the classic skewed schedule: at tick t it processes microbatch
    ``t - stage`` (when in range) and permutes its output to stage+1.
    """

    def per_stage(params, x):
        # params: (1, ...) local slice -> squeeze; x: (n_micro, mb, ...)
        params = jax.tree.map(lambda a: a[0], params)
        stage = jax.lax.axis_index(axis)
        mb = x[0]
        buf = jnp.zeros_like(mb)                 # activation in flight
        outs = jnp.zeros_like(x)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t from its local input
            inject = jnp.where(t < x.shape[0], t, 0)
            buf = jnp.where(stage == 0, x[inject], buf)
            m_idx = t - stage                     # microbatch at this stage
            active = (m_idx >= 0) & (m_idx < x.shape[0])
            y = stage_fn(params, buf)
            y = jnp.where(active, y, buf)
            # last stage collects its finished microbatch
            outs = jax.lax.cond(
                active & (stage == n_stages - 1),
                lambda o: o.at[jnp.clip(m_idx, 0, x.shape[0] - 1)].set(y),
                lambda o: o, outs)
            # ring-shift activations to the next stage
            buf = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (buf, outs), None

        ticks = jnp.arange(n_micro + n_stages - 1)
        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), ticks)
        # every device returns outs; only the last stage's is meaningful --
        # psum so the result is replicated (cheap at toy scale; a real
        # deployment would leave it stage-local)
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs

    def run(mesh: Mesh, stage_params, x):
        f = shard_map(
            per_stage, mesh=mesh,
            in_specs=(P(axis), P()),
            out_specs=P(),
            check_vma=False)
        return f(stage_params, x)

    return run
