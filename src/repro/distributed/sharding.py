"""Sharding rules: DP / FSDP / TP / EP / SP specs for every tree.

Strategy (DESIGN.md §5):
* weights: TP ("model") on the head/ffn/vocab dimension + FSDP ("data") on
  the other matrix dimension -- XLA inserts the per-use all-gather
  (ZeRO-3 style).  Column/row pairing (wq/wk/wv/w_up/w_gate column,
  wo/w_down row) keeps one reduce per residual write.
* MoE experts: EP on the expert dim when divisible by the model axis,
  else TP on d_ff (mixtral's 8 < 16, DESIGN.md §6).
* activations: the scanned residual stream is sequence-sharded over
  "model" between blocks (Megatron-SP analogue) -- applied by the model
  via :func:`constrain` -- and batch-sharded over the DP axes.
* packed bipolar weights (serving): same rules -- the plane axis rides as
  a leading dim, the packed-word axis inherits the FSDP ("data") shard.
* every sharded dim is divisibility-checked; non-dividing axes fall back
  to replication (e.g. mamba2-130m's 3352-row in_proj -> DP-only,
  DESIGN.md §6).

Rules are *suffix-aligned*: a candidate spec binds to the trailing dims of
the leaf, so scan-stack / bit-plane / expert prefixes are automatically
unsharded unless the rule names them.
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _key_str(p):
    for attr in ("key", "name", "idx"):
        v = getattr(p, attr, None)
        if v is not None:
            return v if isinstance(v, str) else None
    return None


def _axes_size(mesh, axis):
    if axis is None:
        return 1
    axes = axis if isinstance(axis, tuple) else (axis,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fit(mesh, shape, spec_axes) -> P:
    """Suffix-align a candidate spec to ``shape`` and drop axes that do not
    divide their dim."""
    spec_axes = tuple(spec_axes)
    if len(spec_axes) > len(shape):
        spec_axes = spec_axes[len(spec_axes) - len(shape):]
    full = (None,) * (len(shape) - len(spec_axes)) + spec_axes
    fixed = [ax if ax is not None and dim % _axes_size(mesh, ax) == 0
             else None
             for dim, ax in zip(shape, full)]
    return P(*fixed)


def _dp_axis(mesh):
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return dp if len(dp) > 1 else dp[0]


# ---------------------------------------------------------------------------
# activation-sharding context (used by model code via `constrain`)
# ---------------------------------------------------------------------------

_CTX: dict = {"mesh": None, "rules": {}}
_MOE_MODE = "ep"   # "ep": experts over model axis | "tp": d_ff over model
                   # (tp avoids token resharding through the dispatch
                   # scatter -- hillclimb lever, EXPERIMENTS.md §Perf)


def set_moe_mode(mode: str):
    global _MOE_MODE
    assert mode in ("ep", "tp")
    _MOE_MODE = mode


def set_activation_context(mesh: Optional[Mesh],
                           rules: Optional[dict] = None,
                           extra=()):
    """Install the mesh + activation specs the model constrains to.

    ``rules``: name -> PartitionSpec.  ``None`` mesh disables constraints
    (single-device tests).  ``extra``: names of opt-in hillclimb rules
    (e.g. "attn_chunks")."""
    _CTX["mesh"] = mesh
    _CTX["rules"] = rules if rules is not None else (
        default_activation_rules(mesh, extra) if mesh is not None else {})


def constrain(x, name: str):
    """Apply a named activation constraint if a context is installed."""
    mesh, rules = _CTX["mesh"], _CTX["rules"]
    if mesh is None or name not in rules:
        return x
    spec = _fit(mesh, x.shape, tuple(rules[name]))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def default_activation_rules(mesh, extra=()) -> dict:
    dp = _dp_axis(mesh)
    rules = {
        # residual stream between blocks: batch over DP, sequence over
        # model (Megatron-SP analogue; bounds the remat stash per chip)
        "residual": P(dp, "model", None),
        # grouped MoE dispatch buffer (G, E, C, d): token groups over DP
        # (local capacity), experts over model in EP mode
        "moe_dispatch": (P(dp, "model", None, None) if _MOE_MODE == "ep"
                         else P(dp, None, None, None)),
        # combine side: expert outputs resharded token-local (G over DP,
        # E replicated) so the per-token gather needs no model-axis
        # all-gather -- the reshard itself is an all-to-all
        "moe_combine": P(dp, None, None, "model"),
    }
    if "attn_chunks" in extra:
        # stacked KV chunks (nc, B, Hkv, ck, D) in the online-softmax scan:
        # keep the chunk axis UNSHARDED so per-iteration dynamic-slice does
        # not reshard (kills the involuntary-full-remat copies)
        rules["attn_chunks"] = P(None, dp, "model", None, None)
    return rules


# ---------------------------------------------------------------------------
# parameter sharding rules
# ---------------------------------------------------------------------------

# column-parallel: d_out on model, d_in(/packed words) on data (FSDP)
_COL = ("wq", "wk", "wv", "w_up", "w_gate", "in_proj", "lm_head", "frontend",
        "embed")
# row-parallel: d_in on model, d_out on data
_ROW = ("wo", "w_down", "out_proj")
_SKIP_NAMES = ("w", "packed", "scale", "blocks", "prelude", "mixer", "ffn",
               "attn", "shared", "encoder", "cross")


def _param_spec(mesh, path_keys, shape) -> P:
    name = next((k for k in reversed(path_keys)
                 if k is not None and k not in _SKIP_NAMES), None)
    nd = len(shape)
    if name == "router" or nd <= 1:
        return P(*([None] * nd))
    moe_expert = path_keys and any(
        k in ("w_up", "w_gate", "w_down") for k in path_keys if k) \
        and nd >= 3 and name not in ("shared",)
    is_shared = "shared" in [k for k in path_keys if k]
    if moe_expert and not is_shared:
        # trailing dims (E, d_out, d_in[/Kw]); EP on E when divisible
        if _MOE_MODE == "ep" and shape[-3] % mesh.shape["model"] == 0:
            return _fit(mesh, shape, ("model", None, "data"))
        if name in ("w_up", "w_gate"):
            return _fit(mesh, shape, (None, "model", "data"))
        return _fit(mesh, shape, (None, "data", "model"))
    if name in _COL:
        return _fit(mesh, shape, ("model", "data"))
    if name in _ROW:
        return _fit(mesh, shape, ("data", "model"))
    if name == "conv_w":
        return _fit(mesh, shape, (None, "model"))
    return P(*([None] * nd))


def shardings_for_params(mesh: Mesh, params):
    """NamedSharding tree for params (also fits optimizer moments/scales:
    map over the moment tree -- same structure, same trailing dims)."""
    def spec_of(path, leaf):
        keys = [_key_str(p) for p in path]
        return NamedSharding(mesh, _param_spec(mesh, keys,
                                               getattr(leaf, "shape", ())))

    return jax.tree_util.tree_map_with_path(spec_of, params)


# ---------------------------------------------------------------------------
# batch / cache shardings
# ---------------------------------------------------------------------------

def shardings_for_batch(mesh: Mesh, batch):
    dp = _dp_axis(mesh)

    def spec_of(path, leaf):
        keys = [_key_str(p) for p in path]
        shape = leaf.shape
        if keys and keys[-1] == "positions" and len(shape) == 3:
            # M-RoPE ids (3, B, S)
            return NamedSharding(mesh, _fit(mesh, shape, (None, dp, None)))
        cand = (dp,) + (None,) * (max(len(shape) - 1, 0))
        # prefix-aligned: batch is the leading dim
        full = cand[:len(shape)]
        fixed = [ax if ax is not None and d % _axes_size(mesh, ax) == 0
                 else None for d, ax in zip(shape, full)]
        return NamedSharding(mesh, P(*fixed))

    return jax.tree_util.tree_map_with_path(spec_of, batch)


# expected trailing layouts per cache leaf name
_CACHE_RULES = {
    "k": ("__dp__", "model", None, None),      # (B, L, Hkv, Dh): L is SP-
    "v": ("__dp__", "model", None, None),      # sharded for long contexts
    "k_scale": ("__dp__", "model", None, None),
    "v_scale": ("__dp__", "model", None, None),
    "pos": ("__dp__", "model"),
    "index": ("__dp__",),
    "state": ("__dp__", "model", None, None),  # (B, H, P, N)
    "conv": ("__dp__", None, "model"),         # (B, w, conv_dim)
}


def shardings_for_caches(mesh: Mesh, caches):
    dp = _dp_axis(mesh)

    def spec_of(path, leaf):
        keys = [_key_str(p) for p in path]
        name = next((k for k in reversed(keys) if k), "")
        rule = _CACHE_RULES.get(name, ("__dp__",))
        rule = tuple(dp if r == "__dp__" else r for r in rule)
        if name in ("k", "v") and getattr(leaf, "dtype", None) == np.uint32:
            # packed bipolar KV planes carry a trailing (kv_bits, D/32)
            # pair instead of D: extend the rule so suffix alignment keeps
            # (B, L) on (dp, model) for both plain and (n_units,)-stacked
            rule = rule + (None,)
        shape = leaf.shape
        # suffix-align so stacked (n_units, ...) caches work, but keep the
        # batch axis aligned to its true position: pad on the LEFT only by
        # the stacking prefix (ndim - len(rule)).
        return NamedSharding(mesh, _fit(mesh, shape, rule))

    return jax.tree_util.tree_map_with_path(spec_of, caches)


def replicated(mesh: Mesh, tree):
    return jax.tree.map(lambda leaf: NamedSharding(mesh, P()), tree)
