"""Input specs for every (architecture x input-shape) cell.

``input_specs(cfg, shape, mode)`` returns ``ShapeDtypeStruct`` stand-ins
(weak-type-correct, shardable, no device allocation) for AOT lowering;
``make_batch`` materializes small real batches for CPU smoke tests.

Shape registry (task spec):
  train_4k     seq 4096,   global_batch 256   -> train_step
  prefill_32k  seq 32768,  global_batch 32    -> prefill_step
  decode_32k   seq 32768,  global_batch 128   -> serve_step (1 new token,
                                                KV cache of seq length)
  long_500k    seq 524288, global_batch 1     -> serve_step; requires
                                                sub-quadratic attention
Modality frontends are STUBS: audio provides precomputed frame
embeddings, vlm precomputed patch embeddings (+ 3-axis M-RoPE ids).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, mode="train"),
    "prefill_32k": dict(seq=32768, batch=32, mode="prefill"),
    "decode_32k": dict(seq=32768, batch=128, mode="decode"),
    "long_500k": dict(seq=524288, batch=1, mode="decode"),
}


def cell_runnable(cfg: ModelConfig, shape_name: str):
    """-> (runnable, reason).  long_500k needs sub-quadratic attention."""
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return False, ("full quadratic attention; 500k-token decode "
                       "requires SSM/hybrid/sliding-window (DESIGN.md §4)")
    return True, ""


def enc_len(cfg: ModelConfig, seq: int) -> int:
    """Stub audio-encoder frame count for a given decoder length."""
    return min(max(seq // 8, 64), 4096)


def _token_specs(cfg: ModelConfig, batch: int, seq: int, mode: str) -> dict:
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    s = seq if mode != "decode" else 1
    specs = {"tokens": jax.ShapeDtypeStruct((batch, s), i32)}
    if mode == "train":
        specs["labels"] = jax.ShapeDtypeStruct((batch, s), i32)
    if cfg.family == "vlm":
        npt = min(cfg.n_patches, s)
        specs["positions"] = jax.ShapeDtypeStruct((3, batch, s), i32)
        if mode != "decode":
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (batch, npt, cfg.d_model), dt)
    if cfg.family == "audio" and mode != "decode":
        specs["frames"] = jax.ShapeDtypeStruct(
            (batch, enc_len(cfg, seq), cfg.frontend_dim), dt)
    if mode == "decode":
        specs["positions"] = (
            jax.ShapeDtypeStruct((3, batch, 1), i32) if cfg.family == "vlm"
            else jax.ShapeDtypeStruct((batch, 1), i32))
    return specs


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """AOT-lowering input specs for one shape cell."""
    sh = SHAPES[shape_name]
    return _token_specs(cfg, sh["batch"], sh["seq"], sh["mode"])


def make_batch(cfg: ModelConfig, batch: int, seq: int, mode: str = "train",
               seed: int = 0) -> dict:
    """Materialize a random batch matching the spec (CPU smoke tests)."""
    rng = np.random.default_rng(seed)
    specs = _token_specs(cfg, batch, seq, mode)
    out = {}
    for k, spec in specs.items():
        if spec.dtype == jnp.int32:
            if k == "positions":
                base = np.arange(spec.shape[-1], dtype=np.int32)
                out[k] = jnp.broadcast_to(base, spec.shape)
            else:
                out[k] = jnp.array(rng.integers(0, cfg.vocab, spec.shape,
                                                dtype=np.int32))
        else:
            out[k] = jnp.array(
                rng.standard_normal(spec.shape).astype(np.float32) * 0.1
            ).astype(spec.dtype)
    return out
