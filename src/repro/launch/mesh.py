"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state -- the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init and only then builds the mesh.

Axes:
  single pod (v5e-256):  (data=16, model=16)
  multi-pod  (2 pods):   (pod=2, data=16, model=16)

``pod`` is an outer data-parallel axis (per-pod DCN-connected replicas);
``data`` carries batch + FSDP weight sharding; ``model`` carries
TP/EP/SP (DESIGN.md §5).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: int = 4, n_model: int = 2):
    """Small mesh over host platform devices (tests)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """The data-parallel (batch) axes of a mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
