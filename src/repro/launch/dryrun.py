"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

MUST set the 512-placeholder-device flag before ANY other import (jax
locks device count on first init).
"""

import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from functools import partial  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCHS, get_config                     # noqa: E402
from repro.distributed import sharding as S                     # noqa: E402
from repro.launch.mesh import make_production_mesh              # noqa: E402
from repro.launch.specs import SHAPES, cell_runnable, input_specs  # noqa: E402
from repro.models import model as M                             # noqa: E402
from repro.models.config import QuantConfig                     # noqa: E402
from repro.optim.optimizer import (AdamWConfig, adamw_init,      # noqa: E402
                                   adamw_update, wsd_schedule)
from repro.serving import engine as E                           # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                "..", "..", ".."))
from benchmarks import hlo_analysis as H                        # noqa: E402

OUT_DIR = os.environ.get("DRYRUN_OUT", "/root/repo/experiments/dryrun")


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def _train_cell(cfg, mesh, specs, quant_override=None):
    """Lower train_step: fwd+bwd+AdamW(int8 state), donated state."""
    adamw = AdamWConfig(state_bits=8)
    sched = wsd_schedule(peak_lr=3e-4, warmup_steps=2000, total_steps=100000)

    params = jax.eval_shape(partial(M.init_params, cfg),
                            jax.random.PRNGKey(0))
    opt = jax.eval_shape(partial(adamw_init, cfg=adamw), params)

    def train_step(params, opt, batch):
        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(p, batch, cfg))(params)
        lr = sched(opt.step)
        params, opt, stats = adamw_update(grads, opt, params, lr=lr,
                                          cfg=adamw)
        return params, opt, loss

    psh = S.shardings_for_params(mesh, params)
    osh = type(opt)(
        step=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        m=S.shardings_for_params(mesh, opt.m),
        v=S.shardings_for_params(mesh, opt.v),
        m_scale=S.shardings_for_params(mesh, opt.m_scale),
        v_scale=S.shardings_for_params(mesh, opt.v_scale))
    bsh = S.shardings_for_batch(mesh, specs)
    rsh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    fn = jax.jit(train_step,
                 in_shardings=(psh, osh, bsh),
                 out_shardings=(psh, osh, rsh),
                 donate_argnums=(0, 1))
    return fn.lower(params, opt, specs), params


def _serve_cell(cfg, mesh, specs, shape, mode):
    """Lower prefill_step / serve_step with quantized packed weights."""
    quant = cfg.quant
    params = jax.eval_shape(partial(M.init_params, cfg),
                            jax.random.PRNGKey(0))
    qparams = jax.eval_shape(partial(M.quantize_params, qcfg=quant), params)
    seq, batch = shape["seq"], shape["batch"]
    caches = jax.eval_shape(
        partial(M.init_caches, cfg, batch, seq, quant=quant))

    def step(params, batch_in, caches):
        if mode == "prefill":
            return E.prefill_step.__wrapped__(params, batch_in, caches, cfg,
                                              quant)
        return E.serve_step.__wrapped__(params, batch_in, caches, cfg, quant)

    psh = S.shardings_for_params(mesh, qparams)
    bsh = S.shardings_for_batch(mesh, specs)
    csh = S.shardings_for_caches(mesh, caches)
    # logits (B, V): batch over DP where divisible (not for batch=1
    # long-context decode), vocab over model
    logits_sh = jax.sharding.NamedSharding(
        mesh, S._fit(mesh, (batch, cfg.vocab_padded),
                     (S._dp_axis(mesh), "model")))
    fn = jax.jit(step, in_shardings=(psh, bsh, csh),
                 out_shardings=(logits_sh, csh),
                 donate_argnums=(2,))
    return fn.lower(qparams, specs, caches), qparams


# ---------------------------------------------------------------------------
# per-cell runner
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = OUT_DIR, quiet: bool = False,
             opts: tuple = ()) -> dict:
    """opts (hillclimb levers, EXPERIMENTS.md §Perf):
       moe_tp      -- MoE experts TP-sharded on d_ff instead of EP
       attn_chunks -- pin the KV-chunk scan axis unsharded
       kv8         -- 8-bit bipolar KV cache
       bf16serve   -- disable weight quantization (paper FP baseline)
       bitserial   -- paper-faithful bit-serial APMM variant
    """
    import dataclasses as _dc
    from repro.models.config import QuantConfig as _QC
    cfg = get_config(arch)
    # the kv8 lever must stay a real A/B even though some shipped configs
    # default QuantConfig.kv_bits=8: cells pin the KV format explicitly
    kv = 8 if "kv8" in opts else None
    cfg = _dc.replace(cfg, kv_bits=kv,
                      quant=_dc.replace(cfg.quant, kv_bits=kv))
    if "bf16serve" in opts:
        cfg = _dc.replace(cfg, quant=_QC(w_bits=None))
    if "bitserial" in opts:
        cfg = _dc.replace(cfg, quant=_dc.replace(cfg.quant,
                                                 variant="bitserial"))
    if "attn_bf16" in opts:
        cfg = _dc.replace(cfg, attn_score_bf16=True)
    S.set_moe_mode("tp" if "moe_tp" in opts else "ep")
    shape = SHAPES[shape_name]
    mesh_tag = "pod512" if multi_pod else "pod256"
    cell_id = f"{arch}__{shape_name}__{mesh_tag}"
    if opts:
        cell_id += "__opt-" + "-".join(sorted(opts))
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, cell_id + ".json")

    ok, reason = cell_runnable(cfg, shape_name)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "opts": list(opts),
        "mode": shape["mode"], "seq": shape["seq"], "batch": shape["batch"],
        "n_chips": 512 if multi_pod else 256,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "w_bits": cfg.quant.w_bits, "a_bits": cfg.quant.a_bits,
    }
    if not ok:
        rec.update(status="skipped", reason=reason)
        json.dump(rec, open(out_path, "w"), indent=1)
        return rec

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        S.set_activation_context(
            mesh, extra=("attn_chunks",) if "attn_chunks" in opts else ())
        specs = input_specs(cfg, shape_name)
        if shape["mode"] == "train":
            lowered, _ = _train_cell(cfg, mesh, specs)
        else:
            lowered, _ = _serve_cell(cfg, mesh, specs, shape, shape["mode"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        ca = H.xla_cost_analysis(compiled)
        hlo = H.analyze(compiled.as_text())
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            memory=dict(
                argument_bytes=int(ma.argument_size_in_bytes),
                output_bytes=int(ma.output_size_in_bytes),
                temp_bytes=int(ma.temp_size_in_bytes),
                peak_bytes=int(ma.peak_memory_in_bytes),
                alias_bytes=int(ma.alias_size_in_bytes),
            ),
            cost_analysis=dict(
                flops=float(ca.get("flops", 0)),
                bytes_accessed=float(ca.get("bytes accessed", 0)),
            ),
            hlo=dict(
                dot_flops=float(hlo.get("dot_flops", 0)),
                dot_flops_int=float(hlo.get("dot_flops_int", 0)),
                dot_flops_f32=float(hlo.get("dot_flops_f32", 0)),
                dot_flops_bf16=float(hlo.get("dot_flops_bf16", 0)),
                bytes=float(hlo.get("bytes", 0)),
                collective_bytes=float(hlo.get("collective_bytes", 0)),
                n_collective_ops=int(hlo.get("n_collective_ops", 0)),
                collectives={k: float(v)
                             for k, v in hlo.get("collectives", {}).items()},
                top_ops=[dict(name=o["name"][-120:], opcode=o["opcode"],
                              bytes=float(o["bytes"]),
                              flops=float(o["flops"]))
                         for o in hlo.get("top_ops", [])],
            ),
        )
    except Exception as e:  # noqa: BLE001 -- a cell failure is a bug report
        rec.update(status="failed", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    json.dump(rec, open(out_path, "w"), indent=1)
    if not quiet:
        peak = rec.get("memory", {}).get("peak_bytes", 0) / 2**30
        print(f"[{cell_id}] {rec['status']} "
              f"peak={peak:.2f}GiB "
              f"compile={rec.get('compile_s', 0)}s "
              f"{rec.get('error', '')}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="shape name or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--force", action="store_true",
                    help="re-run cells with existing results")
    ap.add_argument("--opt", default="",
                    help="comma-separated hillclimb levers "
                         "(moe_tp,attn_chunks,kv8,bf16serve,bitserial)")
    args = ap.parse_args()
    opts = tuple(o for o in args.opt.split(",") if o)

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = 0
    for multi in meshes:
        for arch in archs:
            for shape in shapes:
                tag = "pod512" if multi else "pod256"
                name = f"{arch}__{shape}__{tag}"
                if opts:
                    name += "__opt-" + "-".join(sorted(opts))
                p = os.path.join(args.out, name + ".json")
                if os.path.exists(p) and not args.force:
                    rec = json.load(open(p))
                    if rec.get("status") in ("ok", "skipped"):
                        continue
                rec = run_cell(arch, shape, multi, args.out, opts=opts)
                failures += rec["status"] == "failed"
    print(f"dry-run sweep done, failures={failures}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
