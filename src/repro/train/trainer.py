"""Fault-tolerant training driver.

Features (task spec "large-scale runnability"):
* checkpoint/restart: periodic async atomic checkpoints; ``run(resume=True)``
  restores the latest complete checkpoint and -- because the data pipeline
  is stateless (step -> batch) -- replays the exact token stream, making
  restarts bit-reproducible (verified in tests/test_train.py).
* preemption simulation: ``preempt_at=N`` raises after step N, mimicking a
  spot eviction; tests restart and check loss-curve continuity.
* straggler watchdog: per-step wall time vs rolling median; slow steps
  (> watchdog_factor x median) are recorded and surfaced -- the hook a
  cluster agent would use to trigger hot-spare replacement.
* gradient accumulation: ``microbatches=A`` scans A microbatches before the
  optimizer step (same math, 1/A activation memory).
* optional distributed hooks: a ``grad_transform`` (e.g. the int8
  compressed DP all-reduce from repro.distributed) applied between grad
  computation and the optimizer.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataSpec, batch_at
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   cosine_schedule, wsd_schedule)


class SimulatedPreemption(RuntimeError):
    pass


@dataclasses.dataclass
class TrainConfig:
    num_steps: int = 100
    peak_lr: float = 3e-4
    warmup_steps: int = 10
    schedule: str = "wsd"            # wsd | cosine  (minicpm trains WSD)
    adamw: AdamWConfig = AdamWConfig()
    microbatches: int = 1
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    ckpt_keep: int = 3
    log_every: int = 10
    seed: int = 0
    watchdog_factor: float = 3.0
    preempt_at: Optional[int] = None  # simulate preemption after this step


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig,
                 data_spec: DataSpec, *,
                 grad_transform: Optional[Callable] = None,
                 async_ckpt: bool = True):
        self.cfg, self.tcfg, self.spec = cfg, tcfg, data_spec
        sched = wsd_schedule if tcfg.schedule == "wsd" else cosine_schedule
        self.schedule = sched(peak_lr=tcfg.peak_lr,
                              warmup_steps=tcfg.warmup_steps,
                              total_steps=tcfg.num_steps)
        self.ckpt = CheckpointManager(
            tcfg.ckpt_dir, interval=tcfg.ckpt_every, keep=tcfg.ckpt_keep,
            async_save=async_ckpt)
        self.grad_transform = grad_transform
        self.step_times: list = []
        self.straggler_events: list = []
        self._jit_step = jax.jit(self._step)

    # -- state --------------------------------------------------------------
    def init_state(self, key=None):
        params = M.init_params(self.cfg, key or jax.random.PRNGKey(
            self.tcfg.seed))
        opt = adamw_init(params, self.tcfg.adamw)
        return {"params": params, "opt": opt}

    # -- one update ----------------------------------------------------------
    def _step(self, state, batch):
        params, opt = state["params"], state["opt"]
        A = self.tcfg.microbatches

        def loss_of(p, b):
            return M.loss_fn(p, b, self.cfg)

        if A == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            def micro(carry, mb):
                acc_loss, acc_g = carry
                l, g = jax.value_and_grad(loss_of)(params, mb)
                return (acc_loss + l,
                        jax.tree.map(jnp.add, acc_g, g)), None

            mbatch = jax.tree.map(
                lambda x: x.reshape(A, x.shape[0] // A, *x.shape[1:]), batch)
            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.zeros((), jnp.float32), zero_g), mbatch)
            loss = loss / A
            grads = jax.tree.map(lambda g: g / A, grads)

        if self.grad_transform is not None:
            grads = self.grad_transform(grads)
        lr = self.schedule(opt.step)
        params, opt, stats = adamw_update(grads, opt, params, lr=lr,
                                          cfg=self.tcfg.adamw)
        return {"params": params, "opt": opt}, {
            "loss": loss, "lr": lr, **stats}

    # -- main loop -----------------------------------------------------------
    def run(self, *, resume: bool = True, state=None, on_step=None):
        start = 0
        if state is None:
            state = self.init_state()
            if resume and self.ckpt.latest_step() is not None:
                state, meta = self.ckpt.restore(state)
                start = int(meta["step"])
        history = []
        for step in range(start, self.tcfg.num_steps):
            batch = jax.tree.map(jnp.asarray, batch_at(self.spec, step))
            t0 = time.perf_counter()
            state, metrics = self._jit_step(state, batch)
            loss = float(metrics["loss"])      # sync point = step end
            dt = time.perf_counter() - t0
            self._watchdog(step, dt)
            history.append(loss)
            if on_step:
                on_step(step, loss)
            self.ckpt.maybe_save(state, step + 1,
                                 extra_meta={"loss": loss})
            if self.tcfg.preempt_at is not None \
                    and step + 1 >= self.tcfg.preempt_at:
                self.ckpt.maybe_save(state, step + 1, force=True,
                                     extra_meta={"loss": loss})
                self.ckpt.wait()
                raise SimulatedPreemption(f"preempted after step {step + 1}")
        self.ckpt.maybe_save(state, self.tcfg.num_steps, force=True)
        self.ckpt.wait()
        return state, history

    def _watchdog(self, step: int, dt: float):
        self.step_times.append(dt)
        window = self.step_times[-32:]
        med = float(np.median(window))
        if len(window) >= 8 and dt > self.tcfg.watchdog_factor * med:
            self.straggler_events.append(
                {"step": step, "dt": dt, "median": med})
