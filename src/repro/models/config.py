"""Model configuration system.

One :class:`ModelConfig` describes every assigned architecture family
(dense / ssm / hybrid / moe / vlm / audio).  Configs are plain frozen
dataclasses -- hashable, so they can ride along jit static args -- and
every arch file in :mod:`repro.configs` exports ``CONFIG`` plus a
``reduced()`` variant for CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Arbitrary-precision serving configuration (the paper's technique).

    ``w_bits``/``a_bits`` apply to every APLinear-able GEMM (attention,
    MLP, MoE experts, SSM projections).  Router and norm layers stay in
    bf16 (DESIGN.md §4 caveats).  ``w_bits=None`` disables weight
    quantization (bf16 serving baseline).

    ``kv_bits`` quantizes the decode KV cache to packed bipolar-INT bit
    planes with per-(token, head) absmax scales: cache HBM traffic and
    footprint scale with bits/element instead of 16 (the paper's bit-level
    storage applied to the tensor that dominates long-context serving).
    Any 1..8 bits; ``None`` falls back to ``ModelConfig.kv_bits`` and then
    to the bf16 cache.  Reads dequantize on the fly -- inside the Pallas
    flash-attention kernel on TPU, via jnp recovery under the
    ``reference`` impl (see :mod:`repro.kernels.ops`).

    ``fused_linear`` routes every quantized GEMM through the one-kernel
    fused linear (``ops.ap_linear_fused``): activation quantize-pack in
    the GEMM prologue, bias/activation/residual epilogue, dual-GEMM
    gate/up for SwiGLU.  Bit-identical outputs to the unfused two-launch
    path -- ``False`` only for A/B benchmarking the unfused baseline.

    ``nested_bits`` serves a *nested* checkpoint at a lower width than
    it was packed at: weights stay stored once at ``w_bits`` with
    per-width scale vectors, and every quantized GEMM ships only the
    leading ``nested_bits`` bit planes (``bipolar.nested_slice`` -- no
    requantization, weight HBM traffic scales with the served width).
    ``None`` serves the full stored width.  The engine's per-request
    precision lanes are realized as ``dataclasses.replace(quant,
    nested_bits=k)`` per lane.

    ``precision_floor`` is the load-adaptive tier policy's lower bound:
    under queue pressure the engine may degrade a request's served
    width down to -- never below -- this floor (``None`` disables
    degradation entirely).  See ``engine.tier_bits``.

    All bit-width fields are validated up front (descriptive
    ``ValueError`` instead of a shape error deep inside pack/dispatch).
    """
    w_bits: Optional[int] = None
    a_bits: int = 8
    variant: str = "fused"          # "fused" | "bitserial" (paper-faithful)
    kv_bits: Optional[int] = None   # bipolar KV-cache bits (1..8)
    fused_linear: bool = True       # one-kernel linear w/ fused epilogue
    nested_bits: Optional[int] = None   # served weight width (<= w_bits)
    precision_floor: Optional[int] = None  # tier-policy lower bound

    def __post_init__(self):
        def _chk(name, v, lo, hi):
            if v is not None and not (isinstance(v, int)
                                      and lo <= v <= hi):
                raise ValueError(
                    f"QuantConfig.{name}={v!r} out of range: expected an "
                    f"int in [{lo}, {hi}] or None")
        _chk("w_bits", self.w_bits, 1, 8)
        _chk("a_bits", self.a_bits, 1, 8)
        _chk("kv_bits", self.kv_bits, 1, 8)
        _chk("nested_bits", self.nested_bits, 1, 8)
        _chk("precision_floor", self.precision_floor, 1, 8)
        if self.a_bits is None:
            raise ValueError("QuantConfig.a_bits must be set (1..8)")
        if self.variant not in ("fused", "bitserial"):
            raise ValueError(
                f"QuantConfig.variant={self.variant!r}: expected 'fused' "
                f"or 'bitserial'")
        if self.nested_bits is not None:
            if self.w_bits is None:
                raise ValueError(
                    "QuantConfig.nested_bits requires w_bits (the stored "
                    "max width of the nested checkpoint)")
            if self.nested_bits > self.w_bits:
                raise ValueError(
                    f"QuantConfig.nested_bits={self.nested_bits} exceeds "
                    f"the stored width w_bits={self.w_bits}: a nested "
                    f"slice can only drop planes, not add them")
        if self.precision_floor is not None:
            top = self.nested_bits if self.nested_bits is not None \
                else self.w_bits
            if top is not None and self.precision_floor > top:
                raise ValueError(
                    f"QuantConfig.precision_floor={self.precision_floor} "
                    f"> max served width {top}: the tier policy could "
                    f"never satisfy the floor")

    @property
    def enabled(self) -> bool:
        return self.w_bits is not None

    @property
    def serve_bits(self) -> Optional[int]:
        """Weight width actually served: ``nested_bits`` when nested
        slicing is active, else the stored ``w_bits``."""
        return self.nested_bits if self.nested_bits is not None \
            else self.w_bits


def effective_kv_bits(cfg: "ModelConfig",
                      quant: Optional[QuantConfig]) -> Optional[int]:
    """KV-cache bit width in effect: ``quant.kv_bits`` overrides
    ``cfg.kv_bits``; ``None`` = bf16 cache."""
    if quant is not None and quant.kv_bits is not None:
        return quant.kv_bits
    return cfg.kv_bits


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | ssm | hybrid | moe | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None    # default d_model // n_heads
    # --- normalization / activations ---
    norm_type: str = "rmsnorm"      # rmsnorm | layernorm
    norm_eps: float = 1e-5
    act: str = "silu"               # silu (SwiGLU) | gelu
    tie_embeddings: bool = False
    # --- rope ---
    rope_theta: float = 10000.0
    rope_pct: float = 1.0           # partial rotary (stablelm 0.25, glm 0.5)
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    # --- attention ---
    window: Optional[int] = None    # sliding-window attention (mixtral)
    causal: bool = True
    # --- residual scaling (minicpm) ---
    emb_scale: float = 1.0
    residual_scale: float = 1.0
    logit_scale: float = 1.0
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_every: int = 1              # apply MoE every k-th layer (jamba: 2)
    first_dense: int = 0            # leading dense layers (deepseek-moe: 1)
    # --- SSM (mamba2) ---
    ssm_d_state: int = 0
    ssm_d_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_n_groups: int = 1
    ssm_chunk: int = 128
    # --- hybrid (jamba): attention every k-th layer, rest mamba ---
    attn_every: int = 0             # 0 = family default
    # --- enc-dec (audio) ---
    enc_layers: int = 0
    frontend_dim: int = 0           # stub frontend embedding dim
    # --- vlm ---
    n_patches: int = 0              # stub patch-embedding count
    # --- serving quantization ---
    quant: QuantConfig = QuantConfig()
    # bipolar-INT KV cache (paper's bit-level storage applied to the KV
    # stream): decode KV traffic scales with bits/element instead of 16.
    # Any 1..8 bits; None = bf16 cache.  QuantConfig.kv_bits overrides
    # this at serve time (see effective_kv_bits).
    kv_bits: Optional[int] = None
    # bf16 attention probabilities in the chunked-softmax dataflow (the
    # running max/denominator stay f32); halves score HBM traffic where
    # the Pallas flash kernel is not in play
    attn_score_bf16: bool = False
    # --- misc ---
    dtype: str = "bfloat16"
    max_seq_len: int = 8192

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded to 256 (TP x lane) so embeddings/logits shard over
        the model axis (Megatron-style vocab padding); pad logits are
        masked to -inf in the loss/sampling path."""
        return -(-self.vocab // 256) * 256

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def layer_kind(self, idx: int) -> str:
        """Mixer kind of layer ``idx``: 'attn' | 'mamba'."""
        if self.family == "ssm":
            return "mamba"
        if self.family == "hybrid":
            every = self.attn_every or 8
            # jamba: 1 attention per `every` layers, placed mid-group
            return "attn" if idx % every == every // 2 else "mamba"
        return "attn"

    def ffn_kind(self, idx: int) -> str:
        """FFN kind of layer ``idx``: 'dense' | 'moe' | 'none'.

        'none' = mixer-only blocks (pure-SSM archs: mamba2 has no FFN)."""
        if self.n_experts == 0 and self.d_ff == 0:
            return "none"
        if self.n_experts == 0 or idx < self.first_dense:
            return "dense"
        return "moe" if (idx - self.first_dense) % self.moe_every == 0 else "dense"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM / hybrid / sliding-window archs."""
        return self.family in ("ssm", "hybrid") or self.window is not None

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), exact enough
        for MODEL_FLOPS = 6*N*D roofline accounting."""
        d, v = self.d_model, self.vocab
        total = v * d * (1 if self.tie_embeddings else 2)
        dh = self.head_dim
        attn = d * (self.n_heads * dh) + 2 * d * (self.n_kv_heads * dh) \
            + (self.n_heads * dh) * d
        mlp_dense = 3 * d * self.d_ff
        moe = (self.n_experts + 2 * self.n_shared_experts) * 3 * d * self.expert_d_ff \
            + d * self.n_experts
        di = self.ssm_d_inner
        mamba = d * (2 * di + 2 * self.ssm_n_groups * self.ssm_d_state
                     + self.ssm_n_heads) + di * d \
            + self.ssm_d_conv * (di + 2 * self.ssm_n_groups * self.ssm_d_state)
        n_dec = self.n_layers
        for i in range(n_dec):
            total += attn if self.layer_kind(i) == "attn" else mamba
            total += moe if self.ffn_kind(i) == "moe" else mlp_dense
            total += 2 * d  # norms
        for _ in range(self.enc_layers):
            total += attn + mlp_dense + 2 * d
            total += attn + d  # decoder cross-attention + its norm
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k experts count)."""
        if self.n_experts == 0:
            return self.param_count()
        full_moe = self.n_experts * 3 * self.d_model * self.expert_d_ff
        act_moe = self.top_k * 3 * self.d_model * self.expert_d_ff
        n_moe = sum(1 for i in range(self.n_layers) if self.ffn_kind(i) == "moe")
        return self.param_count() - n_moe * (full_moe - act_moe)

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        d_model = 64
        small = dict(
            n_layers=min(self.n_layers, 4),
            d_model=d_model,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads * 4 // self.n_heads, 4)),
            d_head=16,
            d_ff=0 if self.d_ff == 0 else 128,   # keep SSM mixer-only
            vocab=256,
            ssm_d_state=16 if self.ssm_d_state else 0,
            ssm_head_dim=16 if self.ssm_d_state else 64,
            ssm_n_groups=1,
            ssm_chunk=16,
            n_experts=min(self.n_experts, 4),
            n_shared_experts=min(self.n_shared_experts, 1),
            top_k=min(self.top_k, 2),
            expert_d_ff=64 if self.n_experts else 0,
            # dropless at smoke-test scale: token drops are batch-size
            # dependent and would break prefill/decode consistency checks
            capacity_factor=4.0 if self.n_experts else 1.25,
            window=64 if self.window else None,
            enc_layers=min(self.enc_layers, 2),
            frontend_dim=d_model if self.frontend_dim else 0,
            n_patches=8 if self.n_patches else 0,
            max_seq_len=128,
            # M-RoPE sections must sum to (d_head * rope_pct) / 2 = 8
            mrope_sections=(2, 3, 3) if self.mrope_sections else None,
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)
