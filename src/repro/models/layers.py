"""Composable pure-JAX layers: norm, RoPE, GQA attention, MLP, MoE.

Design: functional modules -- ``<layer>_init(key, cfg, ...) -> params`` and
``<layer>_apply(params, x, ...) -> y`` over plain dict pytrees.  Linear
weights are stored ``(d_out, d_in)`` ("NT" layout), matching the packed
APMM kernels, so serving-time quantization is a pure param transform:
replace the bf16 weight leaf with a :class:`BipolarTensor` and
``linear_apply`` dispatches to :func:`repro.kernels.ops.ap_linear`.

The decode KV cache has the same bit-level treatment (``kv_bits``):
``make_kv_cache`` allocates packed bipolar-INT bit planes + per-(token,
head) absmax scales, ``attention_apply`` packs new K/V on write and reads
through :func:`repro.kernels.ops.kv_cache_attention`, which dequantizes
inside the flash-attention kernel (pallas/interpret) or via jnp recovery
(reference).  The cache format is self-describing (bit width = plane-axis
length), so apply code needs no extra static config.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bipolar import BipolarTensor
from repro.kernels import ops
from repro.kernels.ref import apply_act
from repro.distributed.sharding import constrain
from repro.models.config import ModelConfig

# attention switches to online-softmax KV chunking above this length
ATTN_CHUNK_THRESHOLD = 4096
ATTN_KV_CHUNK = 1024


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Linear / Embedding
# ---------------------------------------------------------------------------

def linear_init(key, d_in: int, d_out: int, dtype) -> dict:
    w = jax.random.normal(key, (d_out, d_in), jnp.float32)
    return {"w": (w / np.sqrt(d_in)).astype(dtype)}


def _epilogue(y: jax.Array, act: str, residual, dtype) -> jax.Array:
    """Post-GEMM epilogue in jnp, with the same ordering/cast points the
    fused kernel uses: activation in f32 on the dtype-cast GEMM output,
    residual added in the output dtype."""
    if act != "none":
        y = apply_act(y.astype(jnp.float32), act).astype(dtype)
    if residual is not None:
        y = y + residual.astype(dtype)
    return y


def _use_fused_linear(w, quant) -> bool:
    return (isinstance(w, BipolarTensor) and quant is not None
            and quant.enabled and quant.fused_linear)


def linear_apply(params: dict, x: jax.Array, *, quant=None,
                 act: str = "none", residual=None) -> jax.Array:
    """``y (..., N) = epi(x (..., K) @ W (N, K)^T)`` -- bf16 or
    arbitrary-precision, with an optional fused epilogue
    (``act in {none, silu, gelu}``, residual add).

    If the weight leaf is a :class:`BipolarTensor` (serving-time quantized
    params) the GEMM runs through the APMM path with on-the-fly activation
    quantization (paper §3.2/§4): the one-kernel fused linear
    (``quant.fused_linear``, activation quantize-pack in the GEMM
    prologue + in-kernel epilogue) or the unfused two-launch baseline.
    Both produce bit-identical outputs; the bf16 path applies the same
    epilogue in jnp.
    """
    w = params["w"]
    if _use_fused_linear(w, quant):
        return ops.ap_linear_fused(x, w, a_bits=quant.a_bits, act=act,
                                   residual=residual,
                                   variant=quant.variant, out_dtype=x.dtype,
                                   w_bits=quant.nested_bits)
    if isinstance(w, BipolarTensor):
        assert quant is not None and quant.enabled
        y = ops.ap_linear(x, w, a_bits=quant.a_bits,
                          variant=quant.variant, out_dtype=x.dtype,
                          w_bits=quant.nested_bits)
    else:
        y = jnp.einsum("...k,nk->...n", x, w.astype(x.dtype))
    return _epilogue(y, act, residual, x.dtype)


def embed_init(key, vocab: int, d_model: int, dtype) -> dict:
    return {"w": (jax.random.normal(key, (vocab, d_model), jnp.float32)
                  * 0.02).astype(dtype)}


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------

def norm_init(d: int, cfg: ModelConfig) -> dict:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_apply(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * params["scale"] + params["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * params["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (standard / partial / M-RoPE)
# ---------------------------------------------------------------------------

def _rope_angles(positions: jax.Array, rot_dim: int, theta: float):
    """positions (..., S) -> cos/sin (..., S, rot_dim/2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32)
                           / rot_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, positions: jax.Array, cfg: ModelConfig):
    """Rotary embedding on ``x (B, S, H, D)``.

    ``positions``: ``(B, S)`` int32, or ``(3, B, S)`` for M-RoPE
    (temporal/height/width sections, qwen2-vl).  Only the leading
    ``rope_pct`` fraction of D rotates (stablelm/glm partial rotary).
    """
    d = x.shape[-1]
    rot = int(d * cfg.rope_pct)
    rot -= rot % 2
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    if cfg.mrope_sections is not None:
        assert positions.ndim == 3, "M-RoPE needs (3, B, S) positions"
        sec = cfg.mrope_sections
        assert sum(sec) == half, (sec, half)
        cos_parts, sin_parts = [], []
        lo = 0
        for axis, width in enumerate(sec):
            c, s = _rope_angles(positions[axis], rot, cfg.rope_theta)
            cos_parts.append(c[..., lo:lo + width])
            sin_parts.append(s[..., lo:lo + width])
            lo += width
        cos = jnp.concatenate(cos_parts, -1)[:, :, None, :]
        sin = jnp.concatenate(sin_parts, -1)[:, :, None, :]
    else:
        cos, sin = _rope_angles(positions, rot, cfg.rope_theta)
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)
    return jnp.concatenate([out, x_pass], axis=-1) if rot < d else out


# ---------------------------------------------------------------------------
# Attention (GQA, causal, sliding-window, cross; direct + online-softmax)
# ---------------------------------------------------------------------------

def _read_quantized_kv(qg, ck, cks, cv, cvs, qp, kv_pos, *,
                       d, causal, window):
    """Attend over a packed bipolar KV cache: fold heads into batch and
    let the ops dispatch pick the dequant-on-read kernel
    (pallas/interpret) or the jnp recovery path (reference).

    ``qg (B, Hk, G, d)`` grouped queries; ``ck/cv (B, T, Hk, bits, Dw)``
    planes with ``cks/cvs (B, T, Hk, 1)`` scales; ``qp (B, G)`` /
    ``kv_pos (B, T)``.  Returns ``(B, Hk, G, d)``.
    """
    b, hk, gs, _ = qg.shape
    return ops.kv_cache_attention(
        qg.reshape(b * hk, gs, d),
        ops.fold_kv_heads(ck), ops.fold_kv_heads(cks),
        ops.fold_kv_heads(cv), ops.fold_kv_heads(cvs),
        jnp.repeat(qp, hk, 0), jnp.repeat(kv_pos, hk, 0),
        d=d, causal=causal, window=window).reshape(b, hk, gs, d)


def attention_init(key, cfg: ModelConfig, cross: bool = False) -> dict:
    d, dh = cfg.d_model, cfg.head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    dt = _dtype(cfg)
    return {
        "wq": linear_init(kq, d, cfg.n_heads * dh, dt),
        "wk": linear_init(kk, d, cfg.n_kv_heads * dh, dt),
        "wv": linear_init(kv, d, cfg.n_kv_heads * dh, dt),
        "wo": linear_init(ko, cfg.n_heads * dh, d, dt),
    }


def _attn_core(q, k, v, q_pos, kv_pos, *, causal: bool,
               window: Optional[int], chunked: bool,
               score_bf16: bool = False):
    """Online-softmax GQA core.

    q: (B, Hkv, Sq, D) with Sq = groups*S folded; k/v: (B, Hkv, T, D);
    q_pos: (B, Sq) absolute positions; kv_pos: (B, T), negative = invalid.
    """
    b, hk, sq, d = q.shape
    t = k.shape[2]
    scale = 1.0 / np.sqrt(d)
    qf = q.astype(jnp.float32) * scale

    def mask_for(kp):  # kp: (B, Tc) -> (B, 1, Sq, Tc) additive mask
        valid = kp[:, None, None, :] >= 0
        if causal:
            valid &= kp[:, None, None, :] <= q_pos[:, None, :, None]
        if window is not None:
            valid &= kp[:, None, None, :] > q_pos[:, None, :, None] - window
        return jnp.where(valid, 0.0, -jnp.inf)

    if not chunked:
        s = jnp.einsum("bhqd,bhtd->bhqt", qf, k.astype(jnp.float32))
        s = s + mask_for(kv_pos)
        m = jnp.max(s, -1, keepdims=True)
        m = jnp.maximum(m, -1e30)  # fully-masked rows stay finite
        p = jnp.exp(s - m)
        o = jnp.einsum("bhqt,bhtd->bhqd", p, v.astype(jnp.float32))
        return o / jnp.maximum(p.sum(-1, keepdims=True), 1e-20)

    nc = -(-t // ATTN_KV_CHUNK)
    tc = nc * ATTN_KV_CHUNK
    pad = tc - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
    ks = k.reshape(b, hk, nc, ATTN_KV_CHUNK, d).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(b, hk, nc, ATTN_KV_CHUNK, d).transpose(2, 0, 1, 3, 4)
    ps = kv_pos.reshape(b, nc, ATTN_KV_CHUNK).transpose(1, 0, 2)
    # opt-in: pin the chunk axis unsharded so per-step dynamic-slice does
    # not reshard (see distributed.sharding.default_activation_rules)
    ks = constrain(ks, "attn_chunks")
    vs = constrain(vs, "attn_chunks")

    def step(carry, inp):
        m, l, acc = carry
        kc, vc, pc = inp
        s = jnp.einsum("bhqd,bhtd->bhqt", qf, kc.astype(jnp.float32))
        s = s + mask_for(pc)
        m_new = jnp.maximum(m, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        if score_bf16:      # halve probability-tensor traffic; m/l stay f32
            p = p.astype(jnp.bfloat16)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1, keepdims=True).astype(jnp.float32)
        acc = acc * alpha + jnp.einsum(
            "bhqt,bhtd->bhqd", p, vc.astype(p.dtype),
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    init = (jnp.full((b, hk, sq, 1), -1e30, jnp.float32),
            jnp.zeros((b, hk, sq, 1), jnp.float32),
            jnp.zeros((b, hk, sq, d), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(step, init, (ks, vs, ps))
    return acc / jnp.maximum(l, 1e-20)


def attention_apply(params: dict, x: jax.Array, cfg: ModelConfig, *,
                    positions: jax.Array,
                    kv_positions: Optional[jax.Array] = None,
                    kv_override=None,
                    cache: Optional[dict] = None,
                    cross_memory: Optional[jax.Array] = None,
                    causal: Optional[bool] = None,
                    quant=None, residual: Optional[jax.Array] = None):
    """GQA attention over ``x (B, S, d_model)``.

    * training / prefill: self-attention over the full sequence.
    * decode: ``cache`` = dict(k, v, pos, index); x is the new token(s),
      K/V are appended at ``index`` and attention runs over the cache.
    * cross: ``cross_memory (B, T, d)`` supplies K/V (enc-dec decoder).
    ``residual`` (the block input) fuses the residual add into the
    output projection's epilogue.  Returns ``(out, new_cache)``.
    """
    b, s, _ = x.shape
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // hk
    causal = cfg.causal if causal is None else causal
    rope_pos = positions
    pos2d = positions[positions.ndim - 2] if positions.ndim == 3 else positions

    q = linear_apply(params["wq"], x, quant=quant).reshape(b, s, h, dh)
    kv_src = x if cross_memory is None else cross_memory
    t_src = kv_src.shape[1]
    k = linear_apply(params["wk"], kv_src, quant=quant).reshape(b, t_src, hk, dh)
    v = linear_apply(params["wv"], kv_src, quant=quant).reshape(b, t_src, hk, dh)

    if cross_memory is None:
        q = apply_rope(q, rope_pos, cfg)
        k = apply_rope(k, rope_pos if cache is None else rope_pos, cfg)

    new_cache = None
    quant_kv = None           # (k_packed, k_scale, v_packed, v_scale) folded
    if cache is not None and "block_tables" in cache:
        # paged decode / suffix prefill: the cache is a block pool shared
        # by every request (k/v (n_blocks, bs, H, kv_bits, Dw) planes +
        # scales), addressed through this batch's block table.  The
        # ``s`` new tokens of row b land at slots ``length[b] + i`` --
        # physically (table[slot // bs], slot % bs) -- then attention
        # runs through the table (ops.paged_kv_cache_attention) with the
        # suffix folded into the query axis; causality is by absolute
        # position, so the suffix sees the shared prefix blocks AND its
        # own just-written tokens in one pass.  Pad tokens (pos -1, from
        # pow2 length bucketing or inactive lanes) are *dropped* at the
        # scatter (routed out of bounds), so they can never touch the
        # null block or a live block's slots.
        kv_bits = cache["k"].shape[-2]
        n_blocks = cache["k"].shape[0]
        blk = cache["k"].shape[1]
        bt, ln = cache["block_tables"], cache["length"]
        k_q, k_s = ops.quantize_kv(k, kv_bits)
        v_q, v_s = ops.quantize_kv(v, kv_bits)
        slot = ln[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]  # (B,s)
        valid = pos2d >= 0
        logical = jnp.where(valid, slot // blk, 0)
        # sliding-window reclaim: the table is a rolling window whose
        # entry j maps logical block j + block_offset (leading blocks
        # already returned to the pool); writes always target the live
        # suffix, so the index stays in range for every real token
        boff = cache.get("block_offset")
        if boff is not None:
            logical = logical - boff[:, None]
        entry = jnp.clip(logical, 0, bt.shape[1] - 1)
        valid_w = valid & (logical >= 0) & (logical < bt.shape[1])
        phys = jnp.take_along_axis(bt, entry, 1)
        phys = jnp.where(valid_w, phys, n_blocks)   # out of bounds -> drop
        off = slot % blk

        def wr(buf, new):
            return buf.at[phys, off].set(new.astype(buf.dtype), mode="drop")

        ck, cks = wr(cache["k"], k_q), wr(cache["k_scale"], k_s)
        cv, cvs = wr(cache["v"], v_q), wr(cache["v_scale"], v_s)
        cpos = wr(cache["pos"], pos2d.astype(jnp.int32))
        new_cache = dict(cache, k=ck, v=cv, k_scale=cks, v_scale=cvs,
                         pos=cpos)
        qg = q.reshape(b, s, hk, g, dh).transpose(0, 2, 3, 1, 4).reshape(
            b, hk, g * s, dh)
        qp = jnp.repeat(pos2d[:, None, :], g, 1).reshape(b, g * s)
        o = ops.paged_kv_cache_attention(
            qg, ck, cks, cv, cvs, cpos, bt, qp,
            d=dh, causal=causal, window=cfg.window)
        o = o.reshape(b, hk, g, s, dh).transpose(0, 3, 1, 2, 4).reshape(
            b, s, h * dh).astype(x.dtype)
        return linear_apply(params["wo"], o, quant=quant,
                            residual=residual), new_cache
    if cache is not None:
        kv_bits = cache["k"].shape[-2] if "k_scale" in cache else None
        cache_len = cache["k"].shape[1]
        if s > cache_len:
            # SWA prefill longer than the ring: attend over the in-sequence
            # K/V directly, then store only the last `window` entries
            # (slot order is irrelevant -- masking is by absolute position).
            tail_k, tail_v = k[:, -cache_len:], v[:, -cache_len:]
            tail_p = pos2d[:, -cache_len:].astype(jnp.int32)
            new_cache = dict(cache, pos=tail_p,
                             index=jnp.zeros_like(cache["index"]))
            if kv_bits:
                new_cache["k"], new_cache["k_scale"] = \
                    ops.quantize_kv(tail_k, kv_bits)
                new_cache["v"], new_cache["v_scale"] = \
                    ops.quantize_kv(tail_v, kv_bits)
            else:
                new_cache["k"] = tail_k.astype(cache["k"].dtype)
                new_cache["v"] = tail_v.astype(cache["v"].dtype)
            kv_pos = pos2d
        else:
            # write new K/V at per-slot ring positions (continuous batching:
            # each batch row advances independently)
            idx = cache["index"]                       # (B,) int32

            def row_write(buf, new, i):
                start = (i,) + (0,) * (new.ndim - 1)
                return jax.lax.dynamic_update_slice(buf, new, start)

            wr = jax.vmap(row_write)
            if kv_bits:
                k_q, k_s = ops.quantize_kv(k, kv_bits)
                v_q, v_s = ops.quantize_kv(v, kv_bits)
                ck, cks = wr(cache["k"], k_q, idx), wr(cache["k_scale"], k_s, idx)
                cv, cvs = wr(cache["v"], v_q, idx), wr(cache["v_scale"], v_s, idx)
                cpos = wr(cache["pos"], pos2d.astype(jnp.int32), idx)
                new_cache = dict(cache, k=ck, v=cv, k_scale=cks, v_scale=cvs,
                                 pos=cpos, index=(idx + s) % cache_len)
                quant_kv = (ck, cks, cv, cvs)
                kv_pos = cpos
            else:
                ck = wr(cache["k"], k.astype(cache["k"].dtype), idx)
                cv = wr(cache["v"], v.astype(cache["v"].dtype), idx)
                cpos = wr(cache["pos"], pos2d.astype(jnp.int32), idx)
                new_cache = dict(cache, k=ck, v=cv, pos=cpos,
                                 index=(idx + s) % cache_len)
                k, v, kv_pos = ck, cv, cpos
    elif cross_memory is not None:
        kv_pos = (kv_positions if kv_positions is not None
                  else jnp.broadcast_to(jnp.arange(t_src), (b, t_src)))
        causal = False
    else:
        kv_pos = pos2d

    # fold the GQA group into the query-sequence axis: (B, Hkv, G*S, D)
    qg = q.reshape(b, s, hk, g, dh).transpose(0, 2, 3, 1, 4).reshape(
        b, hk, g * s, dh)
    qp = jnp.repeat(pos2d[:, None, :], g, 1).reshape(b, g * s)
    if quant_kv is not None:
        ck, cks, cv, cvs = quant_kv
        o = _read_quantized_kv(qg, ck, cks, cv, cvs, qp, kv_pos,
                               d=dh, causal=causal, window=cfg.window)
    else:
        kt = k.transpose(0, 2, 1, 3)
        vt = v.transpose(0, 2, 1, 3)
        # decode (s==1) is a skinny GEMV -- direct; long train/prefill
        # sequences use the online-softmax KV-chunked path to bound the
        # score transient
        chunked = (s > 1) and (k.shape[1] > ATTN_CHUNK_THRESHOLD)
        o = _attn_core(qg, kt, vt, qp, kv_pos, causal=causal,
                       window=cfg.window, chunked=chunked,
                       score_bf16=cfg.attn_score_bf16)
    o = o.reshape(b, hk, g, s, dh).transpose(0, 3, 1, 2, 4).reshape(
        b, s, h * dh).astype(x.dtype)
    out = linear_apply(params["wo"], o, quant=quant, residual=residual)
    return out, new_cache


def _write_cross_slots(cache: dict, ck, cks, cv, cvs, kv_pos) -> dict:
    """Scatter one batch's projected+packed cross-K/V into its slot-pool
    rows.  ``cache`` leaves are ``(rows, cap, ...)`` with ``slots (B,)``
    ids (-1 = padded lane, write dropped).  Rows are written full-width:
    slots past the batch's encoder length carry pos -1 and stay masked,
    so a reused slot cannot leak a freed request's memory."""
    slots = cache["slots"]
    rows, cap = cache["k"].shape[0], cache["k"].shape[1]
    t = ck.shape[1]
    idx = jnp.where(slots >= 0, slots, rows)       # OOB -> dropped

    def pad_t(a, value=0):
        if cap == t:
            return a
        pad = [(0, 0)] * a.ndim
        pad[1] = (0, cap - t)
        return jnp.pad(a, pad, constant_values=value)

    def wr(key, new, value=0):
        buf = cache[key]
        return buf.at[idx].set(pad_t(new, value).astype(buf.dtype),
                               mode="drop")

    return dict(cache, k=wr("k", ck), k_scale=wr("k_scale", cks),
                v=wr("v", cv), v_scale=wr("v_scale", cvs),
                pos=wr("pos", kv_pos, -1))


def cross_attention_apply(params: dict, x: jax.Array, cfg: ModelConfig, *,
                          memory: Optional[jax.Array] = None,
                          cache: Optional[dict] = None,
                          quant=None, residual: Optional[jax.Array] = None):
    """Enc-dec cross-attention (no RoPE, non-causal).

    Prefill/train: ``memory (B, T, d)`` given -> project K/V (and fill
    ``cache`` if provided).  Decode: ``memory=None`` -> replay cached
    projected K/V (the encoder is NOT re-run per token).  A quantized
    cache (``k_scale`` present, :func:`make_cross_cache` with
    ``kv_bits``) stores packed bipolar planes on fill and decodes
    through :func:`repro.kernels.ops.kv_cache_attention`.

    Paged serving hands the cache as *slot-pool rows*: leaves are
    ``(n_slots+1, cap, ...)`` and ``cache["slots"] (B,)`` maps batch
    lanes to rows (slot 0 reserved null, -1 = padded lane).  Prefill
    scatters this request's packed planes into its row; decode gathers
    the batch's rows back.  Returns ``(out, new_cache)``.
    """
    b, s, _ = x.shape
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // hk
    slotted = cache is not None and "slots" in cache
    q = linear_apply(params["wq"], x, quant=quant).reshape(b, s, h, dh)
    qg = q.reshape(b, s, hk, g, dh).transpose(0, 2, 3, 1, 4).reshape(
        b, hk, g * s, dh)
    qp = jnp.zeros((b, g * s), jnp.int32)   # positions unused (non-causal)
    quant_kv = None           # (k, k_scale, v, v_scale) packed planes
    if memory is not None:
        t = memory.shape[1]
        k = linear_apply(params["wk"], memory, quant=quant).reshape(
            b, t, hk, dh)
        v = linear_apply(params["wv"], memory, quant=quant).reshape(
            b, t, hk, dh)
        kv_pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
        new_cache = None
        if cache is not None:
            kv_bits = cache["k"].shape[-2] if "k_scale" in cache else None
            if kv_bits:
                # attend through the quantized planes even in prefill so
                # every position sees the same precision as decode (the
                # recompute-reproduces-identical-tokens invariant)
                ck, cks = ops.quantize_kv(k, kv_bits)
                cv, cvs = ops.quantize_kv(v, kv_bits)
                quant_kv = (ck, cks, cv, cvs)
                if slotted:
                    new_cache = _write_cross_slots(cache, ck, cks, cv,
                                                   cvs, kv_pos)
                else:
                    new_cache = dict(cache, k=ck, v=cv, k_scale=cks,
                                     v_scale=cvs, pos=kv_pos)
            else:
                assert not slotted, \
                    "slot-pool cross caches store packed planes: the " \
                    "paged engine requires kv_bits for audio archs"
                new_cache = dict(cache, k=k.astype(cache["k"].dtype),
                                 v=v.astype(cache["v"].dtype), pos=kv_pos)
    else:
        assert cache is not None, "cross decode needs a filled cross cache"
        if slotted:
            # gather this batch's rows; padded lanes (-1) read the null
            # slot, whose pos stays -1 -> fully masked, contributes 0
            rows = cache["k"].shape[0]
            safe = jnp.clip(cache["slots"], 0, rows - 1)
            quant_kv = (cache["k"][safe], cache["k_scale"][safe],
                        cache["v"][safe], cache["v_scale"][safe])
            kv_pos = cache["pos"][safe]
            new_cache = cache
        elif "k_scale" in cache:
            new_cache, kv_pos = cache, cache["pos"]
            quant_kv = (cache["k"], cache["k_scale"],
                        cache["v"], cache["v_scale"])
        else:
            new_cache, kv_pos = cache, cache["pos"]
            k, v = cache["k"], cache["v"]
    if quant_kv is not None:
        o = _read_quantized_kv(qg, *quant_kv, qp, kv_pos,
                               d=dh, causal=False, window=None)
    else:
        chunked = (s > 1) and (k.shape[1] > ATTN_CHUNK_THRESHOLD)
        o = _attn_core(qg, k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
                       qp, kv_pos, causal=False, window=None, chunked=chunked)
    o = o.reshape(b, hk, g, s, dh).transpose(0, 3, 1, 2, 4).reshape(
        b, s, h * dh).astype(x.dtype)
    return linear_apply(params["wo"], o, quant=quant,
                        residual=residual), new_cache


def make_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype,
                  kv_bits: Optional[int] = None) -> dict:
    """Decode KV cache; for SWA archs the cache is a ring of ``window``.

    ``index`` is per batch row: under continuous batching each slot
    advances independently.  With ``kv_bits`` set (defaults to
    ``cfg.kv_bits``; ``QuantConfig.kv_bits`` overrides via
    ``config.effective_kv_bits`` in ``model.init_caches``) the cache
    stores packed bipolar-INT bit planes ``(B, L, H, kv_bits, D/32)``
    uint32 + per-(token, head) absmax scales: ``kv_bits`` bits per cache
    element instead of 16, dequantized on read (repro.kernels.ops).
    """
    kv_bits = cfg.kv_bits if kv_bits is None else kv_bits
    length = min(max_len, cfg.window) if cfg.window else max_len
    shape = (batch, length, cfg.n_kv_heads, cfg.head_dim)
    cache = {
        "pos": jnp.full((batch, length), -1, jnp.int32),
        "index": jnp.zeros((batch,), jnp.int32),
    }
    if kv_bits:
        assert 1 <= kv_bits <= 8, f"kv_bits={kv_bits} outside 1..8"
        from repro.core import bipolar
        packed = shape[:3] + (kv_bits, bipolar.packed_words(cfg.head_dim))
        cache["k"] = jnp.zeros(packed, jnp.uint32)
        cache["v"] = jnp.zeros(packed, jnp.uint32)
        cache["k_scale"] = jnp.zeros(shape[:3] + (1,), jnp.float32)
        cache["v_scale"] = jnp.zeros(shape[:3] + (1,), jnp.float32)
    else:
        cache["k"] = jnp.zeros(shape, dtype)
        cache["v"] = jnp.zeros(shape, dtype)
    return cache


def make_cross_cache(cfg: ModelConfig, batch: int, enc_len: int, dtype,
                     kv_bits: Optional[int] = None) -> dict:
    """Enc-dec cross-K/V cache (projected encoder memory, replayed every
    decode step).  With ``kv_bits`` the cache stores packed bipolar-INT
    planes + per-(token, head) scales, same format as the self-attention
    KV cache -- the cross stream is read every decode step, so its HBM
    traffic scales with bits/element too."""
    kv_bits = cfg.kv_bits if kv_bits is None else kv_bits
    shape = (batch, enc_len, cfg.n_kv_heads, cfg.head_dim)
    cache = {"pos": jnp.full((batch, enc_len), -1, jnp.int32)}
    if kv_bits:
        assert 1 <= kv_bits <= 8, f"kv_bits={kv_bits} outside 1..8"
        from repro.core import bipolar
        packed = shape[:3] + (kv_bits, bipolar.packed_words(cfg.head_dim))
        cache["k"] = jnp.zeros(packed, jnp.uint32)
        cache["v"] = jnp.zeros(packed, jnp.uint32)
        cache["k_scale"] = jnp.zeros(shape[:3] + (1,), jnp.float32)
        cache["v_scale"] = jnp.zeros(shape[:3] + (1,), jnp.float32)
    else:
        cache["k"] = jnp.zeros(shape, dtype)
        cache["v"] = jnp.zeros(shape, dtype)
    return cache


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    dt = _dtype(cfg)
    p = {"w_up": linear_init(k1, d, f, dt), "w_down": linear_init(k2, f, d, dt)}
    if cfg.act == "silu":
        p["w_gate"] = linear_init(k3, d, f, dt)
    return p


def mlp_apply(params: dict, x: jax.Array, cfg: ModelConfig, quant=None,
              residual: Optional[jax.Array] = None):
    """SwiGLU / GELU MLP.  Quantized + ``quant.fused_linear``: SwiGLU's
    gate and up projections run as ONE dual-GEMM fused-linear launch
    (shared quantized A-tile stream, ``silu(gate) * up`` fused before
    the output write) and the down projection fuses the block residual
    into its epilogue.  ``residual`` (the block input) is added to the
    down projection's output."""
    if cfg.act == "silu":
        if _use_fused_linear(params["w_up"]["w"], quant):
            h = ops.ap_linear_fused(
                x, params["w_gate"]["w"], w2=params["w_up"]["w"],
                a_bits=quant.a_bits, act="silu", variant=quant.variant,
                out_dtype=x.dtype, w_bits=quant.nested_bits)
        else:
            up = linear_apply(params["w_up"], x, quant=quant)
            gate = linear_apply(params["w_gate"], x, quant=quant)
            h = (jax.nn.silu(gate.astype(jnp.float32))
                 * up.astype(jnp.float32)).astype(x.dtype)
    else:
        h = linear_apply(params["w_up"], x, quant=quant, act="gelu")
    return linear_apply(params["w_down"], h, quant=quant,
                        residual=residual)


# ---------------------------------------------------------------------------
# MoE (top-k routing, capacity dispatch via segment-sum, optional shared)
# ---------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.expert_d_ff, cfg.n_experts
    kr, k1, k2, k3, ks = jax.random.split(key, 5)
    dt = _dtype(cfg)
    scale = 1.0 / np.sqrt(d)
    p = {
        "router": {"w": (jax.random.normal(kr, (e, d)) * scale
                         ).astype(jnp.float32)},
        "w_up": (jax.random.normal(k1, (e, f, d)) * scale).astype(dt),
        "w_gate": (jax.random.normal(k2, (e, f, d)) * scale).astype(dt),
        "w_down": (jax.random.normal(k3, (e, d, f)) / np.sqrt(f)).astype(dt),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks, cfg, d_ff=cfg.n_shared_experts * f)
    return p


def _expert_quantize(x_eck, a_bits: int):
    """Per-(expert, row) activation quantization for the expert GEMMs:
    computed ONCE and shared by the gate and up projections (the
    reference-dataflow analogue of the fused kernel's single A-tile
    stream).

    The scale/divide/round chain runs in f32 (single rounding from the
    materialized input).  A native-bf16 chain is NOT compilation-stable:
    XLA's excess-precision pass elides the f32->bf16->f32 converts
    between consecutive bf16 ops inside a fused graph, so the rounded
    integers depend on the surrounding jit context -- the f32 chain has
    no narrowing converts to elide, which is what keeps the legacy path
    bit-identical to the grouped kernel under every compilation."""
    from repro.core import bipolar as bp
    xf = x_eck.astype(jnp.float32)
    sx = bp.absmax_scale(xf, a_bits, axis=-1)             # (E, C, 1) f32
    return bp.quantize_values(xf, a_bits, sx), sx         # (E, C, K) int32


def _expert_matmul(w, x_eck, quant=None, pre=None, out_dtype=None):
    """Batched per-expert NT GEMM: ``(E, C, K) x (E, N, K) -> (E, C, N)``.

    When ``w`` is a :class:`BipolarTensor` (packed ``(n, E, N, Kw)``, scale
    ``(E, N, 1)``), the GEMM runs the fused-APMM formulation batched over
    E: unpack-and-recover weights to bipolar integers in-registers,
    quantize activations per (e, c) row (or reuse ``pre`` = the shared
    ``_expert_quantize`` result), integer einsum, closed-form K-pad
    correction, scale outer product.  Bit-exact with the 2D APMM path.
    ``out_dtype`` overrides the output cast (``jnp.float32`` = hand the
    undegraded f32 dequant to a fused epilogue, the dual-GEMM pattern).
    """
    from repro.core import bipolar as bp
    od = out_dtype if out_dtype is not None else x_eck.dtype
    if isinstance(w, BipolarTensor):
        nested = getattr(quant, "nested_bits", None)
        if nested is not None:
            w = bp.nested_slice(w, nested)
        kp = w.packed.shape[-1] * bp.PACK_WIDTH
        k = w.shape[-1]
        planes = bp.unpack_planes(w.packed, -1, kp)       # (n, E, N, Kp)
        vals = bp.recover(planes, w.n_bits)               # pads -> +maxw
        xq, sx = pre if pre is not None \
            else _expert_quantize(x_eck, quant.a_bits)
        if kp > k:  # pad activations with -maxa (all-zero-bit convention)
            xq = jnp.pad(xq, ((0, 0), (0, 0), (0, kp - k)),
                         constant_values=-bp.max_value(quant.a_bits))
        y = jnp.einsum("eck,enk->ecn", xq, vals,
                       preferred_element_type=jnp.int32)
        y = y + (kp - k) * bp.max_value(quant.a_bits) * bp.max_value(w.n_bits)
        y = y.astype(jnp.float32) * sx * w.scale[:, None, :, 0]
        return y.astype(od)
    return jnp.einsum("eck,enk->ecn", x_eck, w.astype(x_eck.dtype)).astype(od)


MOE_DISPATCH_GROUPS = 32   # static token-group count (per-group capacity)

# Module flag: False forces the legacy batched-over-E expert path even for
# quantized weights -- the pre-rewire oracle for the engine token-identity
# test and the BENCH_moe baseline.  The grouped kernel is the default.
GROUPED_MOE = True


def moe_apply(params: dict, x: jax.Array, cfg: ModelConfig, quant=None):
    """Top-k capacity-bounded MoE over ``x (B, S, d)``.

    *Grouped* dispatch: tokens are split into G static groups with
    per-group capacity (= per-device capacity at scale).  The dispatch
    scatter and the position cumsum are then *batched over G*, which SPMD
    partitions along the group axis -- the flat global scatter was
    "involuntarily replicated" by XLA, costing ~1.4 TiB of all-reduce per
    MoE layer on the jamba-398B train cell (EXPERIMENTS.md §Perf iter 3).
    Memory is O(G * E * C_g * d) = O(k*T*cf*d).

    Quantized experts run through ``ops.ap_moe_expert_linear`` (one
    grouped launch per projection stage, gate+up fused dual-GEMM,
    scalar-prefetched live-row counts skipping empty capacity tiles) --
    token-identical to the legacy batched ``_expert_matmul`` path
    (``GROUPED_MOE = False``), which remains the dense fallback.

    Returns ``(y, aux, stats)``; ``stats`` carries per-layer capacity
    telemetry -- ``load (E,)`` tokens kept per expert, ``dropped ()``
    assignments lost to the capacity bound, ``capacity ()`` total
    dispatch slots -- all int32, computed from the routing one-hots
    (XLA dead-code-eliminates them when the caller drops ``stats``, so
    collection is free unless observability asks for it).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    # grouping pays when groups are token-heavy (train/prefill); for tiny
    # decode batches a flat dispatch avoids XLA replicating the expert
    # weights to satisfy group-sharded operands (EXPERIMENTS.md §Perf A4)
    if t >= 4096:
        g = next(gg for gg in (MOE_DISPATCH_GROUPS, 16, 8, 4, 2, 1)
                 if t % gg == 0)
    else:
        g = 1
    tg = t // g
    # capacity never needs to exceed the group's total routed assignments
    # (tg*k): with tiny decode batches and a generous capacity_factor the
    # ceil formula would dispatch mostly-empty rows the expert GEMM then
    # pays for -- the clamp cannot drop a token (pos < tg*k always), it
    # only removes rows that could never hold one
    cap = min(int(np.ceil(k * tg * cfg.capacity_factor / e)), tg * k)
    xt = x.reshape(t, d)
    xg = x.reshape(g, tg, d)

    logits = jnp.einsum("gtd,ed->gte", xg.astype(jnp.float32),
                        params["router"]["w"])
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, k)                     # (G, Tg, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(g, tg * k)                           # (G, Tg*k)
    oh = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)             # (G, Tg*k, E)
    pos = (jnp.cumsum(oh, axis=1) - oh)                         # count before
    pos = jnp.take_along_axis(pos, flat_e[..., None], 2)[..., 0]
    keep = pos < cap
    slot = jnp.where(keep, flat_e * cap + pos, e * cap)         # (G, Tg*k)

    x_rep = jnp.repeat(xg, k, axis=1)                           # (G, Tg*k, d)
    disp = jax.vmap(
        lambda xr, sl: jax.ops.segment_sum(xr, sl, num_segments=e * cap + 1)
    )(x_rep, slot)[:, :e * cap]
    disp = disp.reshape(g, e, cap, d).astype(x.dtype)
    disp = constrain(disp, "moe_dispatch")
    # fold groups into capacity for the expert GEMMs: (E, G*C, d)
    disp_e = disp.transpose(1, 0, 2, 3).reshape(e, g * cap, d)

    # kept assignments per (group, expert) -- drives both the grouped
    # kernel's tile-skip prefetch and the capacity telemetry
    counts = (oh * keep[..., None].astype(jnp.int32)).sum(1)    # (G, E)
    counts_e = counts.T                                         # (E, G)

    quantized = isinstance(params["w_up"], BipolarTensor)
    if quantized and GROUPED_MOE:
        # grouped kernel: one launch for gate+up (dual-GEMM, shared
        # quantized A-stream), one for down; scalar-prefetched counts
        # skip capacity tiles with no live tokens
        h = ops.ap_moe_expert_linear(
            disp_e, params["w_gate"], w2=params["w_up"], counts=counts_e,
            a_bits=quant.a_bits, act="silu", variant=quant.variant,
            out_dtype=x.dtype, w_bits=quant.nested_bits)
        out = ops.ap_moe_expert_linear(
            h, params["w_down"], counts=counts_e, a_bits=quant.a_bits,
            variant=quant.variant, out_dtype=x.dtype,
            w_bits=quant.nested_bits)                           # (E, G*C, d)
    elif quantized:
        # legacy batched-over-E oracle for the grouped kernel: gate and
        # up share one quantized-activation stream, the dual epilogue
        # composes in f32.  optimization_barrier pins the bf16
        # materialization points the kernel pins physically (its HBM
        # operand/result round-trips) -- without them XLA's excess-
        # precision pass elides the f32->bf16->f32 converts between
        # stages in a fused graph and the two paths bit-diverge
        disp_e = jax.lax.optimization_barrier(disp_e)
        pre = _expert_quantize(disp_e, quant.a_bits)
        gate = _expert_matmul(params["w_gate"], disp_e, quant, pre,
                              out_dtype=jnp.float32)
        up = _expert_matmul(params["w_up"], disp_e, quant, pre,
                            out_dtype=jnp.float32)
        h = jax.lax.optimization_barrier(
            (jax.nn.silu(gate) * up).astype(x.dtype))
        out = jax.lax.optimization_barrier(
            _expert_matmul(params["w_down"], h, quant))         # (E, G*C, d)
    else:
        # dense (unquantized) fallback -- kept barrier-free: the float
        # path trains, and optimization_barrier has no grad rule
        up = _expert_matmul(params["w_up"], disp_e, quant)
        gate = _expert_matmul(params["w_gate"], disp_e, quant)
        h = (jax.nn.silu(gate.astype(jnp.float32)) * up.astype(jnp.float32)
             ).astype(x.dtype)
        out = _expert_matmul(params["w_down"], h, quant)        # (E, G*C, d)

    out_g = out.reshape(e, g, cap, d).transpose(1, 0, 2, 3)     # (G, E, C, d)
    if g > 1:
        # bring expert outputs back token-local BEFORE the combine gather
        # (all-to-all instead of a model-axis replicating all-gather)
        out_g = constrain(out_g, "moe_combine")
    out_flat = jnp.concatenate(
        [out_g.reshape(g, e * cap, d),
         jnp.zeros((g, 1, d), out.dtype)], 1)
    y = jnp.take_along_axis(out_flat, slot[..., None], 1)
    y = y * (top_p.reshape(g, tg * k)[..., None]
             * keep[..., None]).astype(out.dtype)
    y = y.reshape(g, tg, k, d).sum(2).reshape(t, d)

    if "shared" in params:
        y = y + mlp_apply(params["shared"], xt, cfg, quant=quant)

    # Switch-style load-balance auxiliary loss
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_e[..., 0].reshape(-1), e, dtype=jnp.float32), 0)
    frac_probs = jnp.mean(probs.reshape(-1, e), 0)
    aux = e * jnp.sum(frac_tokens * frac_probs) * cfg.router_aux_coef

    routed = oh.sum(axis=(0, 1))                                # (E,) int32
    load = counts.sum(axis=0)                                   # (E,) int32
    stats = {"load": load,
             "dropped": (routed - load).sum(),
             "capacity": jnp.int32(e * cap * g)}
    return y.reshape(b, s, d), aux, stats
