"""Model assembly: decoder LMs (dense/MoE/SSM/hybrid/VLM) and enc-dec.

A config's layers are described by a *plan* ``[(mixer_kind, ffn_kind)]``.
For compile efficiency at depth (40-72 layers, 512-way SPMD) the plan is
split into a *prelude* (unrolled leading layers that break the repetition,
e.g. deepseek-moe's dense layer 0) and a repeating *unit* scanned with
``jax.lax.scan`` over stacked params -- the jamba 8-layer hybrid group
(7 mamba + 1 attention, alternating MoE/dense FFN) is one unit.

Serving-time quantization is a pure param transform
(:func:`quantize_params`): every APLinear-able weight leaf is replaced by
a packed :class:`BipolarTensor`; apply functions dispatch on leaf type.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bipolar import BipolarTensor
from repro.kernels import ops
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.config import ModelConfig, QuantConfig
from repro.distributed.sharding import constrain

LOSS_CHUNK = 512  # sequence chunk for the CE loss (bounds logits memory)


# ---------------------------------------------------------------------------
# Layer plan
# ---------------------------------------------------------------------------

def layer_plan(cfg: ModelConfig):
    """[(mixer_kind, ffn_kind)] for the decoder stack."""
    return [(cfg.layer_kind(i), cfg.ffn_kind(i)) for i in range(cfg.n_layers)]


def plan_split(cfg: ModelConfig):
    """-> (prelude_plan, unit_plan, n_units). The unit is the smallest
    pattern that tiles the post-prelude plan."""
    plan = layer_plan(cfg)
    prelude = plan[:cfg.first_dense]
    rest = plan[cfg.first_dense:]
    for ul in range(1, len(rest) + 1):
        if len(rest) % ul:
            continue
        unit = rest[:ul]
        if all(rest[i:i + ul] == unit for i in range(0, len(rest), ul)):
            return prelude, unit, len(rest) // ul
    return prelude, rest, 1


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig, mixer_kind: str, ffn_kind: str) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"norm1": L.norm_init(cfg.d_model, cfg)}
    p["mixer"] = (L.attention_init(k1, cfg) if mixer_kind == "attn"
                  else S.ssm_init(k1, cfg))
    if ffn_kind != "none":
        p["norm2"] = L.norm_init(cfg.d_model, cfg)
        p["ffn"] = (L.moe_init(k2, cfg) if ffn_kind == "moe"
                    else L.mlp_init(k2, cfg))
    return p


def _stack_init(key, cfg: ModelConfig, unit_plan, n_units: int):
    """Stacked params for the scanned unit: leaves get a leading n_units dim."""
    def one_unit(k):
        ks = jax.random.split(k, len(unit_plan))
        return [_block_init(ks[i], cfg, mk, fk)
                for i, (mk, fk) in enumerate(unit_plan)]
    keys = jax.random.split(key, n_units)
    units = [one_unit(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *units)


def init_params(cfg: ModelConfig, key) -> dict:
    ke, kp, kb, kh, kenc = jax.random.split(key, 5)
    dt = jnp.dtype(cfg.dtype)
    prelude_plan, unit_plan, n_units = plan_split(cfg)
    params: dict = {
        "embed": L.embed_init(ke, cfg.vocab_padded, cfg.d_model, dt),
        "final_norm": L.norm_init(cfg.d_model, cfg),
    }
    if prelude_plan:
        ks = jax.random.split(kp, len(prelude_plan))
        params["prelude"] = [
            _block_init(ks[i], cfg, mk, fk)
            for i, (mk, fk) in enumerate(prelude_plan)]
    params["blocks"] = _stack_init(kb, cfg, unit_plan, n_units)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.linear_init(kh, cfg.d_model,
                                          cfg.vocab_padded, dt)
    if cfg.family == "audio":
        # encoder stack (non-causal self-attention) + frontend projection
        k_f, k_s, k_n, k_x = jax.random.split(kenc, 4)
        enc_cfg = dataclasses.replace(cfg, n_kv_heads=cfg.n_heads)
        ks = jax.random.split(k_s, cfg.enc_layers)
        params["encoder"] = {
            "frontend": L.linear_init(k_f, cfg.frontend_dim, cfg.d_model, dt),
            "blocks": jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[_block_init(ks[i], enc_cfg, "attn", "dense")
                  for i in range(cfg.enc_layers)]),
            "final_norm": L.norm_init(cfg.d_model, cfg),
        }
        # decoder cross-attention (one per decoder layer, stacked like blocks)
        kx = jax.random.split(k_x, n_units)
        params["cross"] = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[{"attn": L.attention_init(kx[i], cfg),
               "norm": L.norm_init(cfg.d_model, cfg)}
              for i in range(n_units * len(unit_plan))])
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _apply_block(p, x, cfg, mixer_kind, ffn_kind, *, positions, cache,
                 cross_memory=None, cross_params=None, cross_cache=None,
                 quant=None):
    """One transformer block.

    Returns ``(x, (new_cache, new_cross), aux, moe_stats)`` --
    ``moe_stats`` is the :func:`repro.models.layers.moe_apply` telemetry
    dict for MoE blocks and ``None`` otherwise (callers that ignore it
    let XLA dead-code-eliminate the collection).

    Quantized serving with ``quant.fused_linear`` (and the default
    ``residual_scale == 1``) threads the block input as ``residual``
    into the attention output projection and the MLP down projection,
    so the residual add runs in the fused linear's epilogue instead of
    as a separate XLA op -- bit-identical (the unfused add multiplies
    ``h`` by 1.0 in the same dtype).
    """
    rs = jnp.asarray(cfg.residual_scale, x.dtype)
    fuse_res = (quant is not None and quant.enabled and quant.fused_linear
                and cfg.residual_scale == 1.0)
    h = L.norm_apply(p["norm1"], x, cfg)
    if mixer_kind == "attn":
        h, new_cache = L.attention_apply(
            p["mixer"], h, cfg, positions=positions, cache=cache,
            quant=quant, residual=x if fuse_res else None)
        x = h if fuse_res else x + h.astype(x.dtype) * rs
    else:
        h, new_cache = S.ssm_apply(p["mixer"], h, cfg, cache=cache,
                                   quant=quant)
        x = x + h.astype(x.dtype) * rs
    x = constrain(x, "residual")   # SP: keep every residual write
    new_cross = None
    if cross_params is not None:
        hc = L.norm_apply(cross_params["norm"], x, cfg)
        hc, new_cross = L.cross_attention_apply(
            cross_params["attn"], hc, cfg, memory=cross_memory,
            cache=cross_cache, quant=quant,
            residual=x if fuse_res else None)
        x = hc if fuse_res else x + hc.astype(x.dtype) * rs
    aux = 0.0
    moe_stats = None
    if ffn_kind != "none":
        h = L.norm_apply(p["norm2"], x, cfg)
        if ffn_kind == "moe":
            h, aux, moe_stats = L.moe_apply(p["ffn"], h, cfg, quant=quant)
            x = x + h.astype(x.dtype) * rs
        else:
            h = L.mlp_apply(p["ffn"], h, cfg, quant=quant,
                            residual=x if fuse_res else None)
            x = h if fuse_res else x + h.astype(x.dtype) * rs
        x = constrain(x, "residual")
    return x, (new_cache, new_cross), aux, moe_stats


def _make_cache_for(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                    dtype, kv_bits=None):
    if kind == "attn":
        return L.make_kv_cache(cfg, batch, max_len, dtype, kv_bits=kv_bits)
    return S.make_ssm_cache(cfg, batch, dtype)


def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                enc_len: Optional[int] = None,
                quant: Optional[QuantConfig] = None,
                state_batch: Optional[int] = None):
    """Decode caches: {'prelude': [..], 'blocks': stacked-unit caches,
    ['cross': stacked per-unit cross-KV]}.  ``enc_len`` (audio): encoder
    memory length for the projected cross-K/V cache.  ``quant``: its
    ``kv_bits`` (over ``cfg.kv_bits``) selects packed bipolar KV caches
    (self- AND cross-attention).

    The paged serving pool reuses this layout with ``batch=n_blocks,
    max_len=block_size``: every attention leaf's leading (batch, length)
    dims become (block, in-block slot) and requests address blocks
    through per-request block tables (:mod:`repro.serving.paged_cache`).
    ``state_batch`` sizes the *fixed-size per-request* state leaves
    independently of the block count: SSM conv+state and enc-dec
    cross-K/V caches get ``state_batch`` rows (the pool's slot rows,
    addressed through per-request slot ids) while attention KV leaves
    keep ``batch`` blocks.  ``None`` = everything shares ``batch`` (the
    contiguous layout)."""
    from repro.models.config import effective_kv_bits
    dt = jnp.dtype(cfg.dtype)
    kvb = effective_kv_bits(cfg, quant)
    sb = batch if state_batch is None else state_batch
    prelude_plan, unit_plan, n_units = plan_split(cfg)

    def cache_for(mk):
        return _make_cache_for(cfg, mk, batch if mk == "attn" else sb,
                               max_len, dt, kvb)

    caches = {}
    if prelude_plan:
        caches["prelude"] = [cache_for(mk) for mk, _ in prelude_plan]
    unit_caches = [
        [cache_for(mk) for mk, _ in unit_plan]
        for _ in range(n_units)]
    caches["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *unit_caches)
    if cfg.family == "audio":
        if enc_len is None:
            from repro.launch.specs import enc_len as _el
            enc_len = _el(cfg, max_len)
        xc = [[L.make_cross_cache(cfg, sb, enc_len, dt, kv_bits=kvb)
               for _ in unit_plan] for _ in range(n_units)]
        caches["cross"] = jax.tree.map(lambda *xs: jnp.stack(xs), *xc)
    return caches


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig, *,
            positions: Optional[jax.Array] = None,
            caches: Optional[dict] = None,
            patch_embeds: Optional[jax.Array] = None,
            frames: Optional[jax.Array] = None,
            quant: Optional[QuantConfig] = None,
            remat: bool = True,
            logits_mode: str = "none",
            collect_moe_stats: bool = False):
    """Run the stack.  Returns ``(hidden|logits, new_caches, aux_loss)``.

    ``logits_mode``: "none" (return final hidden states), "last" (logits of
    the final position only -- decode), "all" is handled by
    :func:`loss_and_logits` in chunks.

    ``collect_moe_stats=True`` appends a 4th element: the per-MoE-layer
    capacity telemetry ``{"load": (L_moe, E), "dropped": (L_moe,),
    "capacity": (L_moe,)}`` (int32; rows ordered prelude layers first,
    then scanned unit positions with their ``n_units`` stacked per row
    group), or ``None`` if the stack has no MoE layers.
    """
    b, s = tokens.shape
    # a QuantConfig that only sets kv_bits still matters (cache reads);
    # weight-path code checks quant.enabled / leaf types itself
    quant = quant if (quant and (quant.enabled or quant.kv_bits)) else None
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    x = params["embed"]["w"][tokens].astype(jnp.dtype(cfg.dtype))
    x = x * jnp.asarray(cfg.emb_scale, x.dtype)
    x = constrain(x, "residual")
    if patch_embeds is not None:      # vlm stub frontend: fuse patch embeds
        npt = patch_embeds.shape[1]
        x = x.at[:, :npt].add(patch_embeds.astype(x.dtype))

    cross_memory = None
    if cfg.family == "audio" and frames is not None:
        cross_memory = encode_frames(params, frames, cfg, quant=quant,
                                     remat=remat)
    elif cfg.family == "audio":
        assert caches is not None and "cross" in caches, \
            "audio decode without frames needs filled cross caches"

    prelude_plan, unit_plan, n_units = plan_split(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: dict = {}

    # --- prelude (unrolled) ---
    moe_parts = []
    if prelude_plan:
        new_caches["prelude"] = []
        for i, (mk, fk) in enumerate(prelude_plan):
            c = caches["prelude"][i] if caches else None
            x, (nc, _), aux, mst = _apply_block(
                params["prelude"][i], x, cfg, mk, fk,
                positions=positions, cache=c, quant=quant)
            aux_total += aux
            new_caches["prelude"].append(nc)
            if collect_moe_stats and mst is not None:
                moe_parts.append(mst)

    # --- scanned unit stack ---
    cross_stack = params.get("cross")

    def unit_body(x, unit_inp):
        p_unit, c_unit, x_unit, xc_unit = unit_inp
        new_c, new_xc, st_u = [], [], []
        aux_u = jnp.zeros((), jnp.float32)
        for i, (mk, fk) in enumerate(unit_plan):
            xp = (x_unit[i] if x_unit is not None else None)
            x, (nc, nxc), aux, mst = _apply_block(
                p_unit[i], x, cfg, mk, fk, positions=positions,
                cache=(c_unit[i] if c_unit is not None else None),
                cross_memory=cross_memory, cross_params=xp,
                cross_cache=(xc_unit[i] if xc_unit is not None else None),
                quant=quant)
            aux_u += aux
            new_c.append(nc)
            new_xc.append(nxc)
            st_u.append(mst if collect_moe_stats else None)
        x = constrain(x, "residual")
        return x, (new_c, new_xc, aux_u, st_u)

    body = jax.checkpoint(unit_body) if remat else unit_body

    def scan_fn(x, inp):
        x, out = body(x, inp)
        return x, out

    c_blocks = caches["blocks"] if caches else None
    # cross caches are already per-position lists with (n_units, ...) leaves
    xc_blocks = caches["cross"] if caches and "cross" in caches else None
    xs = (params["blocks"],
          c_blocks,
          _restack_cross(cross_stack, len(unit_plan)) if cross_stack else None,
          xc_blocks)
    x, (nc_blocks, nxc_blocks, aux_units, st_units) = \
        jax.lax.scan(scan_fn, x, xs)
    aux_total += aux_units.sum()
    if caches is not None:
        new_caches["blocks"] = nc_blocks
        if xc_blocks is not None:
            new_caches["cross"] = nxc_blocks
    moe_parts += [st for st in st_units if st is not None]

    x = L.norm_apply(params["final_norm"], x, cfg)

    moe_stats = None
    if collect_moe_stats and moe_parts:
        # prelude entries have no leading layer dim; scanned entries carry
        # (n_units, ...) -- normalize each to rows and concatenate
        moe_stats = {
            kk: jnp.concatenate(
                [p[kk][None] if p[kk].ndim == (1 if kk == "load" else 0)
                 else p[kk] for p in moe_parts], 0)
            for kk in ("load", "dropped", "capacity")}

    out = x
    if logits_mode == "last":
        out = _logits(params, x[:, -1:, :], cfg, quant)[:, 0]
    ret = (out, (new_caches if caches is not None else None), aux_total)
    return ret + ((moe_stats,) if collect_moe_stats else ())


def _restack_cross(cross_stack, unit_len: int):
    """(n_units*unit_len, ...) stacked cross-attn params -> a list of
    ``unit_len`` trees with leading dim n_units (scan-sliceable)."""
    return [
        jax.tree.map(
            lambda a: a.reshape(a.shape[0] // unit_len, unit_len,
                                *a.shape[1:])[:, i],
            cross_stack)
        for i in range(unit_len)]


def encode_frames(params, frames, cfg: ModelConfig, *, quant=None,
                  remat=True):
    """Audio/enc-dec encoder: stub frontend embeddings -> memory (B,T,d)."""
    enc = params["encoder"]
    x = L.linear_apply(enc["frontend"], frames.astype(jnp.dtype(cfg.dtype)),
                       quant=quant)
    b, t, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    enc_cfg = dataclasses.replace(cfg, n_kv_heads=cfg.n_heads, causal=False)

    def body(x, p):
        x, _, _, _ = _apply_block(p, x, enc_cfg, "attn", "dense",
                                  positions=positions, cache=None,
                                  quant=quant)
        return x, None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, enc["blocks"])
    return L.norm_apply(enc["final_norm"], x, cfg)


def _logits(params, x, cfg: ModelConfig, quant=None):
    x = x * jnp.asarray(cfg.logit_scale, x.dtype)
    if cfg.tie_embeddings:
        w = params["embed"]["w"]
        logits = jnp.einsum("bsd,vd->bsv", x, w.astype(x.dtype))
    else:
        logits = L.linear_apply(params["lm_head"], x, quant=quant)
    if cfg.vocab_padded > cfg.vocab:   # mask vocab-padding slots
        pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab
        logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype), logits)
    return logits


# ---------------------------------------------------------------------------
# Loss (chunked over the sequence: logits never materialize at (B,S,V))
# ---------------------------------------------------------------------------

def loss_fn(params: dict, batch: dict, cfg: ModelConfig, *,
            quant: Optional[QuantConfig] = None, remat: bool = True):
    """Causal-LM cross-entropy (+ MoE aux). batch: tokens, labels, [mask]."""
    x, _, aux = forward(params, batch["tokens"], cfg,
                        positions=batch.get("positions"),
                        patch_embeds=batch.get("patch_embeds"),
                        frames=batch.get("frames"),
                        quant=quant, remat=remat)
    labels = batch["labels"]
    mask = batch.get("mask")
    mask = (labels >= 0) if mask is None else (mask > 0)
    b, s, d = x.shape
    chunk = min(LOSS_CHUNK, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    mask_full = mask
    labels = jnp.maximum(labels, 0)
    nc = x.shape[1] // chunk
    xc = x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)
    mc = mask_full.reshape(b, nc, chunk).transpose(1, 0, 2)

    def step(carry, inp):
        xs, ls, ms = inp
        logits = _logits(params, xs, cfg, quant).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, ls[..., None], -1)[..., 0]
        nll = (lse - gold) * ms
        return (carry[0] + nll.sum(), carry[1] + ms.sum()), None

    (tot, cnt), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0) + aux


# ---------------------------------------------------------------------------
# Serving-time quantization (the paper's technique as a param transform)
# ---------------------------------------------------------------------------

_QUANT_KEYS = ("wq", "wk", "wv", "wo", "w_up", "w_gate", "w_down",
               "in_proj", "out_proj", "lm_head", "frontend")


def quantize_params(params: Any, qcfg: QuantConfig, stacked: bool = False,
                    _key: str = "") -> Any:
    """Replace every quantizable linear weight with packed bipolar planes.

    ``stacked=True`` marks subtrees whose leaves carry a leading
    scan-stacking dim (``blocks``/``cross``): the packed planes are laid
    out ``(n_units, n_bits, ..., Kw)`` so ``lax.scan`` slices the unit
    axis and each slice is a well-formed packed tensor whose *static*
    metadata (shape, n_bits) describes the per-unit weight.

    Router, norms, embeddings and SSM state/conv params stay in bf16
    (DESIGN.md §4 caveats).
    """
    if not qcfg.enabled:
        return params
    if isinstance(params, dict):
        out = {}
        for k, v in params.items():
            sub_stacked = stacked or k in ("blocks", "cross")
            if k in _QUANT_KEYS and isinstance(v, dict) and "w" in v \
                    and not isinstance(v["w"], BipolarTensor):
                out[k] = {"w": _quantize_leaf(v["w"], qcfg, stacked)}
            elif k in ("w_up", "w_gate", "w_down") and isinstance(v, jax.Array) \
                    and v.ndim >= 3:
                out[k] = _quantize_leaf(v, qcfg, stacked)  # stacked MoE experts
            else:
                out[k] = quantize_params(v, qcfg, sub_stacked, k)
        return out
    if isinstance(params, (list, tuple)):
        return type(params)(quantize_params(v, qcfg, stacked, _key)
                            for v in params)
    return params


def _quantize_leaf(w: jax.Array, qcfg: QuantConfig,
                   stacked: bool) -> BipolarTensor:
    """Pack a weight leaf ``(*lead, N, K)`` along K.

    Unstacked: packed ``(n_bits, *lead, N, Kw)``, static shape = w.shape.
    Stacked:   leading dim u = scan units; packed ``(u, n_bits, *rest, Kw)``
    and static shape = per-unit shape ``w.shape[1:]`` (what apply code sees
    after the scan slice).

    The per-width nested scales ride along the same way (unstacked
    ``(n_bits, *lead, N, 1)``; stacked ``(u, n_bits, *rest, 1)``), so a
    scan slice -- which peels the unit axis off every array leaf -- always
    hands ops a plane-leading tensor that ``bipolar.nested_slice`` can
    serve at any width k <= w_bits.
    """
    shape = tuple(w.shape)
    w2 = w.reshape(-1, shape[-1]).astype(jnp.float32)
    t = ops.quantize_rows(w2, qcfg.w_bits, pad_bit=1, impl="reference",
                          scale_search=True)
    kw = t.packed.shape[-1]
    packed = t.packed.reshape(qcfg.w_bits, *shape[:-1], kw)
    scale = t.scale.reshape(*shape[:-1], 1)
    width_scales = None
    if t.width_scales is not None:
        width_scales = t.width_scales.reshape(
            t.n_bits, *shape[:-1], 1)
    if stacked:
        packed = jnp.moveaxis(packed, 0, 1)  # (u, n_bits, *rest, Kw)
        if width_scales is not None:
            width_scales = jnp.moveaxis(width_scales, 0, 1)
        static_shape = shape[1:]
    else:
        static_shape = shape
    return BipolarTensor(packed=packed, scale=scale, n_bits=qcfg.w_bits,
                         shape=static_shape,
                         pack_axis=len(static_shape) - 1,
                         width_scales=width_scales)
