"""Mamba-2 (SSD, state-space duality) mixer in pure JAX.

Chunked SSD algorithm (Dao & Gu, arXiv:2405.21060): the sequence is split
into chunks of length L; within a chunk the output is an attention-like
quadratic form with a causal decay mask, across chunks a small recurrent
state ``(B, H, P, N)`` is carried by a scan.  Decode is the O(1) exact
recurrence on that state (this is what makes SSM/hybrid archs runnable at
``long_500k``).

GEMM-shaped projections (in/out) go through ``linear_apply`` and are
therefore arbitrary-precision-quantizable (paper technique); the selective
state update itself is not a GEMM and stays bf16/f32 (DESIGN.md §4).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import linear_apply, linear_init


def ssm_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = cfg.ssm_d_inner
    h, p, n, g = cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_d_state, cfg.ssm_n_groups
    conv_dim = di + 2 * g * n
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    # in_proj emits [z (di), xBC (di + 2*g*n), dt (h)]
    return {
        "in_proj": linear_init(k1, d, 2 * di + 2 * g * n + h, dt),
        "out_proj": linear_init(k2, di, d, dt),
        "conv_w": (jax.random.normal(k3, (cfg.ssm_d_conv, conv_dim))
                   / np.sqrt(cfg.ssm_d_conv)).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(k4, (h,), jnp.float32,
                                       np.log(1e-3), np.log(1e-1))))),
        "norm_scale": jnp.ones((di,), jnp.float32),
    }


def _segsum(a):
    """Causal cumulative sums: out[..., i, j] = sum_{j < k <= i} a[..., k].

    Returns -inf above the diagonal (used as log-decay mask)."""
    l = a.shape[-1]
    cs = jnp.cumsum(a, -1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, diff, -jnp.inf)


def _ssd_chunked(x, dt, a, b, c, chunk: int, init_state=None):
    """Chunked SSD scan.

    x: (B, S, H, P) input (already dt-scaled outside? no -- raw), dt: (B, S, H)
    softplus'd step, a: (H,) negative decay rates, b/c: (B, S, G, N).
    ``init_state (B,H,P,N)`` seeds the inter-chunk recurrence (chunked
    prefill continuing a cached state); None starts from zero.
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    l = chunk
    assert s % l == 0, (s, l)
    nc = s // l
    rep = h // g

    xd = (x * dt[..., None]).astype(jnp.float32)       # input scaling
    adt = (a[None, None, :] * dt).astype(jnp.float32)  # (B, S, H) log decay
    # reshape to chunks
    xc = xd.reshape(bsz, nc, l, h, p)
    ac = adt.reshape(bsz, nc, l, h)
    bc_ = b.reshape(bsz, nc, l, g, n).astype(jnp.float32)
    cc = c.reshape(bsz, nc, l, g, n).astype(jnp.float32)
    bh = jnp.repeat(bc_, rep, axis=3)                  # broadcast groups->heads
    ch = jnp.repeat(cc, rep, axis=3)

    # --- intra-chunk (quadratic, attention-like) ---
    lmat = jnp.exp(_segsum(ac.transpose(0, 1, 3, 2)))  # (B,nc,H,L,L)
    scores = jnp.einsum("bclhn,bcshn->bchls", ch, bh)  # (B,nc,H,L,L)
    y_diag = jnp.einsum("bchls,bcshp->bclhp", scores * lmat, xc)

    # --- chunk states ---
    a_cum = jnp.cumsum(ac, axis=2)                     # (B,nc,L,H)
    a_tot = a_cum[:, :, -1, :]                         # (B,nc,H)
    decay_states = jnp.exp(a_tot[:, :, None, :] - a_cum)  # (B,nc,L,H)
    states = jnp.einsum("bclhn,bclh,bclhp->bchpn", bh, decay_states, xc)

    # --- inter-chunk recurrence with STREAMED off-diagonal outputs ---
    # (computing y_off inside the scan avoids stacking all (B,nc,H,P,N)
    # chunk states -- that stash dominated the jamba-398B memory roofline,
    # EXPERIMENTS.md §Perf iter 4)
    state_decay = jnp.exp(a_cum)                        # (B,nc,L,H)

    def step(h_prev, inp):
        st, atot, ch_c, sdec_c = inp     # (B,H,P,N) (B,H) (B,L,H,N) (B,L,H)
        y_off_c = jnp.einsum("blhn,bhpn,blh->blhp", ch_c, h_prev, sdec_c)
        h_new = h_prev * jnp.exp(atot)[:, :, None, None] + st
        return h_new, y_off_c

    init = (jnp.zeros((bsz, h, p, n), jnp.float32) if init_state is None
            else init_state.astype(jnp.float32))
    final, y_off = jax.lax.scan(
        step, init, (states.transpose(1, 0, 2, 3, 4),
                     a_tot.transpose(1, 0, 2),
                     ch.transpose(1, 0, 2, 3, 4),
                     state_decay.transpose(1, 0, 2, 3)))
    y_off = y_off.transpose(1, 0, 2, 3, 4)              # (B,nc,L,H,P)

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y, final


def ssm_apply(params: dict, x: jax.Array, cfg: ModelConfig, *,
              cache: Optional[dict] = None, quant=None):
    """Mamba-2 mixer over ``x (B, S, d_model)``.

    With ``cache`` and S == 1 (decode): the conv buffer and SSD state
    are updated in O(1).  With ``cache`` and S > 1 (prefill / chunked
    prefill): the pass *continues* from the cached conv rows and SSD
    state and leaves the cache ready for the next chunk or decode step
    -- a zeroed cache makes this identical to prefilling from scratch.
    Returns ``(y, new_cache)``.

    Paged serving hands the cache as *slot-pool rows*: conv/state leaves
    are ``(n_slots+1, ...)`` and ``cache["slots"] (B,)`` maps batch lanes
    to rows (slot 0 reserved null, -1 = padded lane).  The batch's rows
    are gathered, the ordinary recurrence runs on the local view, and
    the updated state scatters back (padded-lane writes dropped) -- slot
    addressing changes memory management, not math.
    """
    if cache is not None and "slots" in cache:
        slots = cache["slots"]                       # (B,) int32
        rows = cache["state"].shape[0]
        safe = jnp.clip(slots, 0, rows - 1)
        local = {"conv": cache["conv"][safe], "state": cache["state"][safe]}
        y, new_local = ssm_apply(params, x, cfg, cache=local, quant=quant)
        idx = jnp.where(slots >= 0, slots, rows)     # OOB -> dropped
        new_cache = dict(
            cache,
            conv=cache["conv"].at[idx].set(
                new_local["conv"].astype(cache["conv"].dtype),
                mode="drop"),
            state=cache["state"].at[idx].set(
                new_local["state"].astype(cache["state"].dtype),
                mode="drop"))
        return y, new_cache
    bsz, s, _ = x.shape
    di = cfg.ssm_d_inner
    h, p, n, g = cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_d_state, cfg.ssm_n_groups
    conv_dim = di + 2 * g * n

    zxbcdt = linear_apply(params["in_proj"], x, quant=quant)
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, di + conv_dim], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"])           # (B,S,H)
    a = -jnp.exp(params["A_log"])                       # (H,) negative

    new_cache = None
    if cache is None or s > 1:
        # causal depthwise conv along S (window d_conv).  With a cache
        # the buffer holds the previous d_conv-1 raw xBC rows, so an
        # s > 1 pass CONTINUES where the last chunk (or decode step)
        # stopped -- chunked prefill's contract.  A fresh cache is
        # zeros, which reproduces the old zero padding exactly
        pad = cfg.ssm_d_conv - 1
        if cache is not None:
            xbc_p = jnp.concatenate(
                [cache["conv"].astype(xbc.dtype), xbc], axis=1)
        else:
            xbc_p = jnp.pad(xbc, ((0, 0), (pad, 0), (0, 0)))
        windows = jnp.stack(
            [xbc_p[:, i:i + s, :] for i in range(cfg.ssm_d_conv)], axis=2)
        xbc_c = jnp.einsum("bswc,wc->bsc", windows.astype(jnp.float32),
                           params["conv_w"].astype(jnp.float32))
        xbc_c = jax.nn.silu(xbc_c + params["conv_b"].astype(jnp.float32))
        xs, b, c = jnp.split(xbc_c, [di, di + g * n], axis=-1)
        xh = xs.reshape(bsz, s, h, p)
        bh = b.reshape(bsz, s, g, n)
        ch = c.reshape(bsz, s, g, n)
        pad_s = (-s) % cfg.ssm_chunk
        if pad_s:
            xh = jnp.pad(xh, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad_s), (0, 0)))
            bh = jnp.pad(bh, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
            ch = jnp.pad(ch, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        y, state = _ssd_chunked(xh, dt, a, bh, ch, cfg.ssm_chunk,
                                init_state=(None if cache is None
                                            else cache["state"]))
        # D skip connection on the conv'd input
        y = y[:, :s] + (params["D"][None, None, :, None]
                        * xh[:, :s].astype(jnp.float32))
        if cache is not None:
            # fill the decode cache: conv tail = last d_conv-1 raw xBC
            # rows of the continued buffer (a chunk shorter than the
            # conv window keeps the older cached rows it still needs)
            new_cache = dict(
                cache, state=state,
                conv=xbc_p[:, s:s + pad].astype(cache["conv"].dtype))
    else:
        assert s == 1
        # update conv ring buffer: (B, d_conv-1, conv_dim) holds last inputs
        conv_buf = cache["conv"]
        window = jnp.concatenate([conv_buf, xbc.astype(conv_buf.dtype)], 1)
        xbc_c = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                           params["conv_w"].astype(jnp.float32))
        xbc_c = jax.nn.silu(xbc_c + params["conv_b"].astype(jnp.float32))
        xs, b, c = jnp.split(xbc_c, [di, di + g * n], axis=-1)
        xh = xs.reshape(bsz, h, p)
        bh = jnp.repeat(b.reshape(bsz, g, n), h // g, axis=1)
        ch = jnp.repeat(c.reshape(bsz, g, n), h // g, axis=1)
        dt1 = dt[:, 0, :]                               # (B,H)
        decay = jnp.exp(a[None, :] * dt1)               # (B,H)
        ssd_state = cache["state"]                      # (B,H,P,N) f32
        upd = jnp.einsum("bhp,bhn->bhpn", xh * dt1[..., None], bh)
        state = ssd_state * decay[:, :, None, None] + upd
        y1 = jnp.einsum("bhpn,bhn->bhp", state, ch)
        y1 = y1 + params["D"][None, :, None] * xh
        y = y1[:, None, :, :]                           # (B,1,H,P)
        new_cache = dict(cache, conv=window[:, 1:], state=state)

    y = y.reshape(bsz, s, di)
    # gated RMSNorm (mamba2's norm-before-out-proj)
    yz = y * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(yz), -1, keepdims=True)
    yz = yz * jax.lax.rsqrt(ms + 1e-5) * params["norm_scale"]
    out = linear_apply(params["out_proj"], yz.astype(x.dtype), quant=quant)
    if cache is None:
        return out, None
    return out, new_cache


def make_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    conv_dim = cfg.ssm_d_inner + 2 * cfg.ssm_n_groups * cfg.ssm_d_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_d_conv - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, cfg.ssm_n_heads, cfg.ssm_head_dim,
                            cfg.ssm_d_state), jnp.float32),
    }
