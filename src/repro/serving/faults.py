"""FaultInjector: deterministic, seeded fault injection for the serving
stack -- the chaos-testing twin of ``repro.obs.ServingObs``.

The engine, scheduler, and pool each carry a fault facade and consult it
at a fixed set of *injection sites*.  Every site method returns True
("fire the fault here") with an independent per-site probability, drawn
from ONE seeded ``numpy`` Generator in call order -- so a given
``(seed, workload)`` pair replays the exact same fault schedule, which
is what lets tests/test_chaos.py compare a faulted run against its
fault-free twin token for token.

Sites, by subsystem (each maps to a recovery path the chaos suite
asserts):

* **pool** -- ``alloc_fail`` / ``slot_fail`` raise the pool's own
  exhaustion ``RuntimeError`` *before any state mutates* (alloc is
  atomic: it either completes or leaves the pool untouched), and
  ``forced_evict`` evicts one LRU-cached block on an otherwise
  satisfiable alloc (prefix-cache pressure: hits become misses, math is
  unchanged).
* **scheduler** -- ``admit_race`` makes an admission probe lose its
  capacity race for one step (the head retries next step);
  ``preempt_storm`` evicts the youngest running request before the real
  capacity loop runs (recompute restarts are token-identical by the
  seeded-sampling contract).
* **engine** -- ``nan_logits`` poisons one request's logits row for one
  step (containment must quarantine exactly that request);
  ``callback_error`` makes a request's ``on_token`` delivery raise;
  ``wrap_clock`` returns a clock that occasionally jumps forward by
  ``clock_jump`` seconds (deadline storms).

``NULL_FAULTS`` is the disabled twin, mirroring ``NULL_OBS``: a
stateless ``__slots__ = ()`` singleton whose site checks are constant
``False`` -- the hot path pays one attribute access + one no-op call per
site and the engine stays token-identical to a build without the
injection points (benchmarks/fault_recovery.py gates the cost).

:class:`RequestFault` lives here (not in engine.py) so the scheduler's
admission-rollback path can distinguish a *per-request* fault (the
request finishes with ``finish_reason='error'``) from a *transient
pool* fault (the request re-queues and the step retries) without a
circular import.
"""

from __future__ import annotations

import time
from collections import Counter
from typing import Callable, Optional

import numpy as np

__all__ = ["FaultInjector", "NULL_FAULTS", "RequestFault"]


class RequestFault(Exception):
    """A fault attributable to ONE request (poisoned logits, a raising
    ``on_token`` callback): step-level containment quarantines that
    request -- ``finish_reason='error'``, blocks/slots released through
    the refcount path -- and the rest of the batch proceeds untouched.
    ``kind`` labels the ``repro_engine_fault_requests`` counter."""

    def __init__(self, msg: str, kind: str = "exception"):
        super().__init__(msg)
        self.kind = kind


class FaultInjector:
    """Seeded fault schedule over the serving stack's injection sites.

    Probabilities are per *site consultation*, drawn in call order from
    one ``default_rng(seed)`` stream: deterministic for a fixed
    workload, independent across sites.  ``fired`` tallies what
    actually fired (the chaos suite asserts coverage); ``bind`` mirrors
    the schedule into the shared metrics registry as
    ``repro_faults_injected{site=...}``.
    """

    enabled = True

    def __init__(self, seed: int = 0, *,
                 p_alloc_fail: float = 0.0,
                 p_slot_fail: float = 0.0,
                 p_forced_evict: float = 0.0,
                 p_admit_race: float = 0.0,
                 p_preempt_storm: float = 0.0,
                 p_nan_logits: float = 0.0,
                 p_callback_error: float = 0.0,
                 p_clock_jump: float = 0.0,
                 clock_jump: float = 3600.0):
        self.seed = int(seed)
        self._rng = np.random.default_rng(np.random.SeedSequence(seed))
        self.p_alloc_fail = p_alloc_fail
        self.p_slot_fail = p_slot_fail
        self.p_forced_evict = p_forced_evict
        self.p_admit_race = p_admit_race
        self.p_preempt_storm = p_preempt_storm
        self.p_nan_logits = p_nan_logits
        self.p_callback_error = p_callback_error
        self.p_clock_jump = p_clock_jump
        self.clock_jump = clock_jump
        self.fired: Counter = Counter()     # site -> times fired
        self._c_injected = None             # registry counter (bind)
        self._children: dict = {}

    # -- registry ------------------------------------------------------------
    def bind(self, registry) -> None:
        """Declare the injection counter on the shared metrics registry
        (the engine calls this with the pool's registry so one render()
        scrapes faults alongside the recovery counters)."""
        if registry is None or self._c_injected is not None:
            return
        self._c_injected = registry.counter(
            "repro_faults_injected",
            "faults fired by the seeded injector, by site",
            labelnames=("site",))

    def _fire(self, site: str, p: float) -> bool:
        if p <= 0.0 or self._rng.random() >= p:
            return False
        self.fired[site] += 1
        if self._c_injected is not None:
            child = self._children.get(site)
            if child is None:
                child = self._c_injected.labels(site=site)
                self._children[site] = child
            child.inc()
        return True

    # -- pool sites ----------------------------------------------------------
    def alloc_fail(self, n: int) -> bool:
        """Consulted at :meth:`PagedKVPool.alloc` entry, before any
        mutation: True simulates exhaustion on an otherwise satisfiable
        allocation."""
        return self._fire("alloc_fail", self.p_alloc_fail)

    def slot_fail(self) -> bool:
        """Consulted at :meth:`PagedKVPool.alloc_slot` entry."""
        return self._fire("slot_fail", self.p_slot_fail)

    def forced_evict(self) -> bool:
        """Consulted once per :meth:`PagedKVPool.alloc`: True evicts one
        LRU-cached block even though the free list could satisfy the
        request (simulated cache pressure)."""
        return self._fire("forced_evict", self.p_forced_evict)

    # -- scheduler sites -----------------------------------------------------
    def admit_race(self) -> bool:
        """Consulted at the top of each admission probe: True makes the
        head lose this step's capacity race (clean break, retried)."""
        return self._fire("admit_race", self.p_admit_race)

    def preempt_storm(self) -> bool:
        """Consulted repeatedly before the capacity loop: each True
        evicts the youngest running request (drawn again until False, so
        one storm can evict several)."""
        return self._fire("preempt_storm", self.p_preempt_storm)

    # -- engine sites --------------------------------------------------------
    def nan_logits(self, req) -> bool:
        """Consulted per (step, sampled request): True poisons the
        request's logits row with NaN before sampling."""
        return self._fire("nan_logits", self.p_nan_logits)

    def callback_error(self, req) -> bool:
        """Consulted per ``on_token`` delivery: True makes the delivery
        raise a :class:`RequestFault` as if the callback threw."""
        return self._fire("callback_error", self.p_callback_error)

    def wrap_clock(self, clock: Optional[Callable[[], float]]
                   ) -> Callable[[], float]:
        """Wrap the engine's clock: each read may jump the clock forward
        by ``clock_jump`` seconds (the offset is cumulative and
        monotone, so wrapped time never runs backward)."""
        base = clock or time.monotonic
        if self.p_clock_jump <= 0.0:
            return base
        state = {"offset": 0.0}

        def jumping() -> float:
            if self._fire("clock_jump", self.p_clock_jump):
                state["offset"] += self.clock_jump
            return base() + state["offset"]

        return jumping


class _NullFaults:
    """Disabled twin of :class:`FaultInjector`: every site check is a
    constant ``False`` -- no RNG draws, no allocation, nothing retained.
    One shared singleton (``NULL_FAULTS``) serves every engine that was
    not handed an injector, keeping the default hot path token-identical
    (benchmarks/fault_recovery.py gates the residual cost against the
    BENCH_obs_overhead bound)."""

    __slots__ = ()
    enabled = False
    fired: Counter = Counter()

    def bind(self, registry) -> None:
        pass

    def alloc_fail(self, n) -> bool:
        return False

    def slot_fail(self) -> bool:
        return False

    def forced_evict(self) -> bool:
        return False

    def admit_race(self) -> bool:
        return False

    def preempt_storm(self) -> bool:
        return False

    def nan_logits(self, req) -> bool:
        return False

    def callback_error(self, req) -> bool:
        return False

    def wrap_clock(self, clock):
        return clock or time.monotonic


NULL_FAULTS = _NullFaults()
