"""Serving runtime: prefill/decode steps and a continuous-batching engine.

The jitted steps are the units the dry-run lowers (``serve_step`` = one new
token against a KV cache of the cell's sequence length).  The engine wraps
them with slot-based continuous batching: a fixed decode batch of ``B``
slots, each slot independently holding one request's KV state; finished
slots are refilled from the queue without stopping the other slots
(per-slot cache write indices -- see ``make_kv_cache``).

Serving uses quantized packed weights (the paper's technique); pass
``quant=cfg.quant`` after :func:`repro.models.model.quantize_params`.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig, QuantConfig


# ---------------------------------------------------------------------------
# Jitted steps
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg", "quant"))
def prefill_step(params, batch: dict, caches, cfg: ModelConfig,
                 quant: Optional[QuantConfig] = None):
    """Process a full prompt, filling the caches.

    Returns ``(last_logits (B, V), caches)``.
    """
    logits, caches, _ = M.forward(
        params, batch["tokens"], cfg,
        positions=batch.get("positions"),
        patch_embeds=batch.get("patch_embeds"),
        frames=batch.get("frames"),
        caches=caches, quant=quant, remat=False, logits_mode="last")
    return logits, caches


@partial(jax.jit, static_argnames=("cfg", "quant"))
def serve_step(params, batch: dict, caches, cfg: ModelConfig,
               quant: Optional[QuantConfig] = None):
    """One decode step: one new token per sequence against the caches.

    ``batch``: tokens (B, 1), positions (B, 1) (or (3, B, 1) M-RoPE).
    Returns ``(logits (B, V), caches)``.
    """
    logits, caches, _ = M.forward(
        params, batch["tokens"], cfg,
        positions=batch["positions"],
        caches=caches, quant=quant, remat=False, logits_mode="last")
    return logits, caches


def kv_cache_bytes(caches, *, payload_only: bool = False) -> int:
    """Total bytes of the attention KV state in a cache tree.

    Counts ``k``/``v`` buffers plus (unless ``payload_only``) their
    quantization scales; positions/indices/SSM state are bookkeeping
    shared by every format and excluded.  With bipolar ``kv_bits`` caches
    the payload is exactly ``kv_bits/16`` of the bf16 payload (modulo the
    32-element word rounding of the head dim).
    """
    keys = ("k", "v") if payload_only else ("k", "v", "k_scale", "v_scale")

    def leaf_bytes(path, leaf):
        names = [getattr(p, "key", None) for p in path]
        name = next((n for n in reversed(names) if n), "")
        if name not in keys or not hasattr(leaf, "nbytes"):
            return 0
        return int(leaf.nbytes)

    flat = jax.tree_util.tree_flatten_with_path(caches)[0]
    return sum(leaf_bytes(path, leaf) for path, leaf in flat)


def sample(logits: jax.Array, *, temperature: float = 0.0,
           key=None) -> jax.Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, -1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Continuous-batching engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    prompt: np.ndarray              # (s,) int32
    max_new_tokens: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


def _tree_write_slot(batched, single, slot: int):
    """Insert a B=1 cache/state tree into batch position ``slot``.

    The batch dim is 0 for prelude caches but 1 for scanned-stack caches
    (leaves carry a leading n_units dim)."""
    def wr_at(bdim):
        def wr(b, s):
            start = (0,) * bdim + (slot,) + (0,) * (b.ndim - bdim - 1)
            return jax.lax.dynamic_update_slice(b, s.astype(b.dtype), start)
        return wr

    out = dict(batched)
    for key in batched:
        bdim = 0 if key == "prelude" else 1
        out[key] = jax.tree.map(wr_at(bdim), batched[key], single[key])
    return out


class Engine:
    """Slot-based continuous batching over the jitted steps.

    Each of the ``n_slots`` decode lanes owns one request at a time.
    Prefill runs per-request at B=1 (bucketed to ``prefill_len``) and the
    resulting KV state is scattered into the lane's slice of the batched
    cache; decode advances all active lanes in lock-step.
    """

    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 4,
                 max_len: int = 256, quant: Optional[QuantConfig] = None):
        self.params, self.cfg, self.quant = params, cfg, quant
        self.n_slots, self.max_len = n_slots, max_len
        self.caches = M.init_caches(cfg, n_slots, max_len, quant=quant)
        self.slot_req: list[Optional[Request]] = [None] * n_slots
        self.lengths = np.zeros(n_slots, np.int32)     # tokens seen per slot
        self.last_tok = np.zeros(n_slots, np.int32)    # next input token
        self.queue: list[Request] = []
        self.steps = 0

    # -- request lifecycle -------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.n_slots):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.pop(0)
                self._prefill_into(req, slot)
                self.slot_req[slot] = req

    def _prefill_into(self, req: Request, slot: int):
        s = len(req.prompt)
        one = M.init_caches(self.cfg, 1, self.max_len, quant=self.quant)
        batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None, :]}
        if self.cfg.family == "vlm":
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32), (3, 1, s))
            batch["patch_embeds"] = jnp.zeros(
                (1, min(self.cfg.n_patches, s), self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        if self.cfg.family == "audio":
            from repro.launch.specs import enc_len
            batch["frames"] = jnp.zeros(
                (1, enc_len(self.cfg, s), self.cfg.frontend_dim),
                jnp.dtype(self.cfg.dtype))
        logits, one = prefill_step(self.params, batch, one, self.cfg,
                                   self.quant)
        self.caches = _tree_write_slot(self.caches, one, slot)
        self.lengths[slot] = s
        self.last_tok[slot] = int(np.argmax(np.asarray(logits[0])))
        req.out.append(int(self.last_tok[slot]))

    # -- decode loop --------------------------------------------------------
    def step(self):
        """One batched decode step across all active slots."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return False
        toks = jnp.asarray(self.last_tok, jnp.int32)[:, None]
        pos = jnp.asarray(self.lengths, jnp.int32)[:, None]
        if self.cfg.family == "vlm":
            pos = jnp.broadcast_to(pos[None], (3, self.n_slots, 1))
        batch = {"tokens": toks, "positions": pos}
        logits, self.caches = serve_step(self.params, batch, self.caches,
                                         self.cfg, self.quant)
        nxt = np.array(sample(logits))  # writable copy
        self.steps += 1
        for slot in active:
            req = self.slot_req[slot]
            req.out.append(int(nxt[slot]))
            self.lengths[slot] += 1
            if len(req.out) >= req.max_new_tokens \
                    or self.lengths[slot] >= self.max_len - 1:
                req.done = True
                self.slot_req[slot] = None
        self.last_tok = nxt
        return True

    def run(self, max_steps: int = 10_000):
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and self.steps < max_steps:
            if not self.step():
                break
