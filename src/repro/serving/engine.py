"""Serving runtime: prefill/decode steps and continuous-batching engines.

The jitted steps are the units the dry-run lowers (``serve_step`` = one new
token against a KV cache of the cell's sequence length).  The engine wraps
them with continuous batching in one of two memory regimes:

* **contiguous** (``paged=False``): a fixed decode batch of ``n_slots``
  lanes, each lane owning one request's ``(max_len,)`` KV slab; finished
  lanes are refilled from the queue without stopping the others.
* **paged** (``paged=True``): requests share a refcounted copy-on-write
  block pool of packed bipolar-INT KV planes
  (:mod:`repro.serving.paged_cache`) addressed through per-request block
  tables, scheduled by :mod:`repro.serving.scheduler` -- FCFS admission
  gated on free blocks, decode batches bucketed to powers of two,
  preemption-by-eviction when the pool runs dry.  Capacity scales with
  tokens actually resident x ``kv_bits``/16, not ``n_slots x max_len``,
  and the pool's *prefix cache* shares blocks between requests with a
  common prompt prefix: admission acquires the cached blocks and
  prefills only the **suffix**, directly through the block table
  (``_paged_prefill``).  Sliding-window archs may run ``window <
  max_len``: blocks whose tokens all fall out of the window are
  reclaimed each step, so tables are rolling windows and steady-state
  decode memory is O(window) per request.  SSM/hybrid state and
  enc-dec cross caches ride a fixed-size *state slot pool* (one slot
  per request), so every config family serves from this one engine.

Prefill always runs per-request at B=1, with the prompt (paged: the
uncached suffix) *bucketed to the next power of two* (padded tokens
carry position -1 and are masked out of every attention read and every
pool write), so a stream of varied lengths compiles O(log max_len)
programs instead of one per distinct length.

**Chunked prefill** (``chunk_tokens``, paged only): a prompt no longer
prefills whole at admission -- it streams through the step loop
``chunk_tokens`` at a time, fused with the decode batch
(:meth:`Engine._fused_forward`): decode lanes carry 1 real token and
chunk lanes up to ``chunk_tokens``, all padded to one bucketed ``(B,
S)`` dispatch whose pad rows are position-masked by the Sq>=1 paged
kernel.  Running decodes therefore emit a token *every* step while a
long prompt trickles in, instead of stalling O(prompt).  SSM/hybrid
archs cannot pad the recurrence, so their mixed steps split into one
decode dispatch plus exact-length B=1 chunk dispatches riding the
cached conv/state continuation (:mod:`repro.models.ssm`); vlm/audio
frontends fill their side inputs in one pass and keep whole-prompt
admission.

The submit/stream API is asynchronous at the request level:
:meth:`Engine.submit` returns a :class:`StreamHandle` (iterate tokens
as they are emitted, poll, cancel); requests take ``on_token``
callbacks (fired in emission order), ``timeout`` deadlines (expiry
finishes the request with ``finish_reason='timeout'``), and
cancellation releases blocks and state slots through the scheduler's
refcount path mid-prefill or mid-decode.

**Observability** (``metrics=...``, default off): the engine reports
through a :class:`repro.obs.ServingObs` facade -- per-request lifecycle
traces (queued/running/chunk_prefill/decode spans, token instants,
TTFT/inter-token histograms, Perfetto export) plus step-loop gauges
(batch lanes live vs padded, chunk-budget utilization, pool occupancy)
in the SAME metrics registry the pool's and scheduler's counters live
in, so ``report()``, ``registry.render()``, and the benchmarks can
never disagree.  Every timestamp goes through the engine's injectable
``clock`` (traces are deterministic under test), and the default is
the no-op ``NULL_OBS`` sink: hooks cost one constant no-op call, no
clock read, no allocation -- the hot path and token-identity are
untouched when observability is off.

**Robustness** (``faults=...``, ``max_queue=...``, ``validate_every=...``):
the engine contains failures at the *request* level, never the step
level.  A non-finite logits row or an ``on_token`` callback exception
quarantines exactly the offending sequence -- ``finish_reason='error'``,
the error surfaced on ``StreamHandle.result().error``, its blocks and
state slot released through the refcount path -- while the rest of the
batch keeps producing bit-identical tokens to a fault-free run.
``finish_reason`` is always one of :attr:`Request.FINISH_REASONS`
(``length | timeout | cancelled | rejected | error``).  ``max_queue=N``
bounds the waiting queue: submits past the bound are shed with
``finish_reason='rejected'`` and a ``retry_after`` hint derived from
queue depth and pool occupancy (``StreamHandle.resubmit`` retries with
capped exponential backoff).  ``validate_every=N`` runs the pool's
invariant checker off the hot path every N steps; a violation
quarantines the corrupt chains and rebuilds the free lists instead of
raising.  ``faults=FaultInjector(seed, ...)`` threads a deterministic,
seeded fault schedule through the pool, scheduler, and engine
(:mod:`repro.serving.faults`) so tests/test_chaos.py can prove all of
the above; the default ``NULL_FAULTS`` twin keeps the hot path
token-identical with faults off.

**Nested precision** (``Request.precision``, paged): a checkpoint packed
at ``quant.w_bits`` with per-width scales serves any width ``k <=
w_bits`` by reading only the leading ``k`` bit planes
(:func:`repro.core.bipolar.nested_slice` -- no repacking, weight HBM
traffic scales with ``k``).  Each request may ask for its own width;
:func:`tier_bits` resolves it against the configured
``quant.precision_floor`` load-adaptive policy (bits shed under queue
pressure, floor-clamped, restored as the queue drains) and the result
is **frozen at first admission** -- precision never changes
mid-request, preemption re-admits at the same bits.  The step loop
groups lanes per precision (quant is jit-static: one compiled program
per served width) and the prefix cache salts its chain hashes with the
lane's bits, so equal prompts share KV only at equal precision.  Tokens
emitted per width surface as ``repro_engine_precision{bits}``.

Serving uses quantized packed weights (the paper's technique); pass
``quant=cfg.quant`` after :func:`repro.models.model.quantize_params`.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig, QuantConfig
from repro.obs import NULL_OBS, MetricsRegistry, ServingObs
from repro.serving.faults import NULL_FAULTS, RequestFault


# ---------------------------------------------------------------------------
# Jitted steps
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg", "quant"))
def prefill_step(params, batch: dict, caches, cfg: ModelConfig,
                 quant: Optional[QuantConfig] = None):
    """Process a full prompt, filling the caches.

    Returns ``(last_logits (B, V), caches)``.
    """
    logits, caches, _ = M.forward(
        params, batch["tokens"], cfg,
        positions=batch.get("positions"),
        patch_embeds=batch.get("patch_embeds"),
        frames=batch.get("frames"),
        caches=caches, quant=quant, remat=False, logits_mode="last")
    return logits, caches


@partial(jax.jit, static_argnames=("cfg", "quant", "moe_stats"))
def prefill_step_bucketed(params, batch: dict, caches, cfg: ModelConfig,
                          quant: Optional[QuantConfig] = None,
                          moe_stats: bool = False):
    """Prefill a length-bucketed prompt: tokens are padded past the real
    length (pad positions -1, masked everywhere) and the logits are taken
    at ``batch["last_idx"]`` (B,) -- the last *real* token -- instead of
    the last padded position.  Jits once per bucket, not per length.

    ``moe_stats=True`` (static) appends the per-MoE-layer capacity
    telemetry dict (:func:`repro.models.model.forward`) to the return.
    """
    out = M.forward(
        params, batch["tokens"], cfg,
        positions=batch.get("positions"),
        patch_embeds=batch.get("patch_embeds"),
        frames=batch.get("frames"),
        caches=caches, quant=quant, remat=False, logits_mode="none",
        collect_moe_stats=moe_stats)
    x, caches = out[0], out[1]
    idx = batch["last_idx"].astype(jnp.int32)           # (B,)
    xl = jnp.take_along_axis(
        x, idx[:, None, None].astype(jnp.int32), axis=1)  # (B, 1, d)
    logits = M._logits(params, xl, cfg, quant)
    if moe_stats:
        return logits[:, 0], caches, out[3]
    return logits[:, 0], caches


@partial(jax.jit, static_argnames=("cfg", "quant", "moe_stats"))
def serve_step(params, batch: dict, caches, cfg: ModelConfig,
               quant: Optional[QuantConfig] = None,
               moe_stats: bool = False):
    """One decode step: one new token per sequence against the caches.

    ``batch``: tokens (B, 1), positions (B, 1) (or (3, B, 1) M-RoPE).
    Returns ``(logits (B, V), caches)`` -- plus the per-MoE-layer
    capacity telemetry dict when ``moe_stats=True`` (static).
    """
    out = M.forward(
        params, batch["tokens"], cfg,
        positions=batch["positions"],
        caches=caches, quant=quant, remat=False, logits_mode="last",
        collect_moe_stats=moe_stats)
    if moe_stats:
        return out[0], out[1], out[3]
    return out[0], out[1]


def kv_cache_bytes(caches, *, payload_only: bool = False) -> int:
    """Total bytes of the attention KV state in a cache tree.

    Counts ``k``/``v`` buffers plus (unless ``payload_only``) their
    quantization scales; positions/indices/SSM state are bookkeeping
    shared by every format and excluded.  With bipolar ``kv_bits`` caches
    the payload is exactly ``kv_bits/16`` of the bf16 payload (modulo the
    32-element word rounding of the head dim).
    """
    keys = ("k", "v") if payload_only else ("k", "v", "k_scale", "v_scale")

    def leaf_bytes(path, leaf):
        names = [getattr(p, "key", None) for p in path]
        name = next((n for n in reversed(names) if n), "")
        if name not in keys or not hasattr(leaf, "nbytes"):
            return 0
        return int(leaf.nbytes)

    flat = jax.tree_util.tree_flatten_with_path(caches)[0]
    return sum(leaf_bytes(path, leaf) for path, leaf in flat)


def sample(logits: jax.Array, *, temperature: float = 0.0,
           key=None) -> jax.Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, -1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


def _next_pow2(n: int, floor: int = 1) -> int:
    p = floor
    while p < n:
        p *= 2
    return p


def prefill_bucket(s: int, cap: int, floor: int = 8) -> int:
    """Bucket a prompt length to the next power of two (>= ``floor``,
    capped at ``cap`` = the cache ring length): a stream of varied
    prompt lengths compiles O(log cap) prefill programs.  Lengths at or
    beyond the ring stay exact -- padding past the ring would evict
    real in-window tokens through the SWA tail-store path."""
    if s >= cap:
        return s
    return min(_next_pow2(s, floor), cap)


def tier_bits(requested: Optional[int], *, max_bits: int,
              floor: Optional[int] = None, queue_depth: int = 0,
              pressure: int = 4) -> int:
    """Resolve one request's served weight width (bits).

    ``requested`` (None = full width) is capped at ``max_bits``, the
    checkpoint's stored width -- a nested checkpoint can serve fewer
    planes than it stores, never more.  Without a ``floor`` the request
    gets exactly what it asked for (no load adaptation).  With one, the
    policy is load-adaptive: every ``pressure`` waiting requests shed
    one bit off the grant, clamped at the floor -- bulk lanes degrade
    under overload and recover as the queue drains (each *new*
    admission re-reads the depth; granted requests keep their bits).
    A request explicitly asking for less than the floor is honored:
    the floor bounds degradation, not choice.
    """
    bits = min(requested or max_bits, max_bits)
    if floor is None:
        return bits
    lo = min(floor, bits)
    return max(lo, bits - queue_depth // max(pressure, 1))


# ---------------------------------------------------------------------------
# Requests and per-request state
# ---------------------------------------------------------------------------

@dataclasses.dataclass(eq=False)    # identity equality: queue membership
class Request:                      # must never compare prompt arrays
    prompt: np.ndarray              # (s,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0        # 0 = greedy
    seed: Optional[int] = None      # per-request sampling stream; token k
                                    # is drawn from rng((seed, k)), so
                                    # preemption/recompute cannot change
                                    # the sampled sequence.  None: the
                                    # engine assigns a distinct seed at
                                    # submit (identical prompts still
                                    # sample diverse completions)
    precision: Optional[int] = None  # requested weight width (bits) for
                                     # nested-precision serving; capped
                                     # at quant.w_bits, load-adapted by
                                     # tier_bits, frozen at admission.
                                     # None: the engine's full width
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    error: Optional[str] = None     # rejection / quarantine detail
    # -- async streaming API -------------------------------------------------
    on_token: Optional[Callable[[int], None]] = None   # emission-order cb
    timeout: Optional[float] = None  # seconds from submit to deadline
    deadline: Optional[float] = None  # absolute (engine clock); computed
                                      # from ``timeout`` at submit if unset
    # why the request stopped: one of FINISH_REASONS (the class constant
    # below is THE enum -- obs labels and tests assert against it)
    finish_reason: Optional[str] = None
    # backpressure hint: seconds to wait before resubmitting, set when
    # the engine sheds this request off a full queue (max_queue)
    retry_after: Optional[float] = None

    # not a dataclass field (no annotation): the single definition of
    # every value ``finish_reason`` may take
    FINISH_REASONS = frozenset(
        {"length", "timeout", "cancelled", "rejected", "error"})


class StreamHandle:
    """Async view of a submitted request.

    The engine is single-threaded, so "async" means the handle *drives*
    it: :meth:`tokens` steps the engine until the request advances and
    yields each output token in emission order, which lets callers
    interleave many requests (each with its own handle or ``on_token``
    callback) without threads.  :meth:`cancel` aborts the request and
    releases its memory through the refcount path."""

    def __init__(self, engine: "Engine", req: Request):
        self.engine, self.req = engine, req

    @property
    def done(self) -> bool:
        return self.req.done

    @property
    def finish_reason(self) -> Optional[str]:
        return self.req.finish_reason

    @property
    def error(self) -> Optional[str]:
        """Rejection / quarantine detail (``finish_reason`` in
        ``{'rejected', 'error'}``), else None."""
        return self.req.error

    @property
    def retry_after(self) -> Optional[float]:
        """Backpressure hint attached when the engine shed this request
        off a full queue."""
        return self.req.retry_after

    def cancel(self) -> bool:
        return self.engine.cancel(self.req)

    def resubmit(self, max_attempts: int = 5, base_delay: float = 0.05,
                 max_delay: float = 2.0,
                 sleep: Optional[Callable[[float], None]] = None
                 ) -> "StreamHandle":
        """Client-side backoff helper: while the request sits shed
        (``finish_reason='rejected'``), wait max(engine ``retry_after``
        hint, capped exponential backoff) and submit it again.  Returns
        self once the request is back in the engine (drive it with
        :meth:`tokens`/:meth:`result` as usual) or after
        ``max_attempts`` consecutive sheds.  ``sleep`` is injectable so
        tests back off on a fake clock."""
        sleep = time.sleep if sleep is None else sleep
        for attempt in range(max_attempts):
            if not (self.req.done and self.req.finish_reason == "rejected"):
                return self
            sleep(min(max_delay, max(self.req.retry_after or 0.0,
                                     base_delay * (2 ** attempt))))
            self._reset_for_resubmit()
            self.engine.submit(self.req)
        return self

    def _reset_for_resubmit(self) -> None:
        """Clear the terminal fields a shed left behind so the request
        can go through ``submit`` again (deadline is recomputed from
        ``timeout``; emitted tokens are untouched -- a shed request
        never emitted any)."""
        r = self.req
        r.done = False
        r.error = None
        r.finish_reason = None
        r.retry_after = None
        r.deadline = None
        r._engine = None       # re-arm the double-submit guard

    def tokens(self, max_steps: int = 10_000):
        """Yield output tokens as they are emitted, stepping the engine
        as needed; returns when the request finishes (or the engine
        runs out of work / ``max_steps``)."""
        sent = steps = 0
        while True:
            while sent < len(self.req.out):
                yield self.req.out[sent]
                sent += 1
            if self.req.done or steps >= max_steps:
                return
            if not self.engine.step():
                return
            steps += 1

    def result(self, max_steps: int = 10_000) -> Request:
        """Block (drive the engine) until the request finishes."""
        for _ in self.tokens(max_steps):
            pass
        return self.req


def _tree_write_slot(batched, single, slot: int):
    """Insert a B=1 cache/state tree into batch position ``slot``.

    The batch dim is 0 for prelude caches but 1 for scanned-stack caches
    (leaves carry a leading n_units dim)."""
    def wr_at(bdim):
        def wr(b, s):
            start = (0,) * bdim + (slot,) + (0,) * (b.ndim - bdim - 1)
            return jax.lax.dynamic_update_slice(b, s.astype(b.dtype), start)
        return wr

    out = dict(batched)
    for key in batched:
        bdim = 0 if key == "prelude" else 1
        out[key] = jax.tree.map(wr_at(bdim), batched[key], single[key])
    return out


class Engine:
    """Continuous batching over the jitted steps (contiguous or paged).

    Contiguous: each of the ``n_slots`` decode lanes owns one request at
    a time; prefill runs per-request at B=1 (bucketed, see
    :func:`prefill_bucket`) and the resulting KV state is scattered into
    the lane's slice of the batched cache; decode advances all active
    lanes in lock-step.

    Paged (``paged=True``, requires ``kv_bits``): requests share a
    :class:`~repro.serving.paged_cache.PagedKVPool` of ``n_blocks``
    blocks x ``block_size`` tokens, run under the
    :class:`~repro.serving.scheduler.Scheduler`, and the decode batch is
    whatever is running, padded to the next power-of-two bucket
    (<= ``max_batch``) to bound recompiles.  With ``prefix_cache``
    (default) admission reuses pool blocks whose prompt-chain hash
    matches the head of the request and prefills only the suffix; block
    aliasing is refcounted with copy-on-write, so sharing changes
    memory management, not math: greedy decode stays token-identical to
    the contiguous engine (and to ``prefix_cache=False``) at equal
    ``kv_bits``.
    """

    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 4,
                 max_len: int = 256, quant: Optional[QuantConfig] = None,
                 paged: bool = False, block_size: int = 16,
                 n_blocks: Optional[int] = None,
                 max_batch: Optional[int] = None,
                 prefix_cache: bool = True,
                 chunk_tokens: Optional[int] = None,
                 clock: Optional[Callable[[], float]] = None,
                 metrics=None, faults=None,
                 max_queue: Optional[int] = None,
                 validate_every: Optional[int] = None):
        self.params, self.cfg, self.quant = params, cfg, quant
        self.n_slots, self.max_len = n_slots, max_len
        self.paged = paged
        self.steps = 0
        # per-width QuantConfig cache (nested-precision serving): the
        # jitted steps treat quant as static, so each served width is
        # one compiled program, reused across steps
        self._quant_cache: dict = {}
        self._seed_counter = 0      # default per-request sampling seeds
        # fault facade (repro.serving.faults): one seeded schedule shared
        # by the pool, scheduler, and engine; NULL_FAULTS (default) is
        # the constant-False twin -- hot path and tokens untouched
        self.faults = faults if faults is not None else NULL_FAULTS
        # backpressure: bound on the waiting queue; submits past it are
        # shed with finish_reason='rejected' + a retry_after hint
        self.max_queue = max_queue
        # pool integrity watchdog cadence (steps between validate runs)
        assert validate_every is None or validate_every >= 1, validate_every
        self.validate_every = validate_every
        # deadline clock, injectable for deterministic timeout tests;
        # ALL observability timestamps route through it too (satellite
        # of ISSUE 7), so a ServingObs built with its own test clock
        # supplies the engine clock when none is injected here.  The
        # fault facade may wrap it with injected forward jumps
        if clock is None and isinstance(metrics, ServingObs):
            clock = metrics.clock
        self._clock = self.faults.wrap_clock(clock)
        # ``metrics``: None/False = off (NULL_OBS: no-op hooks, no clock
        # reads, token-identical hot path); True = fresh ServingObs;
        # or pass a MetricsRegistry / ServingObs to share a namespace
        if metrics is None or metrics is False:
            self.obs = NULL_OBS
        elif isinstance(metrics, ServingObs):
            self.obs = metrics
            self.obs.clock = self._clock
        elif isinstance(metrics, MetricsRegistry):
            self.obs = ServingObs(registry=metrics, clock=self._clock)
        elif metrics is True:
            self.obs = ServingObs(clock=self._clock)
        else:
            raise TypeError(
                f"metrics: expected None/bool/MetricsRegistry/"
                f"ServingObs, got {type(metrics).__name__}")
        self._deadlines = False     # fast-path: no deadline submitted yet
        # MoE capacity telemetry: only worth a distinct jit specialization
        # (and host transfers) when observability is on AND the stack has
        # MoE layers; with NULL_OBS the steps compile without the stats
        # outputs and the hot path is untouched
        self._moe_telemetry = bool(
            self.obs.enabled
            and any(cfg.ffn_kind(i) == "moe" for i in range(cfg.n_layers)))
        self.chunk_tokens_processed = 0
        if chunk_tokens is not None and not paged:
            raise ValueError("chunk_tokens requires paged=True (chunked "
                             "prefill writes through the block pool)")
        # whole-prompt frontends (vlm patch embeds, audio encoder frames)
        # fill their side inputs in one prefill pass; those families keep
        # whole-prompt admission
        if chunk_tokens is not None and cfg.family in ("vlm", "audio"):
            chunk_tokens = None
        self.chunk_tokens = chunk_tokens
        if paged:
            from repro.serving.paged_cache import (PagedKVPool,
                                                   needs_state_slots)
            from repro.serving.scheduler import Scheduler
            assert max_len % block_size == 0, (max_len, block_size)
            # window < max_len is fine: the scheduler reclaims blocks
            # whose tokens are all out of the attention window, so block
            # tables are rolling windows and steady-state decode memory
            # is O(window + state) per request (PR 5 tentpole; the pool
            # raises a descriptive ValueError for block_size > window)
            if n_blocks is None:
                # same token capacity as the n_slots contiguous engine,
                # plus the reserved null block
                n_blocks = n_slots * (max_len // block_size) + 1
            self.max_batch = max_batch or 2 * n_slots
            stateful = needs_state_slots(cfg)
            enc = None
            if cfg.family == "audio":
                from repro.launch.specs import enc_len
                enc = enc_len(cfg, max_len)
            # the engine's VLM frontend is a stub (zero patch embeds), but
            # real per-request patch embeds would make equal token
            # prefixes carry different KV -- keep the cache off for vlm.
            # Stateful archs (ssm/hybrid/audio) keep it off too: SSM
            # state is an order-dependent running summary (not
            # block-addressable content) and cross caches are
            # per-request, so there is no prefix to share
            self.pool = PagedKVPool(
                cfg, n_blocks, block_size, quant=quant,
                prefix_cache=(prefix_cache and cfg.family != "vlm"
                              and not stateful),
                n_state_slots=self.max_batch if stateful else 0,
                # NULL_OBS.registry is None -> the pool keeps a private
                # registry, so report() snapshots work with metrics off
                enc_len=enc, metrics=self.obs.registry,
                faults=self.faults)
            # nested-precision serving needs packed weights to slice;
            # without w_bits every lane runs the configured quant and
            # the scheduler stays unsalted (pre-nested behavior)
            tiered = quant is not None and quant.w_bits is not None
            self.scheduler = Scheduler(self.pool, max_len=max_len,
                                       max_batch=self.max_batch,
                                       chunk_tokens=self.chunk_tokens,
                                       obs=self.obs,
                                       precision_policy=(
                                           self._tier_policy if tiered
                                           else None))
            self.n_batch_blocks = max_len // block_size   # table width
        else:
            self.caches = M.init_caches(cfg, n_slots, max_len, quant=quant)
            self.slot_req: list = [None] * n_slots   # SequenceState per lane
            self.queue: list[Request] = []
        # robustness counters: in the pool's registry (paged) or the
        # obs registry / a private one (contiguous), so render() scrapes
        # faults, quarantines, and sheds next to the serving counters
        reg = self.pool.metrics if paged \
            else (self.obs.registry or MetricsRegistry())
        self._c_fault_requests = reg.counter(
            "repro_engine_fault_requests",
            "requests quarantined by step-level containment, by fault "
            "kind", labelnames=("kind",))
        self._fault_children: dict = {}
        self._c_fault_steps = reg.counter(
            "repro_engine_fault_steps",
            "steps aborted by a transient pool fault the scheduler "
            "could not absorb (state intact, step retried)")
        self._c_watchdog = reg.counter(
            "repro_engine_fault_watchdog_violations",
            "pool invariant violations caught by the validate_every "
            "watchdog (corrupt chains quarantined, free lists rebuilt)")
        self._c_shed = reg.counter(
            "repro_sched_shed_requests",
            "submits shed by the max_queue backpressure bound")
        self._g_retry_after = reg.gauge(
            "repro_sched_shed_retry_after",
            "retry_after hint attached to the most recent shed (s)")
        self._c_precision = reg.counter(
            "repro_engine_precision",
            "output tokens emitted per effective serving precision "
            "(weight bits; 'full' = unquantized weights)",
            labelnames=("bits",))
        self._precision_children: dict = {}
        self.faults.bind(reg)

    # -- request lifecycle -------------------------------------------------
    def submit(self, req: Request) -> StreamHandle:
        # double-submit is idempotent: a request this engine already
        # holds (queued or running) just gets a fresh handle -- queueing
        # it twice would double-release through free()'s strict path
        if getattr(req, "_engine", None) is self and not req.done:
            return StreamHandle(self, req)
        req._engine = self
        if getattr(req, "seed", None) is None:
            req.seed = self._seed_counter     # stable across preemption
            self._seed_counter += 1
        if getattr(req, "timeout", None) is not None \
                and getattr(req, "deadline", None) is None:
            req.deadline = self._clock() + req.timeout
        if getattr(req, "deadline", None) is not None:
            self._deadlines = True
        # trace starts BEFORE scheduler.submit so an immediate
        # rejection still closes a balanced span tree
        self.obs.on_submit(req)
        depth = len(self.scheduler.waiting) if self.paged \
            else len(self.queue)
        if self.max_queue is not None and depth >= self.max_queue:
            self._shed(req, depth)
            return StreamHandle(self, req)
        if self.paged:
            self.scheduler.submit(req)
        else:
            self.queue.append(req)
        return StreamHandle(self, req)

    def _shed(self, req: Request, depth: int) -> None:
        """Backpressure: the waiting queue is at ``max_queue`` -- finish
        the request immediately with ``finish_reason='rejected'`` and a
        ``retry_after`` hint that grows with queue depth and pool
        occupancy (deterministic, so shed/backoff behavior replays)."""
        if self.paged and self.pool.needs_blocks:
            occ = self.pool.used_blocks / max(self.pool.n_usable, 1)
        elif self.paged:
            occ = (self.pool.slots.used_slots
                   / max(self.pool.slots.n_slots, 1))
        else:
            occ = (sum(r is not None for r in self.slot_req)
                   / max(self.n_slots, 1))
        req.retry_after = 0.05 * (depth + 1) * (1.0 + occ)
        req.error = (f"rejected: queue full ({depth} waiting >= "
                     f"max_queue={self.max_queue})")
        req.done = True
        req.finish_reason = "rejected"
        self._c_shed.inc()
        self._g_retry_after.set(req.retry_after)
        self.obs.on_finish(req, "rejected")

    # -- nested-precision lanes --------------------------------------------
    def _tier_policy(self, req: Request) -> int:
        """Scheduler admission hook: resolve the request's served width
        through :func:`tier_bits` against the queue depth *now*, and
        freeze it on the request -- a preempted request re-admits at
        the SAME bits whatever the queue looks like by then (precision
        never changes mid-request, the tier property suite's
        invariant)."""
        frozen = getattr(req, "_tier_bits", None)
        if frozen is not None:
            return frozen
        q = self.quant
        bits = tier_bits(getattr(req, "precision", None),
                         max_bits=q.w_bits,
                         floor=q.precision_floor,
                         queue_depth=len(self.scheduler.waiting))
        req._tier_bits = bits
        return bits

    def _quant_for(self, bits: Optional[int]) -> Optional[QuantConfig]:
        """QuantConfig for one precision lane, cached per width.

        Full-width lanes reuse ``self.quant`` verbatim (same static jit
        key as pre-nested serving).  Narrower lanes get a cached
        ``nested_bits=bits`` copy; the floor is dropped -- it already
        did its job in :meth:`_tier_policy`, and a request granted
        bits below the configured floor (explicitly requested) must
        still validate."""
        q = self.quant
        if bits is None or q is None or bits == q.serve_bits:
            return q
        cached = self._quant_cache.get(bits)
        if cached is None:
            cached = dataclasses.replace(q, nested_bits=bits,
                                         precision_floor=None)
            self._quant_cache[bits] = cached
        return cached

    def cancel(self, req: Request) -> bool:
        """Abort ``req``: no further tokens are emitted and no further
        ``on_token`` callbacks fire; paged requests release their
        blocks and state slot through the scheduler's refcount path
        (mid-prefill included).  Returns False if the request already
        finished or is unknown to this engine."""
        if req.done:
            return False
        if self.paged:
            return self.scheduler.cancel(req)
        if req in self.queue:
            self.queue.remove(req)
        else:
            for i, seq in enumerate(self.slot_req):
                if seq is not None and seq.req is req:
                    self.slot_req[i] = None
                    break
            else:
                return False
        req.done, req.finish_reason = True, "cancelled"
        self.obs.on_finish(req, "cancelled")
        return True

    def _expire(self) -> None:
        """Finish every request whose deadline has passed: a clean
        completion with ``finish_reason='timeout'`` whose memory
        returns through the same path cancellation uses."""
        if not self._deadlines:
            return
        now = self._clock()

        def expired(req):
            dl = getattr(req, "deadline", None)
            return dl is not None and now >= dl and not req.done

        if self.paged:
            sch = self.scheduler
            stale = [r for r in list(sch.waiting) if expired(r)]
            stale += [s.req for s in list(sch.running) if expired(s.req)]
            for req in stale:
                sch.cancel(req, reason="timeout")
            return
        for req in [r for r in self.queue if expired(r)]:
            self.queue.remove(req)
            req.done, req.finish_reason = True, "timeout"
            self.obs.on_finish(req, "timeout")
        for i, seq in enumerate(self.slot_req):
            if seq is not None and expired(seq.req):
                self.slot_req[i] = None
                seq.req.done, seq.req.finish_reason = True, "timeout"
                self.obs.on_finish(seq.req, "timeout", seq=seq)

    def _emit(self, seq, tok: int) -> None:
        """Append an output token and fire ``on_token``: emission order
        == callback order, and a finished request (cancelled/expired by
        another lane's callback mid-step) never reaches here again.

        Callback *exceptions* are isolated per-request: they surface as
        a :class:`RequestFault` the step loop turns into a quarantine of
        this request alone (a callback that cancels/expires requests is
        a supported pattern and raises nothing)."""
        seq.req.out.append(tok)
        self.obs.on_token(seq.req, tok)
        bits = getattr(seq, "precision", None)
        if bits is None:
            q = self.quant
            bits = q.serve_bits if q is not None and q.w_bits else "full"
        child = self._precision_children.get(bits)
        if child is None:
            child = self._c_precision.labels(bits=str(bits))
            self._precision_children[bits] = child
        child.inc()
        if self.faults.callback_error(seq.req):
            raise RequestFault(
                f"injected on_token failure at token "
                f"{len(seq.req.out) - 1}", kind="callback")
        cb = getattr(seq.req, "on_token", None)
        if cb is not None:
            try:
                cb(tok)
            except RequestFault:
                raise
            except Exception as e:
                raise RequestFault(f"on_token callback raised: {e!r}",
                                   kind="callback") from e

    def _sample_checked(self, row: np.ndarray, seq) -> int:
        """Guarded sampling: a non-finite logits row (numerical blowup,
        or the injector's poisoned row) never reaches the sampler --
        it raises a :class:`RequestFault` that quarantines exactly this
        request.  Always on: the finiteness scan is O(V) on a row the
        step already materialized on host."""
        if self.faults.nan_logits(seq.req):
            row = np.full_like(row, np.nan)
        if not np.isfinite(row).all():
            raise RequestFault(
                f"non-finite logits row at output index "
                f"{len(seq.req.out)}", kind="nan_logits")
        return self._sample_token(row, seq)

    def _quarantine(self, seq, exc: Exception) -> None:
        """Step-level containment: retire exactly the offending
        sequence with ``finish_reason='error'``, surfacing the cause on
        ``req.error``; paged blocks and the state slot return through
        the scheduler's refcount path, a contiguous lane is simply
        vacated.  The rest of the batch never notices."""
        kind = getattr(exc, "kind", "exception")
        req = seq.req
        if req.error is None:
            req.error = f"quarantined ({kind}): {exc}"
        child = self._fault_children.get(kind)
        if child is None:
            child = self._c_fault_requests.labels(kind=kind)
            self._fault_children[kind] = child
        child.inc()
        if self.paged and seq in self.scheduler.running:
            self.scheduler.finish(seq, reason="error")
            return
        if not self.paged:
            for i, s in enumerate(self.slot_req):
                if s is seq:
                    self.slot_req[i] = None
                    break
        req.done = True
        req.finish_reason = "error"
        self.obs.on_finish(req, "error", seq=seq)

    # -- pool integrity watchdog -------------------------------------------
    def _watchdog(self) -> None:
        """``validate_every`` cadence: run the pool's full invariant
        checker off the hot path; on violation, recover instead of
        raising -- quarantine the chains whose tables are corrupt and
        rebuild the pool's bookkeeping from the survivors."""
        try:
            self.pool.validate()
        except AssertionError:
            self._c_watchdog.inc()
            self._rebuild_pool()

    def _rebuild_pool(self) -> None:
        """Recover a pool whose invariants broke: block tables are the
        ground truth.  Sequences whose table is self-evidently corrupt
        (out-of-range, null, or duplicated block ids; impossible slot)
        are quarantined *bypassing* release -- their references cannot
        be trusted against the refcount map.  Every derived structure
        is then rebuilt from the surviving tables: refcounts from a
        table-reference count, the free list as the unreferenced ids,
        the state-slot pool from the surviving slots.  The prefix cache
        is dropped wholesale (hits become misses; math unchanged) and
        chain memos reset.  Ends with a full ``validate()`` -- recovery
        must restore the invariants it is guarding, not defer them."""
        from collections import Counter as _Counter
        from repro.serving.paged_cache import ChainMemo
        pool, sch = self.pool, self.scheduler

        def table_corrupt(s) -> bool:
            seen = set()
            for b in s.blocks:
                b = int(b)
                if b < 1 or b > pool.n_usable or b in seen:
                    return True
                seen.add(b)
            return pool.slots is not None and s.slot >= 0 \
                and not 1 <= s.slot <= pool.slots.n_slots
        bad = [s for s in sch.running if table_corrupt(s)]
        for seq in bad:
            sch.running.remove(seq)
            seq.blocks = []
            seq.slot = -1
            self._quarantine(
                seq, RequestFault("pool integrity violation: block "
                                  "table corrupt", kind="watchdog"))
        counts = _Counter(int(b) for s in sch.running for b in s.blocks)
        pool._ref = dict(counts)
        pool._lru.clear()            # prefix cache dropped wholesale
        pool._meta.clear()
        pool._full_index.clear()
        pool._partial_index.clear()
        pool._free = [b for b in range(pool.n_blocks - 1, 0, -1)
                      if b not in counts]
        if pool.slots is not None:
            used = {s.slot for s in sch.running if s.slot >= 1}
            pool.slots._used = used
            pool.slots._free = [i for i in range(pool.slots.n_slots, 0, -1)
                                if i not in used]
        for seq in sch.running:
            seq.chain_memo = ChainMemo()
        pool.version += 1
        sch._blocked_head = None
        pool.validate()

    def _admit(self):
        for slot in range(self.n_slots):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.pop(0)
                self._prefill_into(req, slot)

    @property
    def _bucketable(self) -> bool:
        """Prompt lengths may pad to pow2 buckets only when every mixer
        masks by position: SSM/hybrid recurrences consume pad tokens
        regardless, so those archs prefill at exact length (one rule
        for the contiguous AND paged prefill paths -- diverging them
        would break paged-vs-contiguous token identity)."""
        return all(self.cfg.layer_kind(i) == "attn"
                   for i in range(self.cfg.n_layers))

    # -- shared bucketed B=1 prefill ---------------------------------------
    def _bucketed_prefill(self, prompt: np.ndarray):
        """Prefill one prompt at B=1 with length bucketing.

        Returns ``(logits (1, V) at the last real token, filled B=1
        cache)``.  Pad tokens carry position -1: they are masked out of
        every attention read and land in the cache as invalid slots that
        decode immediately overwrites (the ring index is rewound to the
        real length below).  SSM/hybrid archs prefill at exact length --
        the recurrence consumes every input regardless of position, so
        pads would corrupt the cached state (one jit per length; the
        bucketing win applies to the attention engines).
        """
        s = len(prompt)
        ring = min(self.max_len, self.cfg.window) if self.cfg.window \
            else self.max_len
        p = prefill_bucket(s, ring) if self._bucketable else s
        one = M.init_caches(self.cfg, 1, self.max_len, quant=self.quant)
        toks = np.zeros(p, np.int32)
        toks[:s] = np.asarray(prompt, np.int32)
        pos = np.full(p, -1, np.int32)
        pos[:s] = np.arange(s)
        batch = {"tokens": jnp.asarray(toks)[None],
                 "positions": jnp.asarray(pos)[None],
                 "last_idx": jnp.asarray([s - 1], jnp.int32)}
        if self.cfg.family == "vlm":
            batch["positions"] = jnp.broadcast_to(
                jnp.asarray(pos)[None, None], (3, 1, p))
            batch["patch_embeds"] = jnp.zeros(
                (1, min(self.cfg.n_patches, p), self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        if self.cfg.family == "audio":
            from repro.launch.specs import enc_len
            batch["frames"] = jnp.zeros(
                (1, enc_len(self.cfg, p), self.cfg.frontend_dim),
                jnp.dtype(self.cfg.dtype))
        logits, one = prefill_step_bucketed(self.params, batch, one,
                                            self.cfg, self.quant)
        return logits, self._rewind_ring_index(one, s, p)

    @staticmethod
    def _rewind_ring_index(caches, s: int, p: int):
        """Point each KV ring's write index at the first *pad* slot.

        The prefill write advanced ``index`` by the padded length ``p``;
        left alone, decode would skip the ``p - s`` pad slots (wasting
        ring capacity) or -- when ``p`` wraps the ring -- overwrite live
        prompt KV.  The first pad sits at ``s`` (normal write) or
        ``s - (p - ring)`` (SWA tail store keeps the last ``ring``
        entries), i.e. ``(s - max(0, p - ring)) % ring``.
        """
        def fix(c):
            if not (isinstance(c, dict) and "index" in c and "pos" in c):
                return c
            ring = c["pos"].shape[-1]
            idx = (s - max(0, p - ring)) % ring
            return dict(c, index=jnp.full_like(c["index"], idx))

        out = dict(caches)
        for key in ("prelude", "blocks"):
            if key in out:
                out[key] = [fix(c) for c in out[key]]
        return out

    @staticmethod
    def _sample_token(row_logits: np.ndarray, seq) -> int:
        """Sample the next token for ``seq`` (a SequenceState).

        Greedy below temperature 0+; otherwise inverse-CDF over the
        softmax using the request's stateless per-token RNG stream
        (``seq.sample_rng(k)`` for output index k) -- the draw depends
        only on (request seed, output index), never on batch composition
        or preemption history."""
        t = seq.temperature
        if t <= 0.0:
            return int(np.argmax(row_logits))
        z = row_logits.astype(np.float64) / t
        z -= z.max()
        probs = np.exp(z)
        probs /= probs.sum()
        u = seq.sample_rng(len(seq.req.out)).random()
        return int(min(np.searchsorted(np.cumsum(probs), u),
                       len(probs) - 1))

    # -- contiguous path ----------------------------------------------------
    def _prefill_into(self, req: Request, slot: int):
        from repro.serving.scheduler import SequenceState
        obs = self.obs
        seq = SequenceState(req=req, length=len(req.prompt))
        obs.on_admit(seq, prefilling=True)
        t0 = obs.t() if obs.enabled else 0.0
        logits, one = self._bucketed_prefill(req.prompt)
        self.caches = _tree_write_slot(self.caches, one, slot)
        if obs.enabled:
            obs.on_chunk(seq, len(req.prompt), t0, obs.t())
        obs.on_decode_begin(seq)
        try:
            seq.last_tok = self._sample_checked(
                np.asarray(logits[0], np.float32), seq)
            self._emit(seq, seq.last_tok)
        except RequestFault as e:
            self._quarantine(seq, e)   # lane stays free for the next admit
            return
        self.slot_req[slot] = seq

    def _contiguous_step(self) -> bool:
        obs = self.obs
        t0 = obs.t() if obs.enabled else 0.0
        self._expire()
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return False
        if obs.enabled:
            obs.on_dispatch(live=len(active), lanes=self.n_slots,
                            tok_live=len(active), tok_lanes=self.n_slots)
        toks = np.zeros(self.n_slots, np.int32)
        pos = np.zeros(self.n_slots, np.int32)
        for slot, seq in enumerate(self.slot_req):
            if seq is not None:
                toks[slot], pos[slot] = seq.last_tok, seq.length
        toks = jnp.asarray(toks)[:, None]
        pos = jnp.asarray(pos)[:, None]
        if self.cfg.family == "vlm":
            pos = jnp.broadcast_to(pos[None], (3, self.n_slots, 1))
        batch = {"tokens": toks, "positions": pos}
        logits, self.caches = serve_step(self.params, batch, self.caches,
                                         self.cfg, self.quant)
        logits = np.asarray(logits, np.float32)
        self.steps += 1
        for slot in active:
            seq = self.slot_req[slot]
            if seq is None or seq.req.done:   # cancelled by a callback
                continue
            try:
                seq.last_tok = self._sample_checked(logits[slot], seq)
                self._emit(seq, seq.last_tok)
            except RequestFault as e:
                self._quarantine(seq, e)
                continue
            seq.length += 1
            if len(seq.req.out) >= seq.req.max_new_tokens \
                    or seq.length >= self.max_len - 1:
                seq.req.done = True
                seq.req.finish_reason = "length"
                self.slot_req[slot] = None
                self.obs.on_finish(seq.req, "length", seq=seq)
        if obs.enabled:
            obs.on_step(
                t0, waiting=len(self.queue),
                running=sum(r is not None for r in self.slot_req))
        return True

    # -- paged path ----------------------------------------------------------
    def _paged_prefill(self, seq, tokens: np.ndarray):
        """Scheduler admission callback (whole-prompt mode): prefill the
        whole uncached suffix in one pass, then sample the first token
        (or restore the pending input on a warm resume)."""
        start = seq.cached_len
        logits = self._suffix_forward(
            seq, np.asarray(tokens[start:], np.int32), start)
        seq.length = len(tokens)
        if seq.req.out:
            # re-admission after preemption: the pending input token is
            # already known; the recomputed logits would reproduce it
            seq.last_tok = seq.req.out[-1]
        else:
            seq.last_tok = self._sample_checked(
                np.asarray(logits[0], np.float32), seq)
            self._emit(seq, seq.last_tok)

    def _suffix_forward(self, seq, suffix: np.ndarray, start: int):
        """B=1 block-table *suffix* forward: chain positions ``start..``
        run through the model and land in ``seq``'s blocks.

        The first ``start`` tokens of the chain are already resident in
        the pool (prefix-cache hit, or -- chunked prefill -- the chunks
        a previous step landed); only ``suffix`` runs through the
        model, at B=1 with its length bucketed to the next power of two
        (pad tokens carry position -1: their pool writes are dropped
        and their attention rows masked, so a varied suffix stream
        compiles O(log max_len) programs).  The suffix K/V lands
        directly in the request's blocks via the paged scatter write,
        and its queries attend through the shared prefix blocks and the
        fresh suffix in the same kernel pass -- no contiguous B=1 cache
        or copy step exists anymore.  Stateful archs additionally
        continue the slot-resident conv/SSD state (and cross cache), so
        a chunk picks up exactly where the last one stopped.  Returns
        the ``(1, V)`` logits at the last real suffix token.
        """
        s = len(suffix)
        assert s >= 1, "suffix forward needs >= 1 token to compute"
        p = prefill_bucket(s, self.max_len) if self._bucketable else s
        toks = np.zeros(p, np.int32)
        toks[:s] = suffix
        pos = np.full(p, -1, np.int32)
        pos[:s] = np.arange(start, start + s)
        # bucket the table width like decode does: the kernel grid walks
        # one iteration per table entry
        nbw = min(_next_pow2(max(len(seq.blocks), 1)), self.n_batch_blocks)
        tables = np.zeros((1, nbw), np.int32)   # pad entries: null block
        tables[0, :len(seq.blocks)] = seq.blocks
        jpos = jnp.asarray(pos)[None]
        batch = {"tokens": jnp.asarray(toks)[None],
                 "positions": jpos,
                 "last_idx": jnp.asarray([s - 1], jnp.int32)}
        if self.cfg.family == "vlm":
            batch["positions"] = jnp.broadcast_to(jpos[None], (3, 1, p))
            batch["patch_embeds"] = jnp.zeros(
                (1, min(self.cfg.n_patches, p), self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        if self.cfg.family == "audio":
            from repro.launch.specs import enc_len
            batch["frames"] = jnp.zeros(
                (1, enc_len(self.cfg, p), self.cfg.frontend_dim),
                jnp.dtype(self.cfg.dtype))
        slots = (np.asarray([seq.slot], np.int32)
                 if self.pool.slots is not None else None)
        caches = self.pool.step_caches(
            tables, np.asarray([start], np.int32), slots=slots)
        quant = self._quant_for(getattr(seq, "precision", None))
        if self._moe_telemetry:
            logits, caches, mst = prefill_step_bucketed(
                self.params, batch, caches, self.cfg, quant,
                moe_stats=True)
            self.obs.on_moe(mst)
        else:
            logits, caches = prefill_step_bucketed(
                self.params, batch, caches, self.cfg, quant)
        self.pool.absorb(caches)
        return logits

    def _decode_bucket(self, n: int) -> int:
        return min(_next_pow2(n), self.max_batch)

    def _paged_step(self) -> bool:
        sch = self.scheduler
        obs = self.obs
        t0 = obs.t() if obs.enabled else 0.0
        self._expire()
        if self.validate_every is not None and self.steps \
                and self.steps % self.validate_every == 0:
            self._watchdog()
        try:
            if self.chunk_tokens is None:
                # whole-prompt mode: admission prefills, the step decodes
                sch.admit(self._paged_prefill)
                if not sch.running:
                    # fault-free, an empty step means an empty engine;
                    # with injection on, an admission race/rollback can
                    # leave work waiting -- report it so run() retries
                    return self.faults.enabled and sch.has_work
                sch.ensure_append_capacity()  # reclaims out-of-window too
                plan = [(s, 1) for s in sch.running]
            else:
                sch.admit_chunked()
                plan = sch.ensure_step_capacity(sch.plan_step())
                if not plan:
                    return self.faults.enabled and sch.has_work
        except RuntimeError:
            # a transient pool fault the scheduler could not absorb by
            # preempting (e.g. injected exhaustion with one request
            # left).  Alloc is atomic and the rollback paths ran, so
            # state is intact: consume the step and retry on the next
            # one.  Grown blocks stay owned by their seqs (reused next
            # step, no leak)
            self._c_fault_steps.inc()
            self.steps += 1
            return sch.has_work
        chunk_used = 0
        if obs.enabled and self.chunk_tokens is not None:
            chunk_used = sum(n for s, n in plan if s.prefilling)
        tf0 = obs.t() if obs.enabled else 0.0
        rows = self._forward_plan(plan)
        tf1 = obs.t() if obs.enabled else 0.0
        self._advance(plan, rows, tf0, tf1)
        if obs.enabled:
            self.pool.sync_gauges()
            obs.on_step(
                t0, running=len(sch.running), waiting=len(sch.waiting),
                chunk_used=chunk_used, chunk_budget=self.chunk_tokens,
                occupancy=(self.pool.used_blocks
                           / max(self.pool.n_usable, 1)
                           if self.pool.needs_blocks else None))
        return True

    def _forward_plan(self, plan) -> list:
        """Run the planned step's forward pass(es); returns per-entry
        logits rows aligned with ``plan``.

        Attention-only configs fuse everything into ONE dispatch
        (:meth:`_fused_forward`) whenever a chunk is in flight; pure
        decode steps keep the exact ``(B, 1)`` ``serve_step`` program.
        Stateful archs (SSM/hybrid) cannot pad the recurrence, so their
        mixed steps split: one bucketed decode dispatch plus one
        exact-length B=1 dispatch per chunk lane, riding the cached
        conv/state continuation -- same scheduler step, same starvation
        bound, separate programs."""
        if any(n > 1 for _, n in plan) and self._bucketable:
            return self._fused_forward(plan)
        rows: list = [None] * len(plan)
        decodes = [(i, s) for i, (s, n) in enumerate(plan)
                   if not s.prefilling]
        for i, (seq, n) in enumerate(plan):
            if not seq.prefilling:
                continue
            toks = np.asarray(seq.pending[seq.length:seq.length + n],
                              np.int32)
            logits = self._suffix_forward(seq, toks, seq.length)
            rows[i] = np.asarray(logits[0], np.float32)
        if decodes:
            logits = self._decode_forward([s for _, s in decodes])
            for j, (i, _) in enumerate(decodes):
                rows[i] = logits[j]
        return rows

    @staticmethod
    def _precision_groups(seqs, key):
        """Distinct served widths among ``seqs`` (via ``key``), widest
        first -- a stable grouping order so mixed-precision steps
        dispatch deterministically."""
        return sorted({key(s) for s in seqs},
                      key=lambda b: (b is None, -(b or 0)))

    def _decode_forward(self, running):
        """Decode forward over ``running``, grouped per served
        precision: quant is jit-static, so each width is its own
        compiled program and a mixed batch dispatches once per distinct
        width over that width's lanes (per-lane plane masks).  A
        homogeneous batch -- the common case, and every pre-nested
        config -- is exactly one dispatch, unchanged.  Returns logits
        rows indexable by position in ``running``."""
        groups = self._precision_groups(running, lambda s: s.precision)
        if len(groups) <= 1:
            return self._decode_dispatch(running)
        rows: list = [None] * len(running)
        for bits in groups:
            idx = [i for i, s in enumerate(running) if s.precision == bits]
            logits = self._decode_dispatch([running[i] for i in idx])
            for j, i in enumerate(idx):
                rows[i] = logits[j]
        return rows

    def _decode_dispatch(self, running) -> np.ndarray:
        """One bucketed ``(B, 1)`` decode dispatch over ``running``
        (all lanes at one served precision); returns the (bucketed)
        f32 logits rows."""
        bb = self._decode_bucket(len(running))
        # bucket the table width too: the paged kernel's grid walks one
        # iteration per table entry, so a full-width (max_len/block_size)
        # table would make every decode step pay for the longest possible
        # sequence -- exactly the over-allocation paging removes.  With
        # sliding-window reclaim the tables are rolling windows, so the
        # width (and the kernel grid, and the HBM the step moves) stays
        # O(window/block_size) however long the generation runs
        nb = min(_next_pow2(max(len(s.blocks) for s in running) or 1),
                 self.n_batch_blocks)
        if self.obs.enabled:
            self.obs.on_dispatch(live=len(running), lanes=bb,
                                 tok_live=len(running), tok_lanes=bb)
        toks = np.zeros(bb, np.int32)
        pos = np.full(bb, -1, np.int32)       # pad lanes: masked everywhere
        lens = np.zeros(bb, np.int32)
        tables = np.zeros((bb, nb), np.int32)  # 0 = the null block
        offsets = np.zeros(bb, np.int32)       # reclaimed logical blocks
        slot_ids = np.full(bb, -1, np.int32)   # pad lanes: no slot
        for i, seq in enumerate(running):
            toks[i], pos[i], lens[i] = seq.last_tok, seq.length, seq.length
            tables[i, :len(seq.blocks)] = seq.blocks
            offsets[i] = seq.freed_prefix
            slot_ids[i] = seq.slot
        jpos = jnp.asarray(pos)[:, None]
        if self.cfg.family == "vlm":
            jpos = jnp.broadcast_to(jpos[None], (3, bb, 1))
        batch = {"tokens": jnp.asarray(toks)[:, None], "positions": jpos}
        caches = self.pool.step_caches(
            tables, lens, block_offsets=offsets,
            slots=slot_ids if self.pool.slots is not None else None)
        quant = self._quant_for(running[0].precision)
        if self._moe_telemetry:
            logits, caches, mst = serve_step(self.params, batch, caches,
                                             self.cfg, quant,
                                             moe_stats=True)
            self.obs.on_moe(mst)
        else:
            logits, caches = serve_step(self.params, batch, caches,
                                        self.cfg, quant)
        self.pool.absorb(caches)
        return np.asarray(logits, np.float32)

    def _fused_forward(self, plan) -> list:
        """Fused decode + chunk-prefill forward, grouped per served
        precision like :meth:`_decode_forward`: one
        :meth:`_fused_dispatch` per distinct width over that width's
        plan entries.  Homogeneous plans (every pre-nested config) fuse
        into exactly ONE dispatch, unchanged."""
        groups = self._precision_groups(plan, lambda e: e[0].precision)
        if len(groups) <= 1:
            return self._fused_dispatch(plan)
        rows: list = [None] * len(plan)
        for bits in groups:
            idx = [i for i, (s, _) in enumerate(plan)
                   if s.precision == bits]
            sub = self._fused_dispatch([plan[i] for i in idx])
            for j, i in enumerate(idx):
                rows[i] = sub[j]
        return rows

    def _fused_dispatch(self, plan) -> list:
        """ONE dispatch for a mixed decode + chunk-prefill step.

        Decode lanes carry 1 real token, chunk lanes up to
        ``chunk_tokens``, padded to a common bucketed ``(B, S)``; pad
        tokens carry position -1 (attention rows masked, pool writes
        dropped) exactly like bucketed prefill pads, and the Sq>=1
        paged kernel masks causality by absolute position per row, so
        lanes of different real lengths coexist in one grid.  Per-lane
        logits are gathered at ``last_idx`` (the lane's last real
        token).  Attention-only configs (``_bucketable``); pool slots
        never exist here."""
        bb = self._decode_bucket(len(plan))
        smax = max(n for _, n in plan)
        sq = prefill_bucket(smax, self.max_len)
        nb = min(_next_pow2(max(len(s.blocks) for s, _ in plan) or 1),
                 self.n_batch_blocks)
        if self.obs.enabled:
            self.obs.on_dispatch(live=len(plan), lanes=bb,
                                 tok_live=sum(n for _, n in plan),
                                 tok_lanes=bb * sq)
        toks = np.zeros((bb, sq), np.int32)
        pos = np.full((bb, sq), -1, np.int32)  # pads: masked everywhere
        last = np.zeros(bb, np.int32)
        lens = np.zeros(bb, np.int32)
        tables = np.zeros((bb, nb), np.int32)  # 0 = the null block
        offsets = np.zeros(bb, np.int32)
        for i, (seq, n) in enumerate(plan):
            if seq.prefilling:
                toks[i, :n] = np.asarray(
                    seq.pending[seq.length:seq.length + n], np.int32)
            else:
                toks[i, 0] = seq.last_tok
            pos[i, :n] = np.arange(seq.length, seq.length + n)
            last[i], lens[i] = n - 1, seq.length
            tables[i, :len(seq.blocks)] = seq.blocks
            offsets[i] = seq.freed_prefix
        batch = {"tokens": jnp.asarray(toks),
                 "positions": jnp.asarray(pos),
                 "last_idx": jnp.asarray(last, jnp.int32)}
        caches = self.pool.step_caches(tables, lens, block_offsets=offsets)
        quant = self._quant_for(plan[0][0].precision)
        if self._moe_telemetry:
            logits, caches, mst = prefill_step_bucketed(
                self.params, batch, caches, self.cfg, quant,
                moe_stats=True)
            self.obs.on_moe(mst)
        else:
            logits, caches = prefill_step_bucketed(
                self.params, batch, caches, self.cfg, quant)
        self.pool.absorb(caches)
        logits = np.asarray(logits, np.float32)
        return [logits[i] for i in range(len(plan))]

    def _advance(self, plan, rows, t_fwd0: float = 0.0,
                 t_fwd1: float = 0.0) -> None:
        """Consume a step's logits: advance lengths, sample/emit decode
        tokens (and the first token of a request whose prefill just
        completed), finish what is done.  ``t_fwd0``/``t_fwd1`` bound
        the step's forward pass (engine clock) -- each landed chunk is
        traced as a closed ``chunk_prefill`` span over that window."""
        sch = self.scheduler
        obs = self.obs
        self.steps += 1
        for (seq, n), row in zip(plan, rows):
            if seq.req.done:    # cancelled/expired by a callback mid-step
                continue
            try:
                if seq.prefilling:
                    seq.length += n
                    self.chunk_tokens_processed += n
                    if obs.enabled:
                        obs.on_chunk(seq, n, t_fwd0, t_fwd1)
                    sch.register_progress(seq)
                    if seq.length < len(seq.pending):
                        continue               # more chunks to stream
                    seq.pending = None
                    obs.on_decode_begin(seq)
                    if seq.req.out:
                        # warm resume: the pending input token is known
                        seq.last_tok = seq.req.out[-1]
                        continue
                    seq.last_tok = self._sample_checked(row, seq)
                    self._emit(seq, seq.last_tok)
                else:
                    seq.last_tok = self._sample_checked(row, seq)
                    self._emit(seq, seq.last_tok)
                    seq.length += 1
                if len(seq.req.out) >= seq.req.max_new_tokens \
                        or seq.length >= self.max_len - 1:
                    sch.finish(seq)
            except RequestFault as e:
                # step-level containment: retire exactly this sequence;
                # the other plan entries consume their rows untouched
                self._quarantine(seq, e)

    # -- decode loop --------------------------------------------------------
    def step(self) -> bool:
        """One batched decode step across all active requests."""
        return self._paged_step() if self.paged else self._contiguous_step()

    def run(self, max_steps: int = 10_000):
        while self.steps < max_steps and self._has_work():
            if not self.step():
                break

    def _has_work(self) -> bool:
        if self.paged:
            return self.scheduler.has_work
        return bool(self.queue) or any(r is not None for r in self.slot_req)

    def report(self) -> dict:
        """Occupancy snapshot (paged: pool accounting; contiguous: lanes)."""
        if self.paged:
            rep = self.pool.report(
                tokens_resident=self.scheduler.tokens_resident())
            rep.update(running=len(self.scheduler.running),
                       waiting=len(self.scheduler.waiting),
                       preemptions=self.scheduler.n_preemptions,
                       rejections=self.scheduler.n_rejections,
                       chunk_tokens=self.chunk_tokens,
                       chunk_tokens_processed=self.chunk_tokens_processed)
            return rep
        active = sum(r is not None for r in self.slot_req)
        return dict(n_slots=self.n_slots, running=active,
                    waiting=len(self.queue),
                    pool_bytes=kv_cache_bytes(self.caches),
                    tokens_resident=sum(r.length for r in self.slot_req
                                        if r is not None))
