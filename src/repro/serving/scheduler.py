"""Request scheduler for paged continuous batching.

Replaces the fixed-slot admission of the contiguous engine: requests are
admitted FCFS whenever the block pool can hold their prompt, the decode
batch is assembled from whatever is running (the engine pads it to
bucketed batch sizes to bound recompiles), and when the pool runs dry
mid-decode the *youngest* running request is preempted by eviction --
its blocks freed, the request re-queued at the front for re-prefill of
prompt + tokens generated so far (recomputation-style preemption, the
TensorRT-LLM / vLLM policy that needs no swap space).

Per-request state lives in :class:`SequenceState` objects (not parallel
numpy arrays): cached length, next input token, owned blocks, sampling
params.  Liveness guarantee: a request whose lifetime block need exceeds
the pool is rejected at submit time, so the oldest running request can
always grow -- preemption of everything younger frees enough blocks --
and the preemption loop terminates.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from repro.serving.paged_cache import PagedKVPool


@dataclasses.dataclass(eq=False)       # identity equality: states are
class SequenceState:                   # removed from lists by object
    """Mutable per-request decode state (one object per live request)."""
    req: "Request"                  # repro.serving.engine.Request
    length: int = 0                 # tokens whose KV is resident
    last_tok: int = 0               # next input token
    blocks: list = dataclasses.field(default_factory=list)
    admitted_at: int = -1           # admission counter (preemption order)

    @property
    def temperature(self) -> float:
        return getattr(self.req, "temperature", 0.0)

    def resume_tokens(self) -> np.ndarray:
        """Tokens to (re-)prefill: the prompt plus every generated token
        that has already been fed back (all of ``out`` except the last,
        which is the pending input)."""
        toks = [np.asarray(self.req.prompt, np.int32)]
        if self.req.out:
            toks.append(np.asarray(self.req.out[:-1], np.int32))
        return np.concatenate(toks)


class Scheduler:
    """FCFS admission + preemption-by-eviction over a :class:`PagedKVPool`.

    The engine drives it: :meth:`admit` before each step (prefilling via
    the engine's callback), :meth:`ensure_append_capacity` to make room
    for the step's KV append, then :meth:`finish`/:meth:`reject` as
    requests complete.
    """

    def __init__(self, pool: PagedKVPool, *, max_len: int, max_batch: int):
        self.pool = pool
        self.max_len, self.max_batch = max_len, max_batch
        self.waiting: deque = deque()      # of engine.Request
        self.running: list[SequenceState] = []
        self.n_preemptions = 0
        self.n_rejections = 0
        self._admit_counter = 0

    # -- submission ----------------------------------------------------------
    def submit(self, req) -> None:
        """Queue a request; impossible ones are rejected immediately (a
        request longer than the pool must fail cleanly, never hang)."""
        worst = len(req.prompt) + req.max_new_tokens
        if len(req.prompt) >= self.max_len - 1:
            self.reject(req, f"prompt ({len(req.prompt)} tokens) >= "
                             f"max_len-1 ({self.max_len - 1})")
            return
        need = self.pool.blocks_for(min(worst, self.max_len))
        if need > self.pool.n_usable:
            self.reject(req, f"needs {need} blocks at its longest, pool "
                             f"has {self.pool.n_usable}")
            return
        self.waiting.append(req)

    def reject(self, req, reason: str) -> None:
        req.error = f"rejected: {reason}"
        req.done = True
        self.n_rejections += 1

    # -- admission -----------------------------------------------------------
    def admit(self, prefill_fn) -> None:
        """FCFS: prefill the head of the queue while blocks and batch
        lanes are available.  ``prefill_fn(seq, tokens)`` runs the
        engine's prefill and fills ``seq.length``/``seq.last_tok``."""
        while self.waiting and len(self.running) < self.max_batch:
            req = self.waiting[0]
            seq = SequenceState(req=req)
            tokens = seq.resume_tokens()
            need = self.pool.blocks_for(len(tokens))
            # block-aligned prompts open a fresh block on the first decode
            # append: admitting without that headroom would get the
            # request preempted (its prefill discarded) on the same step
            headroom = 1 if len(tokens) % self.pool.block_size == 0 else 0
            if need + headroom > self.pool.free_blocks:
                break                      # FCFS: no skipping the head
            self.waiting.popleft()
            seq.blocks = self.pool.alloc(need)
            seq.admitted_at = self._admit_counter
            self._admit_counter += 1
            prefill_fn(seq, tokens)
            self.running.append(seq)

    # -- decode-step capacity ------------------------------------------------
    def _needs_block(self, seq: SequenceState) -> bool:
        """True when this step's KV append starts a fresh block."""
        return seq.length % self.pool.block_size == 0

    def ensure_append_capacity(self) -> None:
        """Allocate this step's new blocks, evicting the youngest running
        request(s) while the pool is short.  Terminates: the oldest
        request alone always fits (submit-time rejection bounds any
        single request's lifetime need to the pool size)."""
        while True:
            needy = [s for s in self.running if self._needs_block(s)]
            if len(needy) <= self.pool.free_blocks:
                break
            assert len(self.running) > 1, \
                "pool cannot hold the oldest request (submit gate broken)"
            self.preempt(max(self.running, key=lambda s: s.admitted_at))
        if needy:      # one alloc = one pos-reset scatter per layer
            ids = self.pool.alloc(len(needy))
            for seq, bid in zip(needy, ids):
                seq.blocks.append(bid)

    def preempt(self, seq: SequenceState) -> None:
        """Evict: free the blocks, re-queue at the front for re-prefill."""
        self.pool.free(seq.blocks)
        seq.blocks = []
        self.running.remove(seq)
        self.waiting.appendleft(seq.req)
        self.n_preemptions += 1

    # -- completion ----------------------------------------------------------
    def finish(self, seq: SequenceState) -> None:
        self.pool.free(seq.blocks)
        seq.blocks = []
        self.running.remove(seq)
        seq.req.done = True

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def tokens_resident(self) -> int:
        return sum(s.length for s in self.running)
