"""Request scheduler for paged continuous batching with prefix caching.

Replaces the fixed-slot admission of the contiguous engine: requests are
admitted FCFS whenever the block pool can hold their prompt, the decode
batch is assembled from whatever is running (the engine pads it to
bucketed batch sizes to bound recompiles), and when the pool runs dry
mid-decode the *youngest* running request is preempted by eviction.

Admission goes through the pool's prefix cache
(:meth:`~repro.serving.paged_cache.PagedKVPool.acquire_prefix`): blocks
whose prompt-chain hash matches the head of the request's token chain
are *acquired* (refcount + 1, shared through the block table) rather
than recomputed, and only the suffix is prefilled.  Completion and
preemption *release* blocks instead of destroying them -- a released
block parks in the pool's LRU cache until allocation pressure evicts
it, which turns recompute-preemption into a **warm restart**: the
re-admitted request re-acquires its own blocks and re-prefills only the
partial tail.  A decode append into a block another table still maps
(refcount > 1) first goes through copy-on-write, so shared blocks never
mutate under a reader.

Per-request state lives in :class:`SequenceState` objects (not parallel
numpy arrays): cached length, next input token, owned blocks, the state
slot, sampling params, and the per-request RNG stream (sampling is
keyed by ``(request seed, output index)``, so a preempted-then-resumed
request reproduces the exact tokens an uncontended run produces even at
temperature > 0).

**Chunked prefill** (``chunk_tokens``): instead of prefilling a whole
prompt in one admission pass (stalling every running decode for
O(prompt) and transiently demanding O(prompt) blocks), admission only
acquires the prefix-cache hit and a state slot, and the prompt then
*streams* through the step loop: :meth:`Scheduler.plan_step` composes
each step from every decoding request (always, one token each) plus a
``chunk_tokens`` budget of prompt tokens split oldest-first among
prefilling requests, and :meth:`Scheduler.ensure_step_capacity`
allocates just that step's blocks.  Decodes are therefore never crowded
out of a step, and per-step prompt work -- the decode-latency tax -- is
bounded by the chunk budget.

Liveness guarantee: a request whose *peak held-block count* exceeds the
pool is rejected at submit time (:meth:`Scheduler.lifetime_need`), so
the oldest running request can always grow -- preemption of everything
younger frees or re-caches enough blocks -- and the preemption loop
terminates.  Whole-prompt mode pins that peak at the full
``blocks_for(prompt + new)`` transient; chunked prefill grows at most
one chunk per step and reclaims out-of-window blocks *between chunks*,
so for sliding-window configs the peak drops to
``blocks_for(window + chunk) + 2`` and prompts far longer than the pool
become servable.

Sliding-window reclaim: before each step's allocations
(:meth:`Scheduler.ensure_append_capacity`) every running request's
leading blocks whose tokens are all out of the attention window are
released back through the refcount path -- block tables become rolling
windows (``SequenceState.freed_prefix``) and steady-state memory per
request is O(window), not O(length).  State slots: stateful archs
(ssm/hybrid/audio) additionally gate admission on a free slot of the
pool's :class:`~repro.serving.paged_cache.StateSlotPool`; pure-SSM
configs skip block accounting entirely (``pool.needs_blocks``).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from repro.obs import NULL_OBS
from repro.serving.faults import RequestFault
from repro.serving.paged_cache import ChainMemo, PagedKVPool


@dataclasses.dataclass(eq=False)       # identity equality: states are
class SequenceState:                   # removed from lists by object
    """Mutable per-request decode state (one object per live request)."""
    req: "Request"                  # repro.serving.engine.Request
    length: int = 0                 # tokens whose KV is resident
    last_tok: int = 0               # next input token
    blocks: list = dataclasses.field(default_factory=list)
    cached_len: int = 0             # prompt tokens served from the cache
    admitted_at: int = -1           # admission counter (preemption order)
    # sliding-window reclaim: leading logical blocks already released as
    # fully out-of-window; ``blocks`` holds only the live suffix and the
    # block table carries this as its per-request ``block_offset``
    freed_prefix: int = 0
    # state slot (SSM conv+state / enc-dec cross rows); -1 = none
    slot: int = -1
    # served weight width (bits), resolved by the engine's tier policy
    # at FIRST admission and frozen on the request (precision never
    # changes mid-request; preemption re-admits at the same bits).
    # Salts every prefix-cache chain op below, so equal prompts share
    # KV only at equal precision.  None = the engine's configured width
    precision: Optional[int] = None
    # resume point for pool.register_chain: full blocks already indexed
    # by this owner are skipped, so chain bookkeeping on every
    # finish/preempt costs O(new blocks), not O(chain length)
    chain_memo: ChainMemo = dataclasses.field(default_factory=ChainMemo)
    # chunked prefill: the full token chain still streaming in (prompt
    # plus any fed-back outputs); None once every token's KV is
    # resident and the request is decoding
    pending: Optional[np.ndarray] = None

    @property
    def prefilling(self) -> bool:
        return self.pending is not None and self.length < len(self.pending)

    @property
    def temperature(self) -> float:
        return getattr(self.req, "temperature", 0.0)

    def sample_rng(self, index: int) -> np.random.Generator:
        """Generator for this request's ``index``-th output token.

        Keyed ``(request seed, output index)`` -- stateless, so the
        draw for token k is the same whether the request ran straight
        through, was preempted and recomputed, or resumed warm from the
        prefix cache (the reproducibility contract of recompute
        preemption at temperature > 0).
        """
        seed = getattr(self.req, "seed", None)
        return np.random.default_rng(
            np.random.SeedSequence(entropy=(int(seed or 0), index)))

    def resume_tokens(self) -> np.ndarray:
        """Tokens to (re-)prefill: the prompt plus every generated token
        that has already been fed back (all of ``out`` except the last,
        which is the pending input)."""
        toks = [np.asarray(self.req.prompt, np.int32)]
        if self.req.out:
            toks.append(np.asarray(self.req.out[:-1], np.int32))
        return np.concatenate(toks)

    def token_chain(self) -> np.ndarray:
        """Every token whose KV is resident (prompt + fed-back outputs),
        the chain the pool's prefix index is keyed by."""
        toks = [np.asarray(self.req.prompt, np.int32)]
        if self.req.out:
            toks.append(np.asarray(self.req.out, np.int32))
        return np.concatenate(toks)[:self.length]


class Scheduler:
    """FCFS admission + preemption-by-eviction over a :class:`PagedKVPool`.

    The engine drives it.  Whole-prompt mode (``chunk_tokens=None``):
    :meth:`admit` before each step (prefilling via the engine's
    callback), :meth:`ensure_append_capacity` to make room for the
    step's KV append (allocating fresh blocks and copy-on-write copies
    of shared ones).  Chunked mode: :meth:`admit_chunked`, then
    :meth:`plan_step` to compose the fused decode+chunk step and
    :meth:`ensure_step_capacity` to make room for it.  Either way
    :meth:`finish`/:meth:`cancel`/:meth:`reject` retire requests.
    """

    def __init__(self, pool: PagedKVPool, *, max_len: int, max_batch: int,
                 chunk_tokens: Optional[int] = None, obs=None,
                 tail_compaction: bool = True, faults=None,
                 precision_policy=None):
        assert chunk_tokens is None or chunk_tokens >= 1, chunk_tokens
        self.pool = pool
        # nested-precision serving: ``precision_policy(req) -> bits``
        # resolves a request's served weight width at admission (the
        # engine passes its load-adaptive tier policy).  None keeps
        # every sequence at the configured width, unsalted -- the
        # pre-nested behavior, bit for bit
        self.precision_policy = precision_policy
        # fault facade: defaults to the pool's injector so engine-built
        # stacks share ONE seeded schedule across all three subsystems
        self.faults = faults if faults is not None else pool.faults
        self.max_len, self.max_batch = max_len, max_batch
        self.chunk_tokens = chunk_tokens
        # sub-block sliding-window compaction (see _compact_tail)
        self.tail_compaction = tail_compaction
        self.waiting: deque = deque()      # of engine.Request
        self.running: list[SequenceState] = []
        # lifecycle tracing facade (the engine passes its ServingObs;
        # a standalone scheduler runs against the no-op twin) and the
        # scheduler's slice of the shared metrics namespace -- event
        # counters live in the POOL's registry so one render() scrapes
        # the whole serving stack
        self.obs = obs if obs is not None else NULL_OBS
        m = pool.metrics
        self._c_preemptions = m.counter(
            "repro_sched_preemptions",
            "running requests evicted to free pool blocks")
        self._c_rejections = m.counter(
            "repro_sched_rejections",
            "requests rejected at submit (impossible to serve)")
        self._c_admissions = m.counter(
            "repro_sched_admissions", "requests admitted to running")
        self._c_stall_tokens = m.counter(
            "repro_sched_stall_tokens",
            "prompt tokens co-scheduled with >= 1 running decode (the "
            "per-step decode-latency tax)")
        self._c_stall_steps = m.counter(
            "repro_sched_stall_steps",
            "steps that co-scheduled prompt work with a running decode")
        self._c_compactions = m.counter(
            "repro_sched_tail_compactions",
            "straddling window-edge blocks released early by copying "
            "their live tail into a pre-seeded append block")
        self._c_admit_rollbacks = m.counter(
            "repro_sched_admit_rollbacks",
            "admissions rolled back by a transient alloc/slot failure "
            "(blocks and slot returned through the refcount path, "
            "request re-queued at the head)")
        self._admit_counter = 0
        # (head request, pool.version) of the last admission probe that
        # failed the capacity gate: while neither changes, re-probing
        # would re-walk the head's whole chain (hashing + refcount
        # churn) every engine step just to fail again
        self._blocked_head = None

    # legacy counter attributes: snapshots of the shared registry (the
    # registry is the source of truth, same rule as the pool's n_*)
    @property
    def n_preemptions(self) -> int:
        return int(self._c_preemptions.value)

    @property
    def n_rejections(self) -> int:
        return int(self._c_rejections.value)

    # -- submission ----------------------------------------------------------
    def submit(self, req) -> None:
        """Queue a request; impossible ones are rejected immediately (a
        request longer than the pool must fail cleanly, never hang)."""
        worst = len(req.prompt) + req.max_new_tokens
        if len(req.prompt) == 0:
            self.reject(req, "empty prompt (no position to take logits "
                             "from)")
            return
        if len(req.prompt) >= self.max_len - 1:
            self.reject(req, f"prompt ({len(req.prompt)} tokens) >= "
                             f"max_len-1 ({self.max_len - 1})")
            return
        if self.pool.needs_blocks:
            need = self.lifetime_need(worst)
            if need > self.pool.n_usable:
                self.reject(req, f"holds up to {need} blocks at once, "
                                 f"pool has {self.pool.n_usable}")
                return
        self.waiting.append(req)

    def lifetime_need(self, worst_tokens: int) -> int:
        """Peak block count a request may *hold at once* over its
        lifetime -- the submit-time liveness gate.

        Whole-prompt mode writes the entire chain in one admission
        pass, so even windowed configs pay the full un-reclaimed
        ``blocks_for(worst)`` transient (the old PR-5 open item).
        Chunked prefill grows a request at most ``chunk_tokens`` per
        step and reclaims out-of-window blocks *between chunks*, so a
        sliding-window request peaks at the in-window blocks plus one
        chunk's growth plus the two boundary partials -- prompts far
        longer than the pool become servable.  Without a window nothing
        is reclaimed mid-prefill (the whole chain stays live), so
        chunking changes decode latency, not this bound."""
        full = self.pool.blocks_for(min(worst_tokens, self.max_len))
        w = self.pool.cfg.window
        if self.chunk_tokens is None or w is None:
            return full
        return min(full, self.pool.blocks_for(w + self.chunk_tokens) + 2)

    def reject(self, req, reason: str) -> None:
        req.error = f"rejected: {reason}"
        req.done = True
        req.finish_reason = "rejected"
        self._c_rejections.inc()
        self.obs.on_finish(req, "rejected")

    # -- admission -----------------------------------------------------------
    def admit(self, prefill_fn) -> None:
        """FCFS: prefill the head of the queue while blocks and batch
        lanes are available.  The pool's prefix cache is consulted
        first: cached blocks are acquired (shared), a shared partial
        tail is copy-on-written, and only ``blocks_for(len) - hits``
        fresh blocks are drawn.  ``prefill_fn(seq, tokens)`` runs the
        engine's suffix prefill (``seq.cached_len`` tokens are already
        resident) and fills ``seq.length``/``seq.last_tok``; afterwards
        the full chain is registered in the prefix index so the *next*
        same-prefix request hits it."""
        stall = 0     # prompt tokens prefilled while decodes were live
        while self.waiting and len(self.running) < self.max_batch:
            if self.faults.admit_race():
                break      # injected race: the head loses this step
            req = self.waiting[0]
            if self.pool.slots is not None \
                    and self.pool.slots.free_slots == 0:
                break      # FCFS: wait for a finishing request's slot
            if self._blocked_head is not None \
                    and self._blocked_head[0] is req \
                    and self._blocked_head[1] == self.pool.version:
                break      # nothing changed since this head last failed
            seq = SequenceState(req=req)
            if self.precision_policy is not None:
                seq.precision = self.precision_policy(req)
            tokens = seq.resume_tokens()
            hit = self.pool.acquire_prefix(tokens, salt=seq.precision)
            # a shared partial tail must be copied before the suffix
            # writes into it (COW); sole-reference tails extend in place
            cow = hit.partial and self.pool.refcount(hit.ids[-1]) > 1
            if self.pool.needs_blocks:
                need = self.pool.blocks_for(len(tokens)) - len(hit.ids) \
                    + (1 if cow else 0)
                # block-aligned chains open a fresh block on the first
                # decode append: admitting without that headroom would
                # get the request preempted (its prefill discarded) on
                # the same step
                headroom = 1 if len(tokens) % self.pool.block_size == 0 \
                    else 0
            else:
                need = headroom = 0     # pure-SSM: state slots only
            if need + headroom > self.pool.free_blocks:
                self.pool.release(hit.ids)     # back to the cache
                # memoize AFTER the release (it bumps pool.version)
                self._blocked_head = (req, self.pool.version)
                break                          # FCFS: no skipping the head
            self.waiting.popleft()
            self._blocked_head = None
            seq.blocks = list(hit.ids)
            announced = False    # obs.on_admit already fired?
            try:
                if cow:
                    seq.blocks[-1] = self.pool.cow(seq.blocks[-1])
                if need - (1 if cow else 0):
                    seq.blocks.extend(
                        self.pool.alloc(need - (1 if cow else 0)))
                if self.pool.slots is not None:
                    seq.slot = self.pool.alloc_slot()
                seq.cached_len = hit.cached_len
                self.pool.record_hit(hit, len(tokens))
                seq.admitted_at = self._admit_counter
                self._admit_counter += 1
                self._c_admissions.inc()
                # whole-prompt admission stalls every running decode for
                # the entire suffix -- the O(prompt) tax chunked prefill
                # bounds (same stall definition either way: prompt tokens
                # co-scheduled with >= 1 running decode)
                if any(not s.prefilling for s in self.running):
                    stall += len(tokens) - seq.cached_len
                obs = self.obs
                obs.on_admit(seq, cached_tokens=seq.cached_len,
                             prefilling=True)
                announced = True
                t0 = obs.t() if obs.enabled else 0.0
                prefill_fn(seq, tokens)
                if obs.enabled:
                    obs.on_chunk(seq, len(tokens) - seq.cached_len,
                                 t0, obs.t())
                obs.on_decode_begin(seq)
                self.pool.register_chain(tokens, seq.blocks,
                                         memo=seq.chain_memo,
                                         salt=seq.precision)
                # a long prompt's leading blocks may already be fully out
                # of the attention window: return them before decode
                self._reclaim_seq(seq)
            except Exception as e:
                self._rollback_admission(req, seq, e, announced)
                break
            self.running.append(seq)
        if stall:
            self._c_stall_tokens.inc(stall)
            self._c_stall_steps.inc()

    def _rollback_admission(self, req, seq, exc, announced) -> None:
        """Unwind a partially-admitted request after a mid-admission
        failure: every block reference ``seq`` holds returns through
        the refcount path and the state slot (if taken) is freed, so
        the pool is exactly as if the admission never started.  A
        transient pool fault (exhaustion ``RuntimeError``) re-queues
        the request at the head for the next step; a
        request-attributable :class:`RequestFault` (e.g. its first
        token's callback raised mid-prefill) finishes it with
        ``finish_reason='error'`` instead -- re-queueing after a
        partial emission would corrupt ``resume_tokens``."""
        self.pool.release(seq.blocks)
        seq.blocks = []
        if seq.slot >= 0:
            self.pool.free_slot(seq.slot)
            seq.slot = -1
        self._c_admit_rollbacks.inc()
        if isinstance(exc, RequestFault):
            req.done = True
            req.finish_reason = "error"
            if getattr(req, "error", None) is None:
                req.error = str(exc)
            self.obs.on_finish(req, "error", seq=seq)
        else:
            if announced:
                # on_admit already opened the trace's running span:
                # close it like a preemption so the walk stays balanced
                self.obs.on_preempt(seq)
            self.waiting.appendleft(req)

    def admit_chunked(self) -> None:
        """FCFS *chunked* admission: acquire the prefix-cache hit and a
        state slot, set up the pending chain, and return -- no blocks
        are allocated and no model pass runs here.  The prompt then
        streams through the step loop (:meth:`plan_step` /
        :meth:`ensure_step_capacity`) one chunk budget at a time, so
        the capacity gate is the *first chunk's* block need plus one
        block of headroom, not the whole prompt."""
        assert self.chunk_tokens is not None, \
            "admit_chunked needs Scheduler(chunk_tokens=...)"
        while self.waiting and len(self.running) < self.max_batch:
            if self.faults.admit_race():
                break      # injected race: the head loses this step
            req = self.waiting[0]
            if self.pool.slots is not None \
                    and self.pool.slots.free_slots == 0:
                break      # FCFS: wait for a finishing request's slot
            if self._blocked_head is not None \
                    and self._blocked_head[0] is req \
                    and self._blocked_head[1] == self.pool.version:
                break      # nothing changed since this head last failed
            seq = SequenceState(req=req)
            if self.precision_policy is not None:
                seq.precision = self.precision_policy(req)
            tokens = seq.resume_tokens()
            hit = self.pool.acquire_prefix(tokens, salt=seq.precision)
            seq.blocks = list(hit.ids)
            seq.cached_len = seq.length = hit.cached_len
            seq.pending = tokens
            if self.pool.needs_blocks:
                first = min(self.chunk_tokens, len(tokens) - seq.length)
                # blocks the running requests' own next step will draw:
                # admitting into them would only get this (the
                # youngest) request preempted right back out
                reserve = sum(self._span_need(s, self._next_n(s))
                              for s in self.running)
                if self._span_need(seq, first) + 1 + reserve \
                        > self.pool.free_blocks:
                    self.pool.release(hit.ids)     # back to the cache
                    # memoize AFTER the release (it bumps pool.version)
                    self._blocked_head = (req, self.pool.version)
                    break                          # FCFS: no skipping
            self.waiting.popleft()
            self._blocked_head = None
            if self.pool.slots is not None:
                try:
                    seq.slot = self.pool.alloc_slot()
                except RuntimeError as e:
                    self._rollback_admission(req, seq, e, False)
                    break
            self.pool.record_hit(hit, len(tokens))
            seq.admitted_at = self._admit_counter
            self._admit_counter += 1
            self._c_admissions.inc()
            self.obs.on_admit(seq, cached_tokens=seq.cached_len,
                              prefilling=seq.prefilling)
            if not seq.prefilling:
                self.obs.on_decode_begin(seq)
            self.running.append(seq)

    # -- chunked step planning -----------------------------------------------
    def _next_n(self, seq: SequenceState) -> int:
        """Tokens ``seq`` would process in a full-budget step."""
        if not seq.prefilling:
            return 1
        return min(self.chunk_tokens, len(seq.pending) - seq.length)

    def plan_step(self) -> list:
        """Compose one continuous-batching step as ``(seq, n_tokens)``
        entries.  Every decoding request is planned every step (one
        token each): prompt work can *never* crowd a decode out of a
        step, which is the starvation bound the property suite asserts.
        Prefilling requests split the ``chunk_tokens`` budget
        oldest-first, so per-step prompt work -- the decode-latency tax
        -- is bounded by the budget and the head of the prefill line
        drains in ceil(remaining / chunk_tokens) steps."""
        plan = [(s, 1) for s in self.running if not s.prefilling]
        budget = self.chunk_tokens or 0
        for s in sorted((s for s in self.running if s.prefilling),
                        key=lambda s: s.admitted_at):
            if budget <= 0:
                break
            n = min(budget, len(s.pending) - s.length)
            plan.append((s, n))
            budget -= n
        return plan

    # -- sliding-window reclaim ----------------------------------------------
    def _reclaim_seq(self, seq: SequenceState) -> None:
        """Release every leading block of ``seq`` whose tokens are all
        out of the attention window for all future queries.

        The pending query position is ``seq.length``, so future queries
        attend positions ``> q - window >= length - window``; logical
        block ``j`` (tokens ``[j*bs, (j+1)*bs)``) is dead once
        ``(j+1)*bs - 1 <= length - window``.  Released blocks go through
        the refcount path (prefix-shared copies survive for their other
        readers) and the block table becomes a rolling window: ``blocks``
        keeps the live suffix, ``freed_prefix`` the offset."""
        w = self.pool.cfg.window
        if w is None or not self.pool.needs_blocks:
            return
        n_dead = max(0, (seq.length - w + 1) // self.pool.block_size)
        drop = n_dead - seq.freed_prefix
        if drop > 0:
            # the write-target block (logical length // bs) is never dead
            # for window >= 1, so the live suffix keeps at least the tail
            assert drop <= len(seq.blocks), (drop, len(seq.blocks))
            dead, seq.blocks = seq.blocks[:drop], seq.blocks[drop:]
            seq.freed_prefix = n_dead
            self.pool.release(dead, window_reclaim=True)
        self._compact_tail(seq, n_dead)

    def _compact_tail(self, seq: SequenceState, n_dead: int) -> None:
        """Sub-block compaction at the window edge: release the
        *straddling* block (head slots dead, tail slots live) a whole
        block-lifetime early by copying its live tail into a fresh
        block pre-seeded as the chain's NEXT append target.

        With window ``w`` and block size ``bs``, ``d0 = (length-w+1) %
        bs`` head slots of logical block ``j = n_dead`` are permanently
        out of window but the block stays held until ``d0`` wraps --
        on average ``bs/2`` dead slots per request.  Instead: allocate
        a fresh block ``F``, copy the straddler's live slots
        ``d0..bs`` (positions AND planes, slot-aligned) into ``F``,
        append ``F`` as logical block ``jt+1`` (``jt`` = the current
        tail), bump ``freed_prefix`` past the straddler and release it.
        Held blocks stay constant *now* but the next append-driven
        allocation is already satisfied, so peak blocks drop by ~1 per
        request.  The paged kernel reads keys by per-slot ``pos`` tag
        across every table entry, so a copied token's KV may live in a
        block that is not its natural ``pos // bs`` home.

        Die-before-clobber: ``F`` slot ``o`` is overwritten when
        position ``(jt+1)*bs + o`` lands; a step writing ``n`` tokens
        from query position ``q0`` may clobber while ``q0`` still
        attends the copied token unless ``d0 >= fill - bs + 1 + (n-1)``
        (``fill`` = tokens in the tail block) -- always true for
        decode (``n=1``, RHS <= 1 <= d0), checked against the chunk
        budget otherwise.  Guards: the straddler must not be the write
        target (``len(blocks) >= 2``), a prior compaction must not
        have advanced past it (``freed_prefix == n_dead``), and the
        pool must hold a strictly-free block -- evicting a cached
        block for a net-zero count move would shrink the prefix cache.
        """
        if not self.tail_compaction:
            return
        w = self.pool.cfg.window
        dead_tokens = seq.length - w + 1
        if dead_tokens <= 0 or seq.freed_prefix != n_dead \
                or len(seq.blocks) < 2:
            return
        bs = self.pool.block_size
        d0 = dead_tokens % bs
        if d0 < 1:
            return
        fill = seq.length - (seq.length - 1) // bs * bs
        slack = (self.chunk_tokens or 1) - 1
        if d0 < fill - bs + 1 + slack:
            return
        if self.pool.free_uncached_blocks < 1:
            return
        (fresh,) = self.pool.alloc(1)
        self.pool.copy_tail(seq.blocks[0], fresh, d0)
        head = seq.blocks[0]
        seq.blocks = seq.blocks[1:] + [fresh]
        seq.freed_prefix = n_dead + 1
        self.pool.release([head], window_reclaim=True)
        self._c_compactions.inc()

    def reclaim_out_of_window(self) -> None:
        """Roll every running request's block table past its dead
        prefix (sliding-window attention), returning out-of-window
        blocks to the pool before this step's allocations."""
        for seq in self.running:
            self._reclaim_seq(seq)

    # -- step capacity -------------------------------------------------------
    def _span_need(self, seq: SequenceState, n: int) -> int:
        """Blocks writing ``n`` tokens at position ``seq.length`` costs:
        fresh blocks to cover the span, plus 1 COW copy when the first
        write lands in a partial block another table still maps."""
        if not self.pool.needs_blocks or n <= 0:
            return 0
        have = seq.freed_prefix + len(seq.blocks)
        need = max(0, self.pool.blocks_for(seq.length + n) - have)
        if seq.length % self.pool.block_size and seq.blocks \
                and self.pool.refcount(seq.blocks[-1]) > 1:
            need += 1
        return need

    def ensure_append_capacity(self) -> None:
        """Whole-prompt mode's per-step capacity call: every running
        request appends one decode token.  (The chunked loop calls
        :meth:`ensure_step_capacity` with its plan instead.)"""
        self.ensure_step_capacity([(s, 1) for s in self.running])

    def ensure_step_capacity(self, plan: list) -> list:
        """Allocate the planned step's blocks (fresh + copy-on-write),
        evicting the youngest running request(s) while the pool is
        short; returns the plan minus preempted entries.  Out-of-window
        blocks are reclaimed first -- freeing a dead prefix can make
        preemption unnecessary, and with chunked prefill this runs
        *between chunks*, so a windowed request's table rolls while its
        prompt is still streaming in and its held-block peak stays at
        :meth:`lifetime_need`, not O(prompt).  Terminates: the oldest
        request alone always fits (the submit gate bounds any single
        request's peak hold by the pool size, and preempting every
        younger request returns all other blocks to refcount 0)."""
        self.reclaim_out_of_window()
        if not self.pool.needs_blocks:
            return plan
        # injected preemption storm: evict the youngest as if the pool
        # were under pressure (recompute restarts reproduce the same
        # tokens by the seeded-sampling contract, so this only stresses
        # the warm-restart path, not the math)
        while self.faults.preempt_storm() and len(self.running) > 1:
            victim = max(self.running, key=lambda s: s.admitted_at)
            self.preempt(victim)
            plan = [(s, n) for s, n in plan if s is not victim]
        while True:
            while True:
                need = sum(self._span_need(s, n) for s, n in plan)
                if need <= self.pool.free_blocks:
                    break
                assert len(self.running) > 1, \
                    "pool cannot hold the oldest request " \
                    "(submit gate broken)"
                victim = max(self.running, key=lambda s: s.admitted_at)
                self.preempt(victim)
                plan = [(s, n) for s, n in plan if s is not victim]
            grow = [(s, self.pool.blocks_for(s.length + n)
                     - (s.freed_prefix + len(s.blocks)))
                    for s, n in plan]
            grow = [(s, g) for s, g in grow if g > 0]
            try:
                if grow:    # one alloc = one pos-reset scatter per layer
                    ids = self.pool.alloc(sum(g for _, g in grow))
                    k = 0
                    for seq, g in grow:
                        seq.blocks.extend(ids[k:k + g])
                        k += g
                for seq, n in plan:
                    if seq.length % self.pool.block_size == 0 \
                            or not seq.blocks:
                        continue
                    # the partial block the first write lands in (NOT
                    # blocks[-1] -- a multi-token chunk may have grown
                    # past it just above)
                    j = seq.length // self.pool.block_size \
                        - seq.freed_prefix
                    if self.pool.refcount(seq.blocks[j]) > 1:
                        seq.blocks[j] = self.pool.cow(seq.blocks[j])
            except RuntimeError:
                # alloc or COW failed AFTER the capacity check (an
                # injected exhaustion, or eviction pressure from a COW
                # draw): alloc is atomic and partial grow/COW state is
                # individually consistent (grown blocks stay owned by
                # their seqs), so treat it as a shortfall -- preempt the
                # youngest and retry.  With one request left, surface to
                # the engine's step containment instead.
                if len(self.running) <= 1:
                    raise
                victim = max(self.running, key=lambda s: s.admitted_at)
                self.preempt(victim)
                plan = [(s, n) for s, n in plan if s is not victim]
                continue
            break
        # the step's decode-stall metric, recorded on the FINAL plan
        # (post-preemption): prompt tokens this step co-schedules with
        # at least one running decode.  This is the canonical stall
        # definition -- benchmarks/chunked_prefill.py asserts its own
        # hand count equals these counters
        stall = sum(n for s, n in plan if s.prefilling)
        if stall and any(not s.prefilling for s, _ in plan):
            self._c_stall_tokens.inc(stall)
            self._c_stall_steps.inc()
        return plan

    def _release_seq(self, seq: SequenceState) -> None:
        """Register the chain (newly filled blocks become hits for
        same-prefix requests -- including this one, on warm restart)
        and drop this table's references.  A rolled table
        (``freed_prefix > 0``) skips registration: its blocks no longer
        start at chain position 0, and a prefix walker could never
        reach them without the reclaimed head anyway.  The state slot
        (if any) returns to the slot pool."""
        if seq.freed_prefix == 0:
            self.pool.register_chain(seq.token_chain(), seq.blocks,
                                     memo=seq.chain_memo,
                                     salt=seq.precision)
        self.pool.release(seq.blocks)
        seq.blocks = []
        if seq.slot >= 0:
            self.pool.free_slot(seq.slot)
            seq.slot = -1

    def preempt(self, seq: SequenceState) -> None:
        """Evict: release the blocks (they stay cached until allocation
        pressure reclaims them), re-queue at the front.  On re-admission
        the prefix lookup re-acquires whatever survived, so an
        uncontended pool turns the recompute into a warm restart."""
        self._release_seq(seq)
        self.running.remove(seq)
        self.waiting.appendleft(seq.req)
        self._c_preemptions.inc()
        self.obs.on_preempt(seq)

    def register_progress(self, seq: SequenceState) -> None:
        """Index the blocks a freshly landed chunk filled in the prefix
        cache: a same-prefix request admitted mid-prefill shares the
        chain that is already resident (copy-on-write protects the
        growing tail).  Rolled tables skip registration, same as
        :meth:`_release_seq`.  O(new blocks) via the chain memo."""
        if seq.freed_prefix == 0:
            self.pool.register_chain(seq.token_chain(), seq.blocks,
                                     memo=seq.chain_memo,
                                     salt=seq.precision)

    # -- completion ----------------------------------------------------------
    def finish(self, seq: SequenceState, reason: str = "length") -> None:
        self._release_seq(seq)
        self.running.remove(seq)
        seq.req.done = True
        seq.req.finish_reason = reason
        self.obs.on_finish(seq.req, reason, seq=seq)

    def cancel(self, req, reason: str = "cancelled") -> bool:
        """Abort ``req`` wherever it lives.  A running request --
        decoding or mid-chunked-prefill -- releases every block and its
        state slot through the refcount path (the zero-leak property
        the harness asserts); a waiting request just leaves the queue.
        Returns False for unknown (or already finished) requests."""
        found = None
        for seq in self.running:
            if seq.req is req:
                self._release_seq(seq)
                self.running.remove(seq)
                found = seq
                break
        else:
            try:
                self.waiting.remove(req)
            except ValueError:
                return False
        req.done = True
        req.finish_reason = reason
        self.obs.on_finish(req, reason, seq=found)
        return True

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def tokens_resident(self) -> int:
        return sum(s.length for s in self.running)
