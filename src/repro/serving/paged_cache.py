"""Paged block pool over packed bipolar-INT KV planes (serving memory).

The contiguous engine reserves ``max_len`` cache tokens per slot whether
a request is 8 tokens or 8k, so the 2x-16x payload savings of ``kv_bits``
is eaten by over-allocation.  This module turns the quantized KV cache
into a *block pool* (the TensorRT-LLM paged-KV design adapted to our
pallas|interpret|reference kernel contract): fixed-size token blocks
shared by every request and every layer, addressed through per-request
block tables.  Concurrent requests then scale with *tokens actually
resident x bits/element*, not ``n_slots x max_len x 16``.

Layout.  The pool reuses :func:`repro.models.model.init_caches` with
``batch=n_blocks, max_len=block_size``: every attention cache leaf's
leading (batch, length) dims become (physical block, in-block slot) --
``k``/``v`` are ``(n_blocks, block_size, H, kv_bits, D/32)`` uint32 bit
planes (stacked scan units carry a leading ``n_units`` dim), scales are
``(n_blocks, block_size, H, 1)`` f32 and ``pos`` is ``(n_blocks,
block_size)`` int32.  One *logical* block id addresses the same physical
index in every layer's pool, so a request owns a single block table.

Block 0 is the reserved **null block**: never allocated, its positions
stay -1, and block-table padding points at it -- a padded or inactive
lane therefore reads only masked slots and contributes exactly 0.

Sharing (copy-on-write prefix cache).  Blocks are *refcounted*: several
requests may map the same physical block through their tables (the
serving analogue of the paper's §4.2 rule of never re-moving data that
is already resident in fast memory -- here, never re-prefilling a
prompt prefix whose packed planes already sit in the pool).  Blocks are
content-addressed by a **prompt-token-chain hash**: the key of block
``j`` commits to every token from position 0 through the end of the
block, so a hash hit means the whole prefix matches (token contents are
additionally compared exactly -- a hash collision can cost a missed
hit, never a wrong one).  :meth:`release` drops a reference; a block
reaching refcount 0 is not reclaimed but parked in an LRU cache and
only :meth:`alloc` evicts it when the free list runs dry.  A write to a
block with refcount > 1 must go through :meth:`cow` (copy-on-write):
the writer gets a private copy, the shared block stays immutable for
its other readers.

Safety argument for shared *partial* blocks (a tail block whose slots
``[0, filled)`` are valid for the sharer): every slot a sharer did not
itself (over)write holds a token at an absolute position >= the
sharer's own write frontier, so the causal mask (``kv_pos <= q_pos``)
excludes it from every one of the sharer's reads until the sharer has
replaced it.  Writers still must COW while refcount > 1 so a block
never mutates under a *live* reader's table.

Sliding-window reclaim.  With ``cfg.window = w < max_len`` a request's
oldest blocks eventually hold only tokens at positions ``<= q - w`` for
every future query position ``q`` -- permanently masked, pure dead
weight in HBM.  The scheduler *releases* such blocks back through the
refcount path (:meth:`release` with ``window_reclaim=True``): a
prefix-shared block survives for its other readers, a sole-owned one
returns to the pool (LRU-parked while indexed, free-listed otherwise).
Block tables become **rolling windows**: the request's table keeps only
live blocks and carries a per-request ``block_offset`` (count of
reclaimed leading logical blocks) so decode writes still land at
``table[slot // bs - offset]``.  Steady-state decode memory is
O(window/block_size + 1) blocks per request instead of O(length);
:meth:`report` counts these reclaims separately from LRU evictions
(``window_reclaimed``).

State slot pool.  SSM conv+state leaves (mamba/hybrid mixers) and
enc-dec cross-K/V caches are *fixed-size per request* -- there is
nothing token-granular to page.  :class:`StateSlotPool` allocates them
in whole-request **slots**: the pool's state leaves carry
``n_state_slots + 1`` rows (row 0 reserved null, read by padded batch
lanes), a request owns one slot id for its lifetime, and
:meth:`step_caches` injects the batch's slot ids so the mixers
gather/scatter their rows (:func:`repro.models.ssm.ssm_apply`,
:func:`repro.models.layers.cross_attention_apply`).  One scheduler owns
all four cache kinds: paged self-attention KV blocks, SSM state slots,
enc-dec cross slots, and (contiguous engine) plain slabs.

Invariants the pool maintains (see :meth:`validate`):
* the null block is never allocated, shared, indexed or freed;
* freshly allocated (and LRU-evicted) blocks have positions reset to -1
  (stale positions from a freed request could otherwise pass the causal
  mask);
* every non-free block has a refcount >= 0; refcount-0 blocks are
  exactly the LRU-cached ones, and only indexed blocks are cached;
* a prefix-index entry's recorded token chain always matches the
  tokens whose KV the block holds (in slots ``[0, filled)``);
* decode/prefill steps receive the pool with this batch's
  ``block_tables`` / ``length`` injected per layer (:meth:`step_caches`)
  and give updated pool leaves back through :meth:`absorb`.

Telemetry.  Event counters (``repro_pool_*``: prefix hits/lookups, COW
copies, evictions, window reclaims, chain-hash ops) live in a shared
:class:`repro.obs.metrics.MetricsRegistry` (pass ``metrics=``; the pool
otherwise keeps a private one).  The registry is the **source of
truth**: the legacy ``n_cow``-style attributes are read-only properties
over it and :meth:`report` is a snapshot of it, so the dict keys, the
benchmark scripts, and a scraped ``registry.render()`` can never
disagree (ROADMAP "Observability" contract).
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from functools import lru_cache, partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig, QuantConfig, effective_kv_bits
from repro.obs.metrics import MetricsRegistry
from repro.serving.faults import NULL_FAULTS

_KV_KEYS = ("k", "v", "k_scale", "v_scale", "pos")

# root of every prompt-token chain hash (any fixed value works; chains
# are only compared within one pool's lifetime)
_CHAIN_ROOT = hash(("paged-kv-prefix-root",))


def _chain_hash(prev: int, tokens: tuple) -> int:
    """Extend a prompt-chain hash by one block's tokens.  The chain
    commits to every token since position 0, so equal hashes (plus the
    exact token compare on lookup) mean equal full prefixes."""
    return hash((prev, tokens))


def _chain_root(salt=None) -> int:
    """Root of a prompt chain's hash walk.  ``salt`` (the serving
    precision, in nested-weight serving) partitions the prefix index:
    equal prompts registered under different salts share nothing -- a
    4-bit lane must never warm-start from KV a request computed through
    8-bit weights, whose logits (and thus cached values under quantized
    KV re-read) belong to a different effective model."""
    if salt is None:
        return _CHAIN_ROOT
    return _chain_hash(_CHAIN_ROOT, ("precision-salt", int(salt)))


def needs_blocks(cfg: ModelConfig) -> bool:
    """True when the decoder owns at least one self-attention KV stream
    (pageable in token blocks).  Pure-SSM archs have none -- their pool
    is slots only."""
    return any(cfg.layer_kind(i) == "attn" for i in range(cfg.n_layers))


def needs_state_slots(cfg: ModelConfig) -> bool:
    """True when the arch carries fixed-size per-request state that the
    paged engine must slot-allocate: SSM conv+state (ssm/hybrid) or
    enc-dec cross caches (audio)."""
    return cfg.family in ("ssm", "hybrid", "audio")


def supports_paging(cfg: ModelConfig) -> bool:
    """Every current family is servable by the paged engine: attention
    KV goes through the block pool, SSM/hybrid state and enc-dec cross
    caches through the fixed-size slot pool (closed ROADMAP PR-2 open
    item).  This is the single support gate -- the pool asserts it at
    construction, so a future family that is neither block- nor
    slot-addressable fails here, in one spot."""
    return needs_blocks(cfg) or needs_state_slots(cfg)


@dataclasses.dataclass
class ChainMemo:
    """Per-owner memo of how far a sequence's chain has already been
    registered: the first ``n_full`` full blocks of the owner's block
    list are indexed *by the owner's own blocks* (their entries are
    stable while the owner holds its references) and ``h`` is the chain
    hash through them.  :meth:`PagedKVPool.register_chain` resumes from
    here instead of re-hashing the whole chain -- release/finish/preempt
    bookkeeping for a length-L chain costs O(new blocks), not O(L)
    (ROADMAP PR-3 open item).  A block that lost the duplicate race to
    another physical copy stalls the memo, keeping it re-walkable so it
    can claim the index once the incumbent is evicted.  Owned by
    :class:`repro.serving.scheduler.SequenceState`; a fresh state
    (re-admission after preemption) starts a fresh memo.
    """
    n_full: int = 0
    h: int = _CHAIN_ROOT


@dataclasses.dataclass
class _BlockMeta:
    """Prefix-index record for one cached/cacheable block."""
    prefix_hash: int       # chain hash of everything BEFORE this block
    start: int             # absolute position of the block's first token
    tokens: tuple          # tokens resident in slots [0, len(tokens))

    @property
    def filled(self) -> int:
        return len(self.tokens)

    @property
    def key(self) -> int:
        return _chain_hash(self.prefix_hash, self.tokens)


@dataclasses.dataclass
class PrefixHit:
    """Result of :meth:`PagedKVPool.acquire_prefix` (refcounts already
    bumped on ``ids``)."""
    ids: list              # acquired blocks, chain order
    cached_len: int        # prompt tokens covered (KV already resident)
    partial: bool          # last id is a partially-filled block
    filled: int            # valid tokens in that partial block (else 0)


class StateSlotPool:
    """Fixed-size per-request state slots (SSM conv+state, enc-dec cross).

    The allocation unit is one request's entire state -- every mamba
    layer's conv/state row plus every cross cache's enc-length row --
    addressed by a single slot id valid in all layers (the slot analogue
    of the block pool's one-logical-id-addresses-all-layers rule).  Row
    0 is the reserved **null slot**: never allocated; padded batch lanes
    gather it (zeros / pos -1, contributing nothing) and their writes
    are routed out of bounds and dropped.
    """

    def __init__(self, n_slots: int):
        assert n_slots >= 1, "need at least one usable slot"
        self.n_slots = n_slots
        # LIFO free list; slot 0 reserved as the null slot
        self._free = list(range(n_slots, 0, -1))
        self._used: set = set()

    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def used_slots(self) -> int:
        return len(self._used)

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError(
                f"slot pool exhausted: all {self.n_slots} state slots "
                f"are owned by running requests")
        slot = self._free.pop()
        self._used.add(slot)
        return slot

    def free(self, slot: int) -> None:
        slot = int(slot)
        if slot == 0:
            raise ValueError("free(): slot 0 is the reserved null slot")
        if slot not in self._used:
            raise ValueError(f"free(): double free of slot {slot}")
        self._used.remove(slot)
        self._free.append(slot)

    def validate(self) -> None:
        free = set(self._free)
        assert 0 not in free and 0 not in self._used, "null slot escaped"
        assert not (free & self._used), free & self._used
        assert len(free) + len(self._used) == self.n_slots, \
            (len(free), len(self._used), self.n_slots)


@lru_cache(maxsize=None)
def _zero_slot_rows(stacked: bool):
    """Jitted one-dispatch reset of a single slot's row across every
    leaf of one state cache dict (cross ``pos`` resets to -1 so empty
    rows stay masked).  ``alloc_slot`` runs per admission, so this
    avoids one whole-leaf copy dispatch per key; on TPU the input
    buffers are donated and the reset is in place (donation is a no-op
    on CPU and would warn, hence the backend check)."""
    donate = (0,) if jax.default_backend() == "tpu" else ()

    @partial(jax.jit, donate_argnums=donate)
    def reset(c: dict, idx):
        bdim = 1 if stacked else 0
        out = {}
        for key, leaf in c.items():
            fill = -1 if key == "pos" else 0
            z = jnp.full((1,) + leaf.shape[bdim + 1:], fill, leaf.dtype)
            if bdim:
                z = jnp.broadcast_to(z[None], leaf.shape[:1] + z.shape)
                out[key] = leaf.at[:, idx].set(z)
            else:
                out[key] = leaf.at[idx].set(z)
        return out

    return reset


class PagedKVPool:
    """Refcounted copy-on-write pool of packed bipolar KV planes, plus a
    fixed-size slot pool for per-request SSM / enc-dec cross state.

    ``n_blocks`` counts physical blocks *including* the reserved null
    block 0; capacity available to requests is ``n_usable = n_blocks-1``
    blocks of ``block_size`` tokens each.  ``prefix_cache=False``
    restores PR-2 behavior: no index, release destroys immediately.
    ``n_state_slots`` (required for ssm/hybrid/audio archs) sizes the
    :class:`StateSlotPool`; ``enc_len`` caps the enc-dec cross rows and
    is required for audio archs (the Engine passes the stub frontend
    length for ``max_len`` -- the pool cannot derive it because its own
    ``max_len`` slot carries ``block_size``).
    """

    def __init__(self, cfg: ModelConfig, n_blocks: int, block_size: int,
                 quant: Optional[QuantConfig] = None, *,
                 prefix_cache: bool = True, n_state_slots: int = 0,
                 enc_len: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 faults=None):
        assert supports_paging(cfg), \
            f"no pageable KV stream or slottable state for {cfg.family!r}"
        kv_bits = effective_kv_bits(cfg, quant)
        self.needs_blocks = needs_blocks(cfg)
        self.needs_slots = needs_state_slots(cfg)
        if self.needs_blocks:
            assert kv_bits, "the paged pool stores packed bipolar " \
                "planes: set kv_bits (QuantConfig.kv_bits or " \
                "ModelConfig.kv_bits)"
        assert n_blocks >= 2, "need at least the null block + one usable"
        if cfg.window is not None and block_size > cfg.window:
            raise ValueError(
                f"Engine block_size={block_size} exceeds ModelConfig."
                f"window={cfg.window}: a block spanning more than the "
                f"attention window could hold live and dead tokens at "
                f"once for arbitrarily long; choose block_size <= "
                f"window (or raise ModelConfig.window)")
        if self.needs_slots and n_state_slots < 1:
            raise ValueError(
                f"{cfg.family} archs carry fixed-size per-request state "
                f"(SSM conv+state / enc-dec cross caches): pass "
                f"n_state_slots >= 1 so the slot pool can hold it "
                f"(Engine sizes it to max_batch)")
        if cfg.family == "audio" and enc_len is None:
            raise ValueError(
                "audio archs need enc_len (the cross-row capacity): the "
                "pool passes block_size where init_caches expects "
                "max_len, so it cannot derive the frontend length "
                "itself -- Engine passes enc_len(cfg, max_len)")
        self.cfg, self.quant = cfg, quant
        # fault injection facade (tests/chaos harness): site checks are
        # constant no-ops on the NULL_FAULTS twin, same contract as obs
        self.faults = faults if faults is not None else NULL_FAULTS
        self.kv_bits = kv_bits
        self.n_blocks, self.block_size = n_blocks, block_size
        self.prefix_cache = prefix_cache
        self.slots = (StateSlotPool(n_state_slots)
                      if self.needs_slots else None)
        self.caches = M.init_caches(
            cfg, n_blocks, block_size, enc_len=enc_len, quant=quant,
            state_batch=(n_state_slots + 1) if self.needs_slots else None)
        # LIFO free list, block 0 reserved as the null block
        self._free = list(range(n_blocks - 1, 0, -1))
        self._ref: dict = {}            # block id -> refcount (>= 0)
        self._lru: OrderedDict = OrderedDict()   # refcount-0 cached blocks
        self._meta: dict = {}           # block id -> _BlockMeta
        self._full_index: dict = {}     # chain hash -> full block id
        self._partial_index: dict = {}  # prefix chain hash -> partial id
        # bumped on every state change that could alter an allocation or
        # prefix-lookup outcome; lets the scheduler memoize a failed
        # admission probe instead of re-walking the head's chain per step
        self.version = 0
        # event accounting lives in the metrics registry (ISSUE 7: one
        # namespace shared with the scheduler and engine -- report()
        # and the legacy ``n_*`` attributes below are snapshots of it).
        # A standalone pool gets a private registry; the engine passes
        # its own so everything scrapes in one render()
        self.metrics = metrics if metrics is not None \
            else MetricsRegistry()
        m = self.metrics
        self._c_prefix_hits = m.counter(
            "repro_pool_prefix_hits",
            "committed admissions that reused >= 1 cached prefix block")
        self._c_hit_tokens = m.counter(
            "repro_pool_prefix_hit_tokens",
            "prompt tokens served from resident prefix blocks")
        self._c_lookups = m.counter(
            "repro_pool_prefix_lookups",
            "committed admissions probed against the prefix index")
        self._c_lookup_tokens = m.counter(
            "repro_pool_prefix_lookup_tokens",
            "prompt tokens of committed admissions")
        self._c_cow = m.counter(
            "repro_pool_cow", "copy-on-write block copies")
        self._c_evictions = m.counter(
            "repro_pool_evictions",
            "LRU-cached blocks evicted under allocation pressure")
        self._c_window = m.counter(
            "repro_pool_window_reclaimed",
            "out-of-window blocks returned to the pool (SWA reclaim)")
        # block-chunk hashes computed by register_chain (the ChainMemo
        # resume point keeps this O(new blocks) per call, not O(chain))
        self._c_chain_ops = m.counter(
            "repro_pool_chain_hash_ops",
            "block-chunk hashes computed by register_chain")
        self._g_blocks = m.gauge(
            "repro_pool_blocks", "pool blocks by state",
            labelnames=("state",))

    # -- accounting ----------------------------------------------------------
    # Legacy counter attributes, preserved as registry snapshots: the
    # registry is the single source of truth (satellite of ISSUE 7),
    # these views keep the PR 2-6 test/benchmark surface exact.
    @property
    def n_prefix_hits(self) -> int:
        return int(self._c_prefix_hits.value)

    @property
    def n_hit_tokens(self) -> int:
        return int(self._c_hit_tokens.value)

    @property
    def n_lookups(self) -> int:
        return int(self._c_lookups.value)

    @property
    def n_lookup_tokens(self) -> int:
        return int(self._c_lookup_tokens.value)

    @property
    def n_cow(self) -> int:
        return int(self._c_cow.value)

    @property
    def n_evictions(self) -> int:
        return int(self._c_evictions.value)

    @property
    def n_window_reclaimed(self) -> int:
        return int(self._c_window.value)

    @property
    def n_chain_hash_ops(self) -> int:
        return int(self._c_chain_ops.value)

    @property
    def n_usable(self) -> int:
        return self.n_blocks - 1

    @property
    def free_blocks(self) -> int:
        """Blocks :meth:`alloc` can hand out *right now*: truly free ones
        plus refcount-0 cached blocks (evictable)."""
        return len(self._free) + len(self._lru)

    @property
    def used_blocks(self) -> int:
        """Blocks some request currently references (refcount >= 1)."""
        return self.n_usable - self.free_blocks

    @property
    def cached_blocks(self) -> int:
        """Refcount-0 blocks parked in the LRU prefix cache."""
        return len(self._lru)

    @property
    def shared_blocks(self) -> int:
        """Blocks mapped by more than one live block table."""
        return sum(1 for r in self._ref.values() if r > 1)

    def refcount(self, bid: int) -> int:
        return self._ref.get(bid, 0)

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def report(self, tokens_resident: Optional[int] = None) -> dict:
        """Occupancy / fragmentation / sharing accounting.

        ``tokens_resident``: total tokens currently cached across
        requests (the scheduler knows; the pool only sees blocks).
        Internal fragmentation = allocated-but-empty token slots as a
        fraction of allocated slots.

        Every event-counter key is read back from the metrics registry
        (the pool increments registry counters directly), so this dict
        is a *snapshot* of the shared namespace and can never drift
        from a scraped ``registry.render()``."""
        from repro.serving.engine import kv_cache_bytes
        self.sync_gauges()
        pool_bytes = kv_cache_bytes(self.caches)
        payload = kv_cache_bytes(self.caches, payload_only=True)
        slots = self.used_blocks * self.block_size
        rep = dict(
            n_blocks=self.n_blocks, block_size=self.block_size,
            kv_bits=self.kv_bits,
            n_usable=self.n_usable, free_blocks=self.free_blocks,
            used_blocks=self.used_blocks,
            cached_blocks=self.cached_blocks,
            shared_blocks=self.shared_blocks,
            max_refcount=max(self._ref.values(), default=0),
            prefix_hits=self.n_prefix_hits,
            prefix_hit_tokens=self.n_hit_tokens,
            prefix_lookups=self.n_lookups,
            prefix_lookup_tokens=self.n_lookup_tokens,
            cow_copies=self.n_cow,
            evictions=self.n_evictions,
            window_reclaimed=self.n_window_reclaimed,
            chain_hash_ops=self.n_chain_hash_ops,
            pool_bytes=int(pool_bytes), payload_bytes=int(payload),
            bytes_per_block=int(pool_bytes / max(self.n_blocks, 1)),
            occupancy=self.used_blocks / max(self.n_usable, 1),
        )
        if self.slots is not None:
            rep.update(state_slots=self.slots.n_slots,
                       free_state_slots=self.slots.free_slots,
                       used_state_slots=self.slots.used_slots)
        if tokens_resident is not None:
            rep["tokens_resident"] = int(tokens_resident)
            rep["fragmentation"] = (
                1.0 - tokens_resident / slots if slots else 0.0)
        return rep

    def sync_gauges(self) -> None:
        """Refresh the registry's block-state gauges from the live
        pool structure (called by :meth:`report` and the engine's
        per-step hook; gauges are derived state, counters are not)."""
        self._g_blocks.labels(state="free").set(len(self._free))
        self._g_blocks.labels(state="used").set(self.used_blocks)
        self._g_blocks.labels(state="cached").set(self.cached_blocks)
        self._g_blocks.labels(state="shared").set(self.shared_blocks)

    # -- alloc / free --------------------------------------------------------
    def alloc(self, n: int) -> list:
        """Take ``n`` blocks at refcount 1 with positions reset to -1.

        The free list is drained first; when dry, refcount-0 cached
        blocks are evicted in LRU order (their prefix-index entries are
        dropped with them).

        Fault sites (both consulted BEFORE any mutation, so alloc is
        atomic -- it either completes or leaves the pool untouched):
        ``alloc_fail`` raises the exhaustion error on a satisfiable
        request; ``forced_evict`` evicts one LRU-cached block first."""
        if self.faults.alloc_fail(n):
            raise RuntimeError(
                f"pool exhausted (injected fault): want {n} blocks, "
                f"{self.free_blocks} free")
        if n > self.free_blocks:
            raise RuntimeError(
                f"pool exhausted: want {n} blocks, {self.free_blocks} free")
        if self.faults.forced_evict() and self._lru:
            victim, _ = self._lru.popitem(last=False)       # LRU end
            self._unregister(victim)
            del self._ref[victim]
            self._free.append(victim)
            self._c_evictions.inc()
        self.version += 1
        ids = []
        for _ in range(n):
            if not self._free:
                victim, _ = self._lru.popitem(last=False)   # LRU end
                self._unregister(victim)
                del self._ref[victim]
                self._free.append(victim)
                self._c_evictions.inc()
            bid = self._free.pop()
            self._ref[bid] = 1
            ids.append(bid)
        self._reset_pos(ids)
        return ids

    def free(self, ids) -> None:
        """Destroy blocks outright (no caching), PR-2 style.

        Safe against misuse: freeing an empty list is a no-op; freeing a
        block that is not live (double-free), freeing the null block, a
        duplicated id, or a block other tables still reference raises a
        clear error instead of silently corrupting the free list."""
        ids = list(ids)
        if len(set(ids)) != len(ids):
            raise ValueError(f"free(): duplicate block ids in {ids}")
        for bid in ids:
            bid = int(bid)
            if bid == 0:
                raise ValueError("free(): block 0 is the reserved null block")
            if bid not in self._ref:
                raise ValueError(
                    f"free(): double free of block {bid} (not live; free "
                    f"list and prefix cache are intact)")
            if self._ref[bid] > 1:
                raise ValueError(
                    f"free(): block {bid} still has refcount "
                    f"{self._ref[bid]}; release() the extra references")
        self.version += 1
        for bid in ids:
            self._destroy(int(bid))

    # -- refcounting ---------------------------------------------------------
    def acquire(self, ids) -> None:
        """Add one reference per block (a cached block leaves the LRU)."""
        ids = list(ids)
        if ids:
            self.version += 1
        for bid in ids:
            bid = int(bid)
            assert bid != 0 and bid in self._ref, bid
            if self._ref[bid] == 0:
                self._lru.pop(bid)
            self._ref[bid] += 1

    def release(self, ids, *, window_reclaim: bool = False) -> None:
        """Drop one reference per block.  At refcount 0 an indexed block
        parks in the LRU cache (evicted only when :meth:`alloc` runs
        dry); an unindexed one is destroyed.  With ``prefix_cache=False``
        refcount 0 always destroys (PR-2 reclamation).

        ``window_reclaim``: this release retires an out-of-window block
        (sliding-window attention: every token the block holds is
        permanently masked for its owner).  Prefix-shared blocks survive
        for their other readers -- ``report()``'s ``window_reclaimed``
        counts only blocks that reached refcount 0 and so became
        *reallocatable*: free-listed if unindexed, LRU-parked if the
        prefix index still maps them (a parked block serves future
        same-prefix hits until allocation pressure takes it, at which
        point it ALSO counts in ``evictions`` -- the two counters tally
        different events, retire-by-window vs reuse-under-pressure, not
        disjoint block sets)."""
        ids = list(ids)
        if ids:
            self.version += 1
        for bid in ids:
            bid = int(bid)
            if self._ref.get(bid, 0) < 1:
                raise ValueError(
                    f"release(): block {bid} has no live reference "
                    f"(double release?)")
            self._ref[bid] -= 1
            if self._ref[bid] > 0:
                continue
            if window_reclaim:
                self._c_window.inc()
            if self.prefix_cache and bid in self._meta:
                self._lru[bid] = None          # MRU end
            else:
                self._destroy(bid)

    @property
    def free_uncached_blocks(self) -> int:
        """Blocks on the free list proper -- allocatable WITHOUT evicting
        a cached (refcount-0, prefix-indexed) block.  The sub-block
        window compactor gates on this: trading a cached block for a
        net-zero block-count move would silently shrink the prefix
        cache."""
        return len(self._free)

    def copy_tail(self, src: int, dst: int, start: int) -> None:
        """Copy slot rows ``start..block_size`` of block ``src`` into
        the SAME slots of ``dst``, every plane plus the ``pos`` tags
        (sub-block sliding-window compaction: the live tail of a
        straddling block moves, with its absolute positions, into a
        fresh block that doubles as the chain's next append target).
        ``src`` is only read -- prefix-shared copies stay intact for
        their other readers."""
        s, d = int(src), int(dst)
        sl = slice(int(start), self.block_size)
        for c, stacked in self._attn_caches():
            for key in _KV_KEYS:
                if stacked:
                    c[key] = c[key].at[:, d, sl].set(c[key][:, s, sl])
                else:
                    c[key] = c[key].at[d, sl].set(c[key][s, sl])

    def cow(self, bid: int) -> int:
        """Copy-on-write: clone ``bid``'s planes into a fresh block and
        drop one reference on the original.  Callers must route every
        write to a block with refcount > 1 through here, so shared
        blocks never mutate under another reader's table."""
        bid = int(bid)
        assert self._ref.get(bid, 0) >= 1, bid
        (new,) = self.alloc(1)
        idx_new = jnp.asarray([new], jnp.int32)
        idx_old = jnp.asarray([bid], jnp.int32)
        for c, stacked in self._attn_caches():
            for key in _KV_KEYS:
                if stacked:
                    c[key] = c[key].at[:, idx_new].set(c[key][:, idx_old])
                else:
                    c[key] = c[key].at[idx_new].set(c[key][idx_old])
        self.release([bid])
        self._c_cow.inc()
        return new

    def _destroy(self, bid: int) -> None:
        """Forget a block entirely: index entries dropped, back on the
        free list.  Positions are reset at the next alloc."""
        self._unregister(bid)
        self._ref.pop(bid, None)
        self._lru.pop(bid, None)
        self._free.append(bid)

    # -- prefix index --------------------------------------------------------
    def acquire_prefix(self, tokens, *, salt=None) -> PrefixHit:
        """Longest cached prefix of ``tokens`` whose KV is resident.

        ``salt`` must match the salt the chain was registered under
        (:func:`_chain_root`): nested-precision serving salts with the
        request's served bits, so lanes only share KV at equal
        precision.

        Walks block-size chunks of the prompt chain through the full
        index, then probes for a cached partial tail block continuing
        the chain.  Coverage is capped at ``len(tokens) - 1``: the last
        token must always be recomputed so the caller has logits to
        sample from.  Every returned block is acquired (refcount +1);
        token contents AND the recorded prefix hash / start offset are
        compared exactly, so a chain-hash collision can only cost a
        miss, never serve KV computed under a different prefix.  Hit
        statistics are NOT recorded here (a capacity-gated admission
        may re-probe the same queue head every step): the caller
        reports a committed admission via :meth:`record_hit`."""
        tokens = np.asarray(tokens)
        n = len(tokens)
        ids: list = []
        h = _chain_root(salt)
        covered = 0
        bs = self.block_size
        if self.prefix_cache:
            while covered + bs <= n - 1:
                chunk = tuple(int(t) for t in tokens[covered:covered + bs])
                bid = self._full_index.get(_chain_hash(h, chunk))
                if bid is None:
                    break
                meta = self._meta[bid]
                if meta.tokens != chunk or meta.prefix_hash != h \
                        or meta.start != covered:
                    break
                ids.append(bid)
                h = _chain_hash(h, chunk)
                covered += bs
        partial, filled = False, 0
        if self.prefix_cache:
            bid = self._partial_index.get(h)
            if bid is not None and bid not in ids:
                meta = self._meta[bid]
                f = meta.filled
                chunk = tuple(int(t) for t in tokens[covered:covered + f])
                if 0 < f <= n - 1 - covered and meta.tokens == chunk \
                        and meta.prefix_hash == h and meta.start == covered:
                    ids.append(bid)
                    partial, filled = True, f
                    covered += f
        self.acquire(ids)
        return PrefixHit(ids=ids, cached_len=covered, partial=partial,
                         filled=filled)

    def record_hit(self, hit: PrefixHit, n_tokens: int) -> None:
        """Count a *committed* admission in the hit statistics -- one
        lookup per admitted request.  Probes that failed the capacity
        gate and released their blocks must not inflate the counters
        that reports and benchmarks divide by prompt tokens."""
        self._c_lookups.inc()
        self._c_lookup_tokens.inc(int(n_tokens))
        if hit.ids:
            self._c_prefix_hits.inc()
            self._c_hit_tokens.inc(hit.cached_len)

    def register_chain(self, tokens, block_ids,
                       memo: Optional[ChainMemo] = None,
                       salt=None) -> None:
        """Index ``block_ids`` under the chain hashes of ``tokens``.

        ``block_ids[j]`` must hold the KV of ``tokens[j*bs:(j+1)*bs]``
        (the trailing partially-filled block included).  Existing
        entries win on duplicate content (the newcomer simply stays
        unindexed and is destroyed at release); a partial entry is
        replaced only by a longer partial on the same chain.

        ``memo`` (a per-owner :class:`ChainMemo`) resumes the walk after
        the full blocks a previous call already registered -- their
        tokens, ids and indexing outcome are immutable while the owner
        holds its references -- so repeated registration of a growing
        chain (every release/finish/preempt) hashes only the *new*
        blocks instead of re-walking the whole chain.

        ``salt`` must equal the owner's :meth:`acquire_prefix` salt --
        the chain lands in that salt's partition of the index.  A memo
        that has advanced past block 0 already carries the salted hash,
        so only the fresh walk consults ``salt``."""
        if not self.prefix_cache:
            return
        self.version += 1
        tokens = np.asarray(tokens)
        bs = self.block_size
        start, h = 0, _chain_root(salt)
        if memo is not None and memo.n_full:
            start, h = min(memo.n_full, len(block_ids)), memo.h
        for j in range(start, len(block_ids)):
            bid = int(block_ids[j])
            lo = j * bs
            chunk = tuple(int(t) for t in tokens[lo:lo + bs])
            if not chunk:
                break
            self._c_chain_ops.inc()
            meta = _BlockMeta(prefix_hash=h, start=lo, tokens=chunk)
            if len(chunk) == bs:
                key = meta.key
                cur = self._full_index.get(key)
                if cur is None:
                    self._unregister(bid)
                    self._meta[bid] = meta
                    self._full_index[key] = bid
                # else: duplicate content -> keep the incumbent
                h = key
                # advance the memo only while contiguous AND this block
                # IS the index entry: a block that lost the duplicate
                # race must stay re-walkable, so it can be re-indexed
                # once the incumbent is evicted from the LRU cache
                if memo is not None and memo.n_full == j \
                        and self._full_index.get(key) == bid:
                    memo.n_full, memo.h = j + 1, key
            else:                                   # partial tail
                cur = self._partial_index.get(h)
                if cur == bid or cur is None \
                        or self._meta[cur].filled < len(chunk):
                    if cur is not None and cur != bid:
                        self._unregister(cur)
                        if self._ref.get(cur) == 0:   # cached + unindexed
                            self._destroy(cur)        # -> useless, reclaim
                    self._unregister(bid)
                    self._meta[bid] = meta
                    self._partial_index[h] = bid
                break                               # chain ends here

    def _unregister(self, bid: int) -> None:
        meta = self._meta.pop(bid, None)
        if meta is None:
            return
        if meta.filled == self.block_size:
            if self._full_index.get(meta.key) == bid:
                del self._full_index[meta.key]
        elif self._partial_index.get(meta.prefix_hash) == bid:
            del self._partial_index[meta.prefix_hash]

    # -- invariants (test/debug surface) ------------------------------------
    def validate(self, check_contents: bool = False) -> None:
        """Assert the pool's structural invariants; with
        ``check_contents`` also verify that every indexed block's
        recorded token chain agrees with the resident positions
        (hash -> contents agreement)."""
        free = set(self._free)
        live = set(self._ref)
        assert 0 not in free and 0 not in live, "null block entered the pool"
        assert not (free & live), f"free list ∩ live set: {free & live}"
        assert len(free) + len(live) == self.n_usable, \
            (len(free), len(live), self.n_usable)
        assert all(r >= 0 for r in self._ref.values()), self._ref
        zero = {b for b, r in self._ref.items() if r == 0}
        assert zero == set(self._lru), (zero, set(self._lru))
        assert set(self._meta) <= live, "index entry for a freed block"
        for key, bid in self._full_index.items():
            meta = self._meta.get(bid)
            assert meta is not None and meta.filled == self.block_size
            assert meta.key == key
        for h, bid in self._partial_index.items():
            meta = self._meta.get(bid)
            assert meta is not None and 0 < meta.filled < self.block_size
            assert meta.prefix_hash == h
        if self.slots is not None:
            self.slots.validate()
        if check_contents:
            for c, stacked in self._attn_caches():
                pos = np.asarray(c["pos"])
                if stacked:
                    pos = pos[0]
                assert (pos[0] == -1).all(), "null block positions moved"
                for bid, meta in self._meta.items():
                    want = meta.start + np.arange(meta.filled)
                    got = pos[bid, :meta.filled]
                    assert (got == want).all(), (bid, got, want)
                break    # one layer suffices: ids address all layers alike

    # -- state slots ---------------------------------------------------------
    def alloc_slot(self) -> int:
        """Take one state slot with its rows reset (a reused slot must
        not leak a freed request's SSM state or cross-K/V through the
        recurrence / position mask).  The ``slot_fail`` fault site fires
        before the slot pool mutates (admission rolls cleanly back)."""
        assert self.slots is not None, "pool has no state slot pool"
        if self.faults.slot_fail():
            raise RuntimeError(
                f"slot pool exhausted (injected fault): "
                f"{self.slots.free_slots} of {self.slots.n_slots} free")
        slot = self.slots.alloc()
        self._reset_slot(slot)
        return slot

    def free_slot(self, slot: int) -> None:
        assert self.slots is not None, "pool has no state slot pool"
        self.slots.free(slot)

    def _reset_slot(self, slot: int) -> None:
        idx = jnp.asarray([slot], jnp.int32)
        for c, stacked in self._state_caches():
            c.update(_zero_slot_rows(stacked)({k: c[k] for k in c}, idx))

    # -- tree plumbing -------------------------------------------------------
    @staticmethod
    def _is_attn(c) -> bool:
        """Self-attention KV cache dict (block-addressed), vs an SSM
        state dict ({conv, state}, slot-addressed)."""
        return "conv" not in c

    def _attn_caches(self, caches=None):
        """Yield ``(cache_dict, stacked)`` for every *self-attention*
        layer (block-addressed KV planes); stacked leaves carry a
        leading ``n_units`` scan dim.  SSM state dicts and the enc-dec
        cross caches are slot-addressed and excluded."""
        caches = self.caches if caches is None else caches
        for c in caches.get("prelude", []):
            if self._is_attn(c):
                yield c, False
        for c in caches["blocks"]:
            if self._is_attn(c):
                yield c, True

    def _state_caches(self, caches=None):
        """Yield ``(cache_dict, stacked)`` for every slot-addressed
        state cache: SSM conv+state dicts and enc-dec cross caches."""
        caches = self.caches if caches is None else caches
        for c in caches.get("prelude", []):
            if not self._is_attn(c):
                yield c, False
        for c in caches["blocks"]:
            if not self._is_attn(c):
                yield c, True
        for c in caches.get("cross", []):
            yield c, True

    def _reset_pos(self, ids) -> None:
        idx = jnp.asarray(ids, jnp.int32)
        for c, stacked in self._attn_caches():
            if stacked:
                c["pos"] = c["pos"].at[:, idx].set(-1)
            else:
                c["pos"] = c["pos"].at[idx].set(-1)

    def write_prefill(self, single, block_ids, n_tokens: int) -> None:
        """Copy a prefilled contiguous B=1 cache into pool blocks.

        Retained as the copy-style oracle for the block-table suffix
        prefill the engine now runs (`Engine._paged_prefill` writes the
        bit-identical planes through the paged kernel's scatter path --
        tests compare the two).  ``single``: the cache tree from
        ``init_caches(cfg, 1, L)`` after a prefill of ``n_tokens``.
        Slots past ``n_tokens`` copy over as pos=-1 (bucketing pads /
        untouched init) and stay masked until decode overwrites them.
        """
        nb = len(block_ids)
        bs = self.block_size
        assert nb == self.blocks_for(max(n_tokens, 1)), (nb, n_tokens)
        idx = jnp.asarray(block_ids, jnp.int32)

        def copy(pool_leaf, single_leaf, stacked):
            if stacked:
                u = pool_leaf.shape[0]
                assert single_leaf.shape[2] >= nb * bs, \
                    "prefill cache shorter than the allocated blocks"
                src = single_leaf[:, 0, :nb * bs].reshape(
                    (u, nb, bs) + single_leaf.shape[3:])
                return pool_leaf.at[:, idx].set(src.astype(pool_leaf.dtype))
            assert single_leaf.shape[1] >= nb * bs
            src = single_leaf[0, :nb * bs].reshape(
                (nb, bs) + single_leaf.shape[2:])
            return pool_leaf.at[idx].set(src.astype(pool_leaf.dtype))

        for (pc, stacked), (sc, _) in zip(self._attn_caches(),
                                          self._attn_caches(single)):
            for key in _KV_KEYS:
                pc[key] = copy(pc[key], sc[key], stacked)

    _STEP_KEYS = ("block_tables", "length", "block_offset", "slots")

    def step_caches(self, block_tables: np.ndarray, lengths: np.ndarray,
                    *, block_offsets: Optional[np.ndarray] = None,
                    slots: Optional[np.ndarray] = None):
        """Pool tree for one decode/prefill step.

        Each *attention* cache dict gains this batch's ``block_tables
        (B, NB)``, ``length (B,)`` -- the number of tokens already
        resident, i.e. the write offset of the step's first new token
        -- and ``block_offset (B,)``, the count of leading logical
        blocks reclaimed out-of-window (the table is a rolling window:
        entry ``j`` maps logical block ``j + offset``).  Each *state*
        cache dict (SSM conv+state, enc-dec cross) gains ``slots (B,)``
        -- the batch rows' slot ids, -1 for padded lanes.  Stacked
        layers see everything broadcast over the leading ``n_units``
        dim."""
        bt = jnp.asarray(block_tables, jnp.int32)
        ln = jnp.asarray(lengths, jnp.int32)
        off = (jnp.zeros_like(ln) if block_offsets is None
               else jnp.asarray(block_offsets, jnp.int32))
        sl = None if slots is None else jnp.asarray(slots, jnp.int32)

        def bc(a, u):
            return jnp.broadcast_to(a, (u,) + a.shape)

        def aug(c, stacked):
            if not self._is_attn(c):
                assert sl is not None, \
                    "state caches need this batch's slot ids"
                u = c["conv"].shape[0] if stacked else None
                return dict(c, slots=bc(sl, u) if stacked else sl)
            if stacked:
                u = c["k"].shape[0]
                return dict(c, block_tables=bc(bt, u),
                            length=bc(ln, u), block_offset=bc(off, u))
            return dict(c, block_tables=bt, length=ln, block_offset=off)

        out = {}
        if "prelude" in self.caches:
            out["prelude"] = [aug(c, False)
                              for c in self.caches["prelude"]]
        out["blocks"] = [aug(c, True) for c in self.caches["blocks"]]
        if "cross" in self.caches:
            assert sl is not None, \
                "cross caches need this batch's slot ids"
            out["cross"] = [
                dict(c, slots=bc(sl, c["k"].shape[0]))
                for c in self.caches["cross"]]
        return out

    def absorb(self, new_caches) -> None:
        """Store updated pool leaves back, stripping the per-step keys."""
        def strip(c):
            return {k: v for k, v in c.items()
                    if k not in self._STEP_KEYS}

        out = {}
        for section in ("prelude", "blocks", "cross"):
            if section in new_caches:
                out[section] = [strip(c) for c in new_caches[section]]
        self.caches = out
