"""Paged block pool over packed bipolar-INT KV planes (serving memory).

The contiguous engine reserves ``max_len`` cache tokens per slot whether
a request is 8 tokens or 8k, so the 2x-16x payload savings of ``kv_bits``
is eaten by over-allocation.  This module turns the quantized KV cache
into a *block pool* (the TensorRT-LLM paged-KV design adapted to our
pallas|interpret|reference kernel contract): fixed-size token blocks
shared by every request and every layer, addressed through per-request
block tables.  Concurrent requests then scale with *tokens actually
resident x bits/element*, not ``n_slots x max_len x 16``.

Layout.  The pool reuses :func:`repro.models.model.init_caches` with
``batch=n_blocks, max_len=block_size``: every attention cache leaf's
leading (batch, length) dims become (physical block, in-block slot) --
``k``/``v`` are ``(n_blocks, block_size, H, kv_bits, D/32)`` uint32 bit
planes (stacked scan units carry a leading ``n_units`` dim), scales are
``(n_blocks, block_size, H, 1)`` f32 and ``pos`` is ``(n_blocks,
block_size)`` int32.  One *logical* block id addresses the same physical
index in every layer's pool, so a request owns a single block table.

Block 0 is the reserved **null block**: never allocated, its positions
stay -1, and block-table padding points at it -- a padded or inactive
lane therefore reads only masked slots and contributes exactly 0.

Invariants the pool maintains:
* freshly allocated blocks have all positions reset to -1 (stale
  positions from a freed request could otherwise pass the causal mask);
* prefill copies a contiguous B=1 cache's packed planes verbatim
  (:meth:`PagedKVPool.write_prefill`), so paged decode is token-identical
  to the contiguous engine at equal ``kv_bits``;
* decode steps receive the pool with this batch's ``block_tables`` /
  ``length`` injected per layer (:meth:`step_caches`) and give updated
  pool leaves back through :meth:`absorb`.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig, QuantConfig, effective_kv_bits

_KV_KEYS = ("k", "v", "k_scale", "v_scale", "pos")


def supports_paging(cfg: ModelConfig) -> bool:
    """Paged serving needs every mixer to own a pageable KV stream:
    attention-only decoders (dense/moe/vlm).  SSM/hybrid state and
    enc-dec cross caches are fixed-size per request -- nothing to page
    (ROADMAP open item)."""
    return (cfg.family != "audio"
            and all(cfg.layer_kind(i) == "attn"
                    for i in range(cfg.n_layers)))


class PagedKVPool:
    """Fixed-size-block pool of packed bipolar KV planes + a free list.

    ``n_blocks`` counts physical blocks *including* the reserved null
    block 0; capacity available to requests is ``n_usable = n_blocks-1``
    blocks of ``block_size`` tokens each.
    """

    def __init__(self, cfg: ModelConfig, n_blocks: int, block_size: int,
                 quant: Optional[QuantConfig] = None):
        assert supports_paging(cfg), \
            f"paged KV pool needs an attention-only decoder, got {cfg.family}"
        kv_bits = effective_kv_bits(cfg, quant)
        assert kv_bits, "the paged pool stores packed bipolar planes: " \
            "set kv_bits (QuantConfig.kv_bits or ModelConfig.kv_bits)"
        assert n_blocks >= 2, "need at least the null block + one usable"
        if cfg.window:
            assert block_size <= cfg.window, (block_size, cfg.window)
        self.cfg, self.quant = cfg, quant
        self.kv_bits = kv_bits
        self.n_blocks, self.block_size = n_blocks, block_size
        self.caches = M.init_caches(cfg, n_blocks, block_size, quant=quant)
        # LIFO free list, block 0 reserved as the null block
        self._free = list(range(n_blocks - 1, 0, -1))

    # -- accounting ----------------------------------------------------------
    @property
    def n_usable(self) -> int:
        return self.n_blocks - 1

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.n_usable - len(self._free)

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def report(self, tokens_resident: Optional[int] = None) -> dict:
        """Occupancy / fragmentation accounting (kv_cache_bytes-style).

        ``tokens_resident``: total tokens currently cached across
        requests (the scheduler knows; the pool only sees blocks).
        Internal fragmentation = allocated-but-empty token slots as a
        fraction of allocated slots."""
        from repro.serving.engine import kv_cache_bytes
        pool_bytes = kv_cache_bytes(self.caches)
        payload = kv_cache_bytes(self.caches, payload_only=True)
        slots = self.used_blocks * self.block_size
        rep = dict(
            n_blocks=self.n_blocks, block_size=self.block_size,
            kv_bits=self.kv_bits,
            n_usable=self.n_usable, free_blocks=self.free_blocks,
            used_blocks=self.used_blocks,
            pool_bytes=int(pool_bytes), payload_bytes=int(payload),
            bytes_per_block=int(pool_bytes / max(self.n_blocks, 1)),
            occupancy=self.used_blocks / max(self.n_usable, 1),
        )
        if tokens_resident is not None:
            rep["tokens_resident"] = int(tokens_resident)
            rep["fragmentation"] = (
                1.0 - tokens_resident / slots if slots else 0.0)
        return rep

    # -- alloc / free --------------------------------------------------------
    def alloc(self, n: int) -> list:
        """Pop ``n`` physical blocks and reset their positions to -1."""
        if n > len(self._free):
            raise RuntimeError(
                f"pool exhausted: want {n} blocks, {len(self._free)} free")
        ids = [self._free.pop() for _ in range(n)]
        self._reset_pos(ids)
        return ids

    def free(self, ids) -> None:
        self._free.extend(ids)

    # -- tree plumbing -------------------------------------------------------
    def _attn_caches(self, caches=None):
        """Yield ``(cache_dict, stacked)`` for every attention layer;
        stacked leaves carry a leading ``n_units`` scan dim."""
        caches = self.caches if caches is None else caches
        for c in caches.get("prelude", []):
            yield c, False
        for c in caches["blocks"]:
            yield c, True

    def _reset_pos(self, ids) -> None:
        idx = jnp.asarray(ids, jnp.int32)
        for c, stacked in self._attn_caches():
            if stacked:
                c["pos"] = c["pos"].at[:, idx].set(-1)
            else:
                c["pos"] = c["pos"].at[idx].set(-1)

    def write_prefill(self, single, block_ids, n_tokens: int) -> None:
        """Copy a prefilled contiguous B=1 cache into pool blocks.

        ``single``: the cache tree from ``init_caches(cfg, 1, L)`` after
        a prefill of ``n_tokens`` (its packed planes are bit-identical
        to what paged decode would have appended, which is what makes
        paged vs contiguous token-identical).  Slots past ``n_tokens``
        copy over as pos=-1 (bucketing pads / untouched init) and stay
        masked until decode overwrites them.
        """
        nb = len(block_ids)
        bs = self.block_size
        assert nb == self.blocks_for(max(n_tokens, 1)), (nb, n_tokens)
        idx = jnp.asarray(block_ids, jnp.int32)

        def copy(pool_leaf, single_leaf, stacked):
            if stacked:
                u = pool_leaf.shape[0]
                assert single_leaf.shape[2] >= nb * bs, \
                    "prefill cache shorter than the allocated blocks"
                src = single_leaf[:, 0, :nb * bs].reshape(
                    (u, nb, bs) + single_leaf.shape[3:])
                return pool_leaf.at[:, idx].set(src.astype(pool_leaf.dtype))
            assert single_leaf.shape[1] >= nb * bs
            src = single_leaf[0, :nb * bs].reshape(
                (nb, bs) + single_leaf.shape[2:])
            return pool_leaf.at[idx].set(src.astype(pool_leaf.dtype))

        for (pc, stacked), (sc, _) in zip(self._attn_caches(),
                                          self._attn_caches(single)):
            for key in _KV_KEYS:
                pc[key] = copy(pc[key], sc[key], stacked)

    def step_caches(self, block_tables: np.ndarray, lengths: np.ndarray):
        """Pool tree for one decode step: each attention cache dict gains
        this batch's ``block_tables (B, NB)`` and ``length (B,)`` (stacked
        layers see them broadcast over the leading ``n_units`` dim)."""
        bt = jnp.asarray(block_tables, jnp.int32)
        ln = jnp.asarray(lengths, jnp.int32)

        def aug(c, stacked):
            if stacked:
                u = c["k"].shape[0]
                return dict(c,
                            block_tables=jnp.broadcast_to(
                                bt, (u,) + bt.shape),
                            length=jnp.broadcast_to(ln, (u,) + ln.shape))
            return dict(c, block_tables=bt, length=ln)

        out = {}
        if "prelude" in self.caches:
            out["prelude"] = [aug(c, False)
                              for c in self.caches["prelude"]]
        out["blocks"] = [aug(c, True) for c in self.caches["blocks"]]
        return out

    def absorb(self, new_caches) -> None:
        """Store updated pool leaves back, stripping the per-step keys."""
        def strip(c):
            return {k: v for k, v in c.items()
                    if k not in ("block_tables", "length")}

        out = {}
        if "prelude" in new_caches:
            out["prelude"] = [strip(c) for c in new_caches["prelude"]]
        out["blocks"] = [strip(c) for c in new_caches["blocks"]]
        self.caches = out
