"""Checkpointing: atomic, versioned, keep-K, optional async, mesh-elastic.

Layout:  ``<dir>/step_<N>/{arrays.npz, meta.json}``  (or per-process
``arrays_p<rank>.npz`` shard files in sharded mode).  A checkpoint becomes
visible only via the final atomic ``os.rename`` of its temp directory, so
a preemption mid-save never corrupts the latest-complete pointer.

Checkpoints store *full logical arrays* keyed by pytree path, so a run can
resume onto a different mesh shape (elastic scaling): ``restore`` takes an
optional ``shardings`` tree and ``jax.device_put``s each leaf to its new
layout.  Moment tensors may be int8 (quantized optimizer state) -- dtypes
round-trip exactly.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    """-> (arrays dict, dtype sidecar).  npz has no bf16 etc.; ml_dtypes
    leaves are stored bit-exactly via a same-width integer view and the
    true dtype recorded in the sidecar."""
    flat, dtypes = {}, {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or arr.dtype.name not in np.sctypeDict:
            dtypes[key] = arr.dtype.name          # e.g. "bfloat16"
            arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
        flat[key] = arr
    return flat, dtypes


def save_tree(tree, directory: str, step: int, *, keep: int = 3,
              extra_meta: Optional[dict] = None) -> str:
    """Atomic synchronous save. Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays, dtypes = _flatten_with_paths(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {"step": step, "time": time.time(), "dtypes": dtypes,
            **(extra_meta or {})}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic publish
    _cleanup(directory, keep)
    return final


def _cleanup(directory: str, keep: int):
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp") \
                and os.path.exists(os.path.join(directory, name, "meta.json")):
            out.append(int(name[5:]))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore_tree(template, directory: str, step: Optional[int] = None, *,
                 shardings=None):
    """Restore into the structure of ``template``.

    ``shardings``: optional matching tree of ``jax.sharding.Sharding`` --
    pass the *new* mesh's shardings to resume elastically on a different
    topology.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta_early = json.load(f)
    sidecar = meta_early.get("dtypes", {})
    import ml_dtypes  # noqa: F401  (registers bfloat16 etc. with numpy)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {}
        for k in z.files:
            arr = z[k]
            if k in sidecar:
                arr = arr.view(np.dtype(sidecar[k]))
            arrays[k] = arr
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    leaves = []
    for (path_t, leaf), shd in zip(paths, shard_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_t)
        arr = arrays[key]
        if shd is not None:
            leaves.append(jax.device_put(arr, shd))
        else:
            leaves.append(jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    return tree, meta


class CheckpointManager:
    """Periodic async checkpointing with bounded queue depth 1.

    The async thread snapshots host copies (``np.asarray``) *before*
    returning control, so training can mutate device buffers immediately;
    a second save request while one is in flight blocks (backpressure)
    rather than dropping checkpoints.
    """

    def __init__(self, directory: str, *, interval: int = 100,
                 keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.interval = interval
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    def maybe_save(self, tree, step: int, *, force: bool = False,
                   extra_meta: Optional[dict] = None):
        if not force and (self.interval <= 0 or step % self.interval):
            return False
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot now
        if self.async_save:
            self._thread = threading.Thread(
                target=save_tree, args=(host_tree, self.directory, step),
                kwargs=dict(keep=self.keep, extra_meta=extra_meta),
                daemon=True)
            self._thread.start()
        else:
            save_tree(host_tree, self.directory, step, keep=self.keep,
                      extra_meta=extra_meta)
        return True

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def latest_step(self):
        return latest_step(self.directory)

    def restore(self, template, step=None, shardings=None):
        return restore_tree(template, self.directory, step,
                            shardings=shardings)
